"""Co-location / interference evaluation (constraint layer v2).

Paper-style table for the affinity extension (arXiv:2407.14572 semantics
on our simulator): a latency-sensitive API function shares a two-rack
cluster with a noisy batch cruncher, and a join function wants to land
next to the cache-warmer holding its working set.

Three policies over identical deployments and workloads:
  * blank     — the constraint-free default policy (topology-aware, but
                blind to what else runs on a worker);
  * tapp+aff  — anti-affinity keeps latency_api off batch_crunch workers,
                affinity steers feature_join onto cache_warmer workers;
  * tapp+fed  — the same constrained policy driven through a two-entry
                TappFederation (each workload class enters at its own
                rack's gateway and spills across racks only when its
                rack declines — Deployment API v2).

Run: PYTHONPATH=src python examples/colocation_eval.py
"""
import statistics

from repro.core.sim.scenarios import run_colocation_case

N_DEPLOYMENTS = 4
FUNCTIONS = ("latency_api", "batch_crunch", "feature_join")


def collect(constrained: bool, federated: bool = False):
    per_fn = {fn: {"mean": [], "p99": []} for fn in FUNCTIONS}
    join_cohosted = []
    forwarded = 0
    for seed in range(N_DEPLOYMENTS):
        _, result = run_colocation_case(
            constrained=constrained, seed=seed, federated=federated
        )
        forwarded += result.n_forwarded
        for fn in FUNCTIONS:
            summary = result.for_function(fn).summary()
            per_fn[fn]["mean"].append(summary["mean"])
            per_fn[fn]["p99"].append(summary["p99"])
        warm_workers = set(
            result.for_function("cache_warmer").per_worker_counts()
        )
        join_counts = result.for_function("feature_join").per_worker_counts()
        total = sum(join_counts.values())
        cohosted = sum(
            n for worker, n in join_counts.items() if worker in warm_workers
        )
        join_cohosted.append(cohosted / max(1, total))
    return per_fn, statistics.fmean(join_cohosted), forwarded


def main() -> None:
    print(f"# co-location evaluation over {N_DEPLOYMENTS} deployments")
    print("policy,function,mean_s,p99_s")
    rows = {}
    for label, constrained, federated in (
        ("blank", False, False),
        ("tapp+aff", True, False),
        ("tapp+fed", True, True),
    ):
        per_fn, cohost, forwarded = collect(constrained, federated)
        rows[label] = (per_fn, cohost, forwarded)
        for fn in FUNCTIONS:
            print(
                f"{label},{fn},"
                f"{statistics.fmean(per_fn[fn]['mean']):.4f},"
                f"{statistics.fmean(per_fn[fn]['p99']):.4f}"
            )

    blank_fn, blank_cohost, _ = rows["blank"]
    aff_fn, aff_cohost, _ = rows["tapp+aff"]
    blank_lat = statistics.fmean(blank_fn["latency_api"]["mean"])
    aff_lat = statistics.fmean(aff_fn["latency_api"]["mean"])
    print()
    print(
        f"latency_api mean: {blank_lat * 1e3:.1f}ms → {aff_lat * 1e3:.1f}ms "
        f"({(1 - aff_lat / blank_lat):.0%} improvement from anti-affinity)"
    )
    print(
        f"feature_join placed on a cache_warmer worker: "
        f"{blank_cohost:.0%} → {aff_cohost:.0%} (affinity)"
    )
    fed_fn, _, fed_forwarded = rows["tapp+fed"]
    fed_lat = statistics.fmean(fed_fn["latency_api"]["mean"])
    print(
        f"federated (per-rack entry): latency_api mean "
        f"{fed_lat * 1e3:.1f}ms; {fed_forwarded} requests forwarded "
        f"across racks over {N_DEPLOYMENTS} deployments"
    )


if __name__ == "__main__":
    main()
