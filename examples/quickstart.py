"""Quickstart: the paper's contribution in 60 lines.

Builds a two-zone serverless topology, loads a tAPP script, and routes
tagged invocations — then shows the same policy engine placing real
inference requests on JAX model replicas.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax

from repro.configs import smoke_config
from repro.core.scheduler import (
    ControllerState,
    Gateway,
    Invocation,
    Watcher,
    WorkerState,
)
from repro.core.scheduler.topology import DistributionPolicy
from repro.models import Model
from repro.runtime.serve_engine import Replica, ServingEngine

SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- critical:
  - controller: EdgeCtl
    workers:
    - set: edge
    strategy: random
    topology_tolerance: none
  followup: fail
"""


def control_plane_demo() -> None:
    print("== control plane: tAPP routing ==")
    watcher = Watcher()
    watcher.register_controller(ControllerState(name="EdgeCtl", zone="edge"))
    watcher.register_controller(ControllerState(name="CloudCtl", zone="cloud"))
    watcher.register_worker(
        WorkerState(name="w-edge", zone="edge", sets=frozenset({"edge", "any"}))
    )
    watcher.register_worker(
        WorkerState(name="w-cloud", zone="cloud", sets=frozenset({"cloud", "any"}))
    )
    watcher.load_script(SCRIPT)
    gateway = Gateway(watcher, distribution=DistributionPolicy.SHARED)

    for tag in ("critical", None):
        decision = gateway.route(Invocation("my_fn", tag=tag))
        print(f"tag={tag!r:>12} → worker={decision.worker} "
              f"(controller={decision.controller})")
    # Observability opts into tracing; the serving hot path leaves it off.
    print(gateway.route(Invocation("my_fn", tag="critical"), trace=True).explain())


def data_plane_demo() -> None:
    print("\n== data plane: tAPP-scheduled serving ==")
    cfg = dataclasses.replace(smoke_config("smollm_135m"), n_layers=2)
    params = Model(cfg).init_params(jax.random.PRNGKey(0))
    engine = ServingEngine(tapp_script=SCRIPT)
    engine.add_controller("EdgeCtl", zone="edge")
    engine.add_controller("CloudCtl", zone="cloud")
    engine.add_replica(Replica("w-edge", cfg, params, zone="edge",
                               sets=["edge"], slots=2, max_len=32))
    engine.add_replica(Replica("w-cloud", cfg, params, zone="cloud",
                               sets=["cloud"], slots=2, max_len=32))

    critical = engine.submit("smollm-135m", [1, 2, 3], tag="critical",
                             max_new_tokens=5)
    normal = engine.submit("smollm-135m", [4, 5, 6], max_new_tokens=5)
    engine.run_until_done()
    print(f"critical request → replica {critical.replica}, "
          f"tokens {critical.output}")
    print(f"normal   request → replica {normal.replica}, "
          f"tokens {normal.output}")


if __name__ == "__main__":
    control_plane_demo()
    data_plane_demo()
