"""Quickstart: the paper's contribution in 60 lines, on the Platform API.

Declares a two-zone serverless deployment as a `ClusterSpec`, applies a
tAPP policy through the platform's apply/dry-run lifecycle, and runs
tagged invocations through the unified invoke→admit→complete flow —
then shows the same policy engine placing real inference requests on
JAX model replicas.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax

from repro.configs import smoke_config
from repro.core.platform import (
    ClusterSpec,
    ControllerSpec,
    TappPlatform,
    WorkerSpec,
)
from repro.core.scheduler.topology import DistributionPolicy
from repro.models import Model
from repro.runtime.serve_engine import Replica, ServingEngine

SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- critical:
  - controller: EdgeCtl
    workers:
    - set: edge
    strategy: random
    topology_tolerance: none
  followup: fail
"""

SPEC = ClusterSpec(
    controllers=(
        ControllerSpec("EdgeCtl", zone="edge"),
        ControllerSpec("CloudCtl", zone="cloud"),
    ),
    workers=(
        WorkerSpec("w-edge", zone="edge", sets=("edge", "any")),
        WorkerSpec("w-cloud", zone="cloud", sets=("cloud", "any")),
    ),
)


def control_plane_demo() -> None:
    print("== control plane: one platform, one policy lifecycle ==")
    platform = TappPlatform(SPEC, distribution=DistributionPolicy.SHARED)

    # Policies are deployment artifacts: validated + dry-run against the
    # live topology, compiled, then atomically swapped (rollback-able).
    handle = platform.apply_policy(SCRIPT, strict=True)
    print(f"policy v{handle.version} active, tags={list(handle.tag_names)}")

    for tag in ("critical", None):
        placement = platform.invoke("my_fn", tag=tag)
        print(f"tag={tag!r:>12} → worker={placement.worker} "
              f"(controller={placement.controller})")
        placement.complete()  # retire the running-function ticket

    # Observability is typed: explain() probes without admitting.
    print(platform.explain("my_fn", tag="critical").render())
    print(platform.stats())


def data_plane_demo() -> None:
    print("\n== data plane: tAPP-scheduled serving ==")
    cfg = dataclasses.replace(smoke_config("smollm_135m"), n_layers=2)
    params = Model(cfg).init_params(jax.random.PRNGKey(0))
    engine = ServingEngine(tapp_script=SCRIPT)
    engine.add_controller("EdgeCtl", zone="edge")
    engine.add_controller("CloudCtl", zone="cloud")
    engine.add_replica(Replica("w-edge", cfg, params, zone="edge",
                               sets=["edge"], slots=2, max_len=32))
    engine.add_replica(Replica("w-cloud", cfg, params, zone="cloud",
                               sets=["cloud"], slots=2, max_len=32))

    critical = engine.submit("smollm-135m", [1, 2, 3], tag="critical",
                             max_new_tokens=5)
    normal = engine.submit("smollm-135m", [4, 5, 6], max_new_tokens=5)
    engine.run_until_done()
    print(f"critical request → replica {critical.replica}, "
          f"tokens {critical.output}")
    print(f"normal   request → replica {normal.replica}, "
          f"tokens {normal.output}")


if __name__ == "__main__":
    control_plane_demo()
    data_plane_demo()
