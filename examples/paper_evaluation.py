"""Reproduce the paper's §5 evaluation tables (DES-driven).

Run: PYTHONPATH=src python examples/paper_evaluation.py [--quick]
"""
import sys

from benchmarks.paper_tables import (
    data_locality_table,
    overhead_table,
    qualitative_mqtt,
)


def _print_table(rows, cols):
    head = " | ".join(f"{c:>14}" for c in cols)
    print(head)
    print("-" * len(head))
    for r in rows:
        print(" | ".join(
            f"{r[c]:>14.3f}" if isinstance(r[c], float) else f"{str(r[c]):>14}"
            for c in cols
        ))


def main() -> None:
    n = 3 if "--quick" in sys.argv else 10

    print("### §5.1 Qualitative case (MQTT): failure rates\n")
    rows = qualitative_mqtt()
    _print_table(rows, ["system", "deployment", "function", "failure_rate"])
    vanilla_bad = [r for r in rows if r["system"] == "vanilla"
                   and r["deployment"] == "cloud-primary"
                   and r["function"] == "data-collection"][0]
    tapp_rows = [r for r in rows if r["system"] == "tapp"]
    print(f"\n→ vanilla fails {vanilla_bad['failure_rate']:.0%} of "
          f"data-collection in the cloud-primary deployment;"
          f" tAPP fails {max(r['failure_rate'] for r in tapp_rows):.0%} anywhere."
          " (paper: 'vanilla OpenWhisk failed every invocation')\n")

    print(f"### §5.4.1 Overhead tests ({n} deployments)\n")
    _print_table(
        overhead_table(n_deployments=n),
        ["test", "scheduler", "mean_s", "std_s", "deployment_spread_s"],
    )

    print(f"\n### §5.4.2 Data-locality tests ({n} deployments)\n")
    _print_table(
        data_locality_table(n_deployments=n),
        ["test", "scheduler", "mean_s", "std_s", "deployment_spread_s"],
    )


if __name__ == "__main__":
    main()
