"""Topology-aware serving: the paper's case study on real model replicas.

Three request classes (the paper's ①②③) over an edge+cloud deployment:
  * ``critical``          → edge replicas only (tolerance none);
  * ``machine_learning``  → cloud replicas, zone-tolerant fallback;
  * untagged (generic)    → local-first with cloud spill (default tag).

Also demonstrates: replica failure → automatic re-routing; the platform
policy lifecycle (live apply flipping the ML class to the edge without
restarting anything, then `rollback()` restoring the previous policy);
the constraint layer's anti-affinity spread with the typed `explain()`
report; and the Deployment API v2 federation — per-zone entrypoints
with cross-zone forwarding priced by a network model and narrated hop
by hop in `TappFederation.explain()`.

Run: PYTHONPATH=src python examples/serve_topology.py
"""
import dataclasses

import jax

from repro.configs import smoke_config
from repro.core.platform import ClusterSpec, ControllerSpec, FederationSpec
from repro.core.scheduler.topology import DistributionPolicy
from repro.core.sim.core import NetworkModel
from repro.models import Model
from repro.runtime.serve_engine import Replica, ServingEngine

CASE_STUDY_SCRIPT = """
- critical:
  - controller: LocalCtl_1
    workers:
    - set: edge
    strategy: random
    topology_tolerance: none
  followup: fail
- machine_learning:
  - controller: CloudCtl
    workers:
    - set: cloud
    topology_tolerance: same
  followup: default
- default:
  - controller: LocalCtl_1
    workers:
    - set: internal
      strategy: random
    - set: cloud
      strategy: random
    strategy: best_first
  - controller: LocalCtl_2
    workers:
    - set: internal
      strategy: random
    - set: cloud
      strategy: random
    strategy: best_first
  strategy: random
"""

FLIPPED = CASE_STUDY_SCRIPT.replace(
    "- controller: CloudCtl\n    workers:\n    - set: cloud",
    "- controller: LocalCtl_1\n    workers:\n    - set: edge",
)

# Constraint layer v2: `spread` requests avoid replicas already serving
# the model (self anti-affinity = spread semantics), spilling to any
# replica once all host one.
SPREAD_SCRIPT = CASE_STUDY_SCRIPT + """
- spread:
  - workers:
    - set:
    strategy: best_first
    invalidate: capacity_used 75%
    anti-affinity: [smollm-135m]
  - workers:
    - set:
  followup: default
"""


def main() -> None:
    cfg = dataclasses.replace(smoke_config("smollm_135m"), n_layers=2)
    params = Model(cfg).init_params(jax.random.PRNGKey(0))

    engine = ServingEngine(
        distribution=DistributionPolicy.SHARED,
        tapp_script=CASE_STUDY_SCRIPT,
    )
    engine.add_controller("LocalCtl_1", zone="edge")
    engine.add_controller("LocalCtl_2", zone="edge")
    engine.add_controller("CloudCtl", zone="cloud")

    def replica(name, zone, sets):
        return Replica(name, cfg, params, zone=zone, sets=sets, slots=2,
                       max_len=32)

    engine.add_replica(replica("W_1", "edge", ["edge", "internal"]))
    engine.add_replica(replica("W_2", "edge", ["edge", "internal"]))
    engine.add_replica(replica("W_3", "cloud", ["cloud"]))
    engine.add_replica(replica("W_4", "cloud", ["cloud"]))

    print("== request classes → placement ==")
    classes = [("critical", "critical"), ("machine_learning", "ml"),
               (None, "generic")]
    reqs = {}
    for tag, label in classes:
        reqs[label] = [
            engine.submit("smollm-135m", [1, 2, 3], tag=tag, max_new_tokens=3)
            for _ in range(3)
        ]
    engine.run_until_done()
    for label, rs in reqs.items():
        print(f"{label:>10}: replicas {[r.replica for r in rs]}")

    print("\n== failure: cloud replica W_3 lost mid-service ==")
    ml = [engine.submit("smollm-135m", [7, 8], tag="machine_learning",
                        max_new_tokens=6) for _ in range(4)]
    engine.step_once()
    engine.remove_replica("W_3")
    engine.run_until_done()
    print(f"ml after failure: replicas {[r.replica for r in ml]} "
          f"(all done: {all(r.state == 'done' for r in ml)})")

    print("\n== live policy apply: ML flipped to the edge (no restart) ==")
    flipped = engine.platform.apply_policy(FLIPPED)
    ml2 = [engine.submit("smollm-135m", [9], tag="machine_learning",
                         max_new_tokens=3) for _ in range(3)]
    engine.run_until_done()
    print(f"ml after apply (policy v{flipped.version}): "
          f"replicas {[r.replica for r in ml2]}")

    print("\n== rollback: previous policy restored bit-for-bit ==")
    restored = engine.platform.rollback()
    ml3 = [engine.submit("smollm-135m", [9], tag="machine_learning",
                         max_new_tokens=3) for _ in range(3)]
    engine.run_until_done()
    print(f"ml after rollback (policy v{restored.version}): "
          f"replicas {[r.replica for r in ml3]}")

    print("\n== anti-affinity spread (constraint layer v2) ==")
    engine.platform.apply_policy(SPREAD_SCRIPT)
    spread = [engine.submit("smollm-135m", [4, 2], tag="spread",
                            max_new_tokens=8) for _ in range(3)]
    engine.step_once()  # admit + first decode tick; replicas now host work
    print(f"spread placements: {[r.replica for r in spread]}")
    report = engine.platform.explain("smollm-135m", tag="spread",
                                     model_id="smollm-135m")
    print("typed explain() report:")
    print(report.render())
    print(f"per-worker rejections: {report.rejections()}")
    engine.run_until_done()
    print(f"platform stats: {engine.platform.stats()}")

    federation_demo(cfg, params)


#: Federation policy: `critical` work is pinned to the edge (tolerance
#: none — it may be *forwarded to* its edge home from any entrypoint but
#: never placed outside it); everything else is zone-local-first with
#: cross-zone spill (`followup: default` + blank set).
FEDERATION_SCRIPT = """
- critical:
  - controller: EdgeCtl
    workers:
    - set: edge
    topology_tolerance: none
  followup: fail
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
"""


def federation_demo(cfg, params) -> None:
    """Deployment API v2: one engine, two zone entrypoints."""
    print("\n== federation: per-zone entrypoints + cross-zone forwarding ==")
    spec = FederationSpec.of(
        {
            "edge": ClusterSpec(controllers=(ControllerSpec("EdgeCtl"),)),
            "cloud": ClusterSpec(controllers=(ControllerSpec("CloudCtl"),)),
        },
        network=NetworkModel(
            rtt={("edge", "cloud"): 0.040},
            bandwidth={},
        ),
        default_entry="edge",
    )
    engine = ServingEngine(
        distribution=DistributionPolicy.SHARED, federation=spec
    )
    engine.platform.apply_policy(FEDERATION_SCRIPT)

    def replica(name, zone, sets, slots=1):
        return Replica(name, cfg, params, zone=zone, sets=sets, slots=slots,
                       max_len=32)

    engine.add_replica(replica("E_1", "edge", ["edge"]))
    engine.add_replica(replica("C_1", "cloud", ["cloud"]))

    # Critical work entering at the CLOUD is forwarded to its edge home;
    # generic work entering at a saturated edge spills to the cloud.
    crit = engine.submit("smollm-135m", [1, 2], tag="critical",
                         entry_zone="cloud", max_new_tokens=3)
    generic = [
        engine.submit("smollm-135m", [3 + i], entry_zone="edge",
                      max_new_tokens=3)
        for i in range(2)
    ]
    engine.run_until_done()
    print(f"critical (entered cloud): replica {crit.replica}")
    print(f"generic (entered edge):   replicas "
          f"{[r.replica for r in generic]}")

    report = engine.platform.explain("smollm-135m", tag="critical",
                                     entry_zone="cloud",
                                     model_id="smollm-135m")
    print("federated explain() hop report:")
    print(report.render())

    stats = engine.platform.stats()
    print(f"forwards={stats.forwards} attempts={stats.forward_attempts} "
          f"cross_zone_rtt={stats.cross_zone_rtt * 1e3:.0f}ms")
    for zone in stats.zones:
        print(f"  {zone.zone}: entered={zone.entered} "
              f"in={zone.forwarded_in} out={zone.forwarded_out} "
              f"inflight={zone.inflight}")


if __name__ == "__main__":
    main()
