"""End-to-end training driver: smollm-135M for a few hundred steps.

The full production path — config, sharded state, synthetic pipeline,
fault-tolerant loop with async checkpointing — scaled to run on this CPU
container via --preset. With --preset full it runs the real 135M config
(the same code the dry-run lowers for the 256-chip mesh).

Run: PYTHONPATH=src python examples/train_smollm.py --steps 300
"""
import argparse
import dataclasses
import tempfile
import time

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config, smoke_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.launch.steps import TrainState, make_train_step
from repro.models import Model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime.train_loop import TrainLoopConfig, run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--preset", choices=["smoke", "small", "full"],
                    default="small")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--inject-failure-at", type=int, default=None,
                    help="raise at this step once, to demo restart")
    args = ap.parse_args()

    if args.preset == "full":
        cfg = get_config("smollm_135m")
    elif args.preset == "small":
        cfg = dataclasses.replace(
            smoke_config("smollm_135m"),
            n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
            d_ff=512, vocab_size=4096,
        )
    else:
        cfg = smoke_config("smollm_135m")

    model = Model(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} preset={args.preset} params={n_params/1e6:.1f}M")

    state = TrainState(params=params, opt=adamw_init(opt_cfg, params))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0,))
    pipeline = SyntheticTokens(
        DataConfig(vocab_size=cfg.vocab_size, global_batch=args.batch,
                   seq_len=args.seq)
    )
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="smollm_ckpt_")
    checkpointer = Checkpointer(ckpt_dir, keep_last=3)

    def log(step, metrics):
        print(
            f"step {step:>5}  loss {float(metrics['loss']):.4f}  "
            f"lr {float(metrics['lr']):.2e}  "
            f"gnorm {float(metrics['grad_norm']):.3f}  "
            f"{metrics['step_time_s']*1e3:.0f} ms"
        )

    t0 = time.time()
    report = run_training(
        step_fn=step_fn,
        state=state,
        pipeline=pipeline,
        checkpointer=checkpointer,
        config=TrainLoopConfig(
            total_steps=args.steps,
            checkpoint_every=max(10, args.steps // 5),
            log_every=max(1, args.steps // 20),
            inject_failure_at=args.inject_failure_at,
        ),
        on_metrics=log,
    )
    wall = time.time() - t0
    first = sum(report.losses[:10]) / max(1, len(report.losses[:10]))
    last = sum(report.losses[-10:]) / max(1, len(report.losses[-10:]))
    print(
        f"\ndone: {report.steps_run} steps in {wall:.1f}s "
        f"({wall / max(1, report.steps_run) * 1e3:.0f} ms/step)\n"
        f"loss {first:.4f} → {last:.4f}   restarts={report.restarts} "
        f"stragglers={report.straggler_steps}\n"
        f"checkpoints in {ckpt_dir} (latest step {checkpointer.latest_step()})"
    )


if __name__ == "__main__":
    main()
