"""Per-architecture smoke tests (reduced same-family configs, CPU).

For each of the 10 assigned archs: instantiate the reduced config, run one
forward/loss + one train step, assert output shapes and no NaNs; check
prefill+decode consistency against the full forward.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models import Model, SHAPES, shape_applicable
from repro.models import encdec as ed_mod
from repro.models import lm as lm_mod
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

RNG = jax.random.PRNGKey(0)


def _batch(cfg, bsz=2, seq=16, rng=RNG):
    toks = jax.random.randint(rng, (bsz, seq), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            rng, (bsz, seq, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_loss_finite_and_shapes(self, arch):
        cfg = smoke_config(arch)
        model = Model(cfg)
        params = model.init_params(RNG)
        batch = _batch(cfg)
        loss, metrics = model.loss(params, batch)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss))
        assert bool(jnp.isfinite(metrics["ce"]))

    def test_one_train_step(self, arch):
        cfg = smoke_config(arch)
        model = Model(cfg)
        params = model.init_params(RNG)
        opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        opt = adamw_init(opt_cfg, params)
        batch = _batch(cfg)

        (loss, _), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True
        )(params)
        new_params, new_opt, metrics = adamw_update(opt_cfg, grads, opt, params)
        assert bool(jnp.isfinite(metrics["grad_norm"]))
        # params actually changed
        delta = jax.tree.reduce(
            lambda a, leaf: a + float(jnp.sum(jnp.abs(leaf))),
            jax.tree.map(
                lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32)),
                new_params, params,
            ),
            0.0,
        )
        assert delta > 0.0
        # no NaNs introduced
        for leaf in jax.tree.leaves(new_params):
            assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))

    def test_decode_matches_forward(self, arch):
        # capacity_factor high enough that no token is dropped: Switch-style
        # capacity drops differ between batched prefill routing and
        # single-token decode routing by design.
        cfg = dataclasses.replace(
            smoke_config(arch), compute_dtype="float32",
            moe_capacity_factor=16.0,
        )
        model = Model(cfg)
        params = model.init_params(RNG)
        bsz, s = 2, 12
        toks = jax.random.randint(RNG, (bsz, s + 1), 0, cfg.vocab_size)
        if cfg.family == "encdec":
            frames = jax.random.normal(RNG, (bsz, s, cfg.d_model), jnp.float32)
            enc = ed_mod.encode(cfg, params, frames)
            full = ed_mod.decode_full(cfg, params, toks, enc)[:, -1, :]
            cache = model.init_cache(bsz, 32, enc_len=s)
            _, cache = model.prefill(
                params, {"frames": frames, "tokens": toks[:, :s]}, cache
            )
        else:
            logits, _ = lm_mod.forward(cfg, params, toks)
            full = logits[:, -1, :]
            cache = model.init_cache(bsz, 32)
            _, cache = model.prefill(params, {"tokens": toks[:, :s]}, cache)
        step, _ = model.decode(
            params, cache, toks[:, s], jnp.full((bsz,), s, jnp.int32)
        )
        err = float(jnp.max(jnp.abs(full - step[:, 0, :])))
        scale = float(jnp.max(jnp.abs(full))) + 1e-9
        assert err / scale < 1e-4, (arch, err, scale)

    def test_shape_applicability(self, arch):
        cfg = get_config(arch)
        long_ok = shape_applicable(cfg, SHAPES["long_500k"])
        assert long_ok == (cfg.family in ("ssm", "hybrid"))
        for name in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(cfg, SHAPES[name])


class TestParamAccounting:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_param_breakdown_matches_init(self, arch):
        cfg = smoke_config(arch)
        model = Model(cfg)
        params = model.init_params(RNG)
        actual = sum(leaf.size for leaf in jax.tree.leaves(params))
        expected = cfg.param_count()
        # breakdown is analytic; allow small bookkeeping slack (pos tables,
        # per-layer norm extras) but catch order-of-magnitude errors.
        assert abs(actual - expected) / expected < 0.35, (arch, actual, expected)

    def test_full_config_param_counts(self):
        # Billions-scale sanity vs the assignment's named sizes.
        expect = {
            "qwen1_5_0_5b": 0.46, "nemotron_4_15b": 15.6, "qwen3_14b": 14.8,
            "smollm_135m": 0.135, "chameleon_34b": 34.3,
            "jamba_1_5_large_398b": 398.0, "whisper_small": 0.29,
            "grok_1_314b": 316.0, "phi3_5_moe_42b": 41.9, "mamba2_2_7b": 2.7,
        }
        for arch, billions in expect.items():
            n = get_config(arch).param_count() / 1e9
            assert abs(n - billions) / billions < 0.10, (arch, n)


class TestMoEDispatch:
    def test_moe_output_is_gate_weighted_combination(self):
        from repro.models.layers.moe import apply_moe, init_moe

        cfg = smoke_config("phi3_5_moe_42b")
        params = init_moe(cfg, RNG)
        x = jax.random.normal(RNG, (4, 8, cfg.d_model), jnp.float32)
        out, aux = apply_moe(cfg, params, x)
        assert out.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(out)))
        assert float(aux) > 0.0

    def test_moe_capacity_drops_are_bounded(self):
        from repro.models.layers.moe import moe_capacity

        cfg = smoke_config("grok_1_314b")
        c = moe_capacity(cfg, 1024)
        assert c >= 1024 * cfg.moe_top_k // cfg.moe_experts


class TestSSD:
    def test_chunked_matches_quadratic_reference(self):
        import numpy as np

        from repro.kernels.ref import ref_ssd
        from repro.models.layers.ssm import ssd_chunked

        k = jax.random.PRNGKey(3)
        b, s, h, p, g, n = 2, 64, 4, 8, 2, 16
        ks = jax.random.split(k, 5)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)))
        B_ = jax.random.normal(ks[3], (b, s, g, n))
        C_ = jax.random.normal(ks[4], (b, s, g, n))
        y, _ = ssd_chunked(x, dt, a, B_, C_, chunk=16)
        xdt = (x * dt[..., None]).transpose(0, 2, 1, 3)
        da = (dt * a[None, None, :]).transpose(0, 2, 1)
        y_ref = ref_ssd(
            xdt, da, B_.transpose(0, 2, 1, 3), C_.transpose(0, 2, 1, 3)
        ).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_state_carry_across_calls(self):
        from repro.models.layers.ssm import ssd_chunked

        k = jax.random.PRNGKey(4)
        b, s, h, p, n = 1, 32, 2, 4, 8
        ks = jax.random.split(k, 5)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)))
        B_ = jax.random.normal(ks[3], (b, s, 1, n))
        C_ = jax.random.normal(ks[4], (b, s, 1, n))
        y_full, st_full = ssd_chunked(x, dt, a, B_, C_, chunk=8)
        h1 = s // 2
        y1, st1 = ssd_chunked(x[:, :h1], dt[:, :h1], a, B_[:, :h1], C_[:, :h1], 8)
        y2, st2 = ssd_chunked(
            x[:, h1:], dt[:, h1:], a, B_[:, h1:], C_[:, h1:], 8,
            initial_state=st1,
        )
        import numpy as np

        np.testing.assert_allclose(np.asarray(y_full[:, h1:]), np.asarray(y2),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st_full), np.asarray(st2),
                                   rtol=1e-4, atol=1e-4)
