"""Seeded fault-injection property suite (PR 6, `make chaos`).

Every test here drives a deterministic fault schedule from a
:class:`ChaosSpec` seed and checks the robustness invariants after each
step:

* **ledger conservation** — ``admitted == completed + evicted + inflight``
  no matter which faults fired;
* **no placement on a DEAD worker** — failure detection and the epoch
  index never hand out a dead worker;
* **partition containment** — ``topology_tolerance: none`` work never
  escapes its designated zone mid-partition;
* **chaos off is bit-identical** — ``chaos=None`` leaves the simulator's
  placements, traces, and RNG streams unchanged.

Failing seeds are written to ``chaos_failures/`` so CI can upload them
as artifacts (see the ``chaos`` job).
"""
import dataclasses
import json
import random
from pathlib import Path

import pytest

from repro.core.platform import (
    BreakerSpec,
    ChaosSpec,
    ClusterSpec,
    ControllerSpec,
    FaultEvent,
    FaultInjector,
    FederationSpec,
    OverloadSpec,
    QueueSpec,
    RetryPolicy,
    TappFederation,
    WorkerSpec,
)
from repro.core.scheduler.topology import DistributionPolicy
from repro.core.sim.core import NetworkModel
from repro.core.sim.scenarios import (
    OVERLOAD_SCRIPT,
    chaos_benchmark_chaos,
    run_chaos_case,
)

FAILURE_DIR = Path(__file__).resolve().parent.parent / "chaos_failures"

SEEDS = range(6)

POLICY = (
    "- default:\n"
    "  - workers:\n"
    "    - set:\n"
    "    strategy: platform\n"
    "    invalidate: overload\n"
    "- pinned:\n"
    "  - controller: ACtl\n"
    "    workers:\n"
    "    - set: a\n"
    "    topology_tolerance: none\n"
    "  followup: fail\n"
)


def zone_slice(prefix: str, ctl: str) -> ClusterSpec:
    return ClusterSpec(
        controllers=(ControllerSpec(ctl),),
        workers=tuple(
            WorkerSpec(f"{prefix}{i}", sets=(prefix, "any"), capacity_slots=3)
            for i in range(3)
        ),
    )


def chaos_federation(**kwargs) -> TappFederation:
    spec = FederationSpec.of(
        {
            "a": zone_slice("a", "ACtl"),
            "b": zone_slice("b", "BCtl"),
            "c": zone_slice("c", "CCtl"),
        },
        network=NetworkModel(
            rtt={("a", "b"): 0.010, ("a", "c"): 0.030, ("b", "c"): 0.020},
            bandwidth={},
        ),
    )
    return TappFederation(
        spec, distribution=DistributionPolicy.SHARED, seed=0, policy=POLICY,
        **kwargs
    )


def record_failing_seed(seed: int, invariant: str, detail: str) -> None:
    """Persist a failing chaos seed for the CI artifact upload."""
    FAILURE_DIR.mkdir(exist_ok=True)
    path = FAILURE_DIR / f"seed_{seed}.json"
    path.write_text(json.dumps(
        {"seed": seed, "invariant": invariant, "detail": detail}, indent=2,
    ))


def check(condition: bool, *, seed: int, invariant: str, detail: str = ""):
    if not condition:
        record_failing_seed(seed, invariant, detail)
        pytest.fail(f"seed {seed}: {invariant} violated {detail}")


def ledger_ok(stats) -> bool:
    return stats.admitted == stats.completed + stats.evicted + stats.inflight


# ---------------------------------------------------------------------------
# Platform-level chaos stepping: invariants hold after EVERY step
# ---------------------------------------------------------------------------


def drive_schedule(seed: int):
    """Interleave a seeded fault schedule with invokes/completes and
    check every invariant after each step."""
    f = chaos_federation(retry=RetryPolicy(max_attempts=3))
    spec = ChaosSpec(
        seed=seed,
        horizon=30.0,
        worker_crashes=3,
        crash_downtime=6.0,
        degraded_events=2,
        flappy_workers=1,
        flap_period=4.0,
        controller_losses=1,
        controller_downtime=5.0,
        partitions=2,
        partition_duration=8.0,
    )
    injector = FaultInjector(
        spec,
        list(f.cluster.workers),
        [c.name for c in f.cluster.controllers.values()],
        tuple(f.zones),
    )
    schedule = injector.schedule()
    assert schedule, "chaos spec produced an empty schedule"
    workload = random.Random(seed ^ 0x5EED)
    open_placements = []
    steps = iter(schedule)
    pending = next(steps, None)
    for tick in range(120):
        now = tick * 0.25
        while pending is not None and pending.at <= now:
            injector.apply(pending, f, now=pending.at)
            pending = next(steps, None)
        entry = workload.choice(tuple(f.zones))
        tag = "pinned" if workload.random() < 0.3 else None
        pl = f.invoke(f"fn{tick % 4}", tag=tag, entry_zone=entry)
        if pl.scheduled:
            worker = f.cluster.workers[pl.worker]
            check(not worker.dead, seed=seed, invariant="dead-placement",
                  detail=f"t={now} worker={pl.worker}")
            if tag == "pinned":
                check(worker.zone == "a", seed=seed,
                      invariant="tolerance-escape",
                      detail=f"t={now} worker={pl.worker} zone={worker.zone}")
            open_placements.append(pl)
        # Retire a prefix of the open work; some of it died with its
        # worker and must decline gracefully.
        if open_placements and workload.random() < 0.7:
            open_placements.pop(0).complete()
        stats = f.stats().aggregate
        check(ledger_ok(stats), seed=seed, invariant="ledger",
              detail=f"t={now} {stats}")
    for pl in open_placements:
        pl.complete()
    final = f.stats().aggregate
    check(ledger_ok(final), seed=seed, invariant="ledger",
          detail=f"final {final}")
    check(final.inflight == 0, seed=seed, invariant="ledger",
          detail=f"final inflight {final.inflight}")
    return final


class TestChaosSchedules:
    @pytest.mark.parametrize("seed", SEEDS)
    def test_invariants_hold_under_fault_schedule(self, seed):
        final = drive_schedule(seed)
        assert final.admitted > 0

    def test_schedule_is_deterministic_per_seed(self):
        spec = ChaosSpec(seed=7, worker_crashes=3, partitions=1,
                         flappy_workers=2)
        workers = [f"w{i}" for i in range(6)]
        a = FaultInjector(spec, workers, ("C",), ("a", "b")).schedule()
        b = FaultInjector(spec, workers, ("C",), ("a", "b")).schedule()
        assert a == b
        c = FaultInjector(dataclasses.replace(spec, seed=8), workers,
                          ("C",), ("a", "b")).schedule()
        assert a != c

    def test_every_fault_has_matching_recovery_inside_horizon(self):
        spec = ChaosSpec(seed=3, horizon=100.0, worker_crashes=4,
                         crash_downtime=5.0, partitions=2,
                         partition_duration=5.0)
        events = FaultInjector(spec, ["w0", "w1", "w2"], (),
                               ("a", "b", "c")).schedule()
        downs = sum(1 for e in events if e.kind in ("crash", "sever"))
        ups = sum(1 for e in events if e.kind in ("recover", "heal"))
        assert downs == ups == 6
        assert all(e.at <= spec.horizon for e in events)
        assert list(events) == sorted(events, key=lambda e: e.at)

    def test_unknown_target_faults_are_noops(self):
        f = chaos_federation()
        spec = ChaosSpec(seed=0, worker_crashes=1)
        injector = FaultInjector(spec, ["ghost"])
        event = FaultEvent(at=1.0, kind="crash", target="ghost")
        assert injector.apply(event, f, now=1.0) is False
        assert ledger_ok(f.stats().aggregate)

    def test_skipped_events_are_reported_not_silently_ignored(self):
        # Satellite (a): a False apply() return lands in injector.skipped
        # with a reason, so a chaos run whose schedule stopped biting is
        # visible after the fact.
        f = chaos_federation()
        injector = FaultInjector(ChaosSpec(seed=0), ["ghost"])
        events = [
            FaultEvent(at=1.0, kind="crash", target="ghost"),
            FaultEvent(at=2.0, kind="controller_down", target="NoCtl"),
            FaultEvent(at=3.0, kind="overload_burst", target="nowhere",
                       value=2.0),
        ]
        for event in events:
            assert injector.apply(event, f, now=event.at) is False
        assert [e for e, _ in injector.skipped] == events
        reasons = [reason for _, reason in injector.skipped]
        assert "deregistered" in reasons[0]
        assert "NoCtl" in reasons[1]
        assert "nowhere" in reasons[2]
        # Applied events don't pollute the skip log.
        ok = FaultEvent(at=4.0, kind="crash", target="a0")
        assert injector.apply(ok, f, now=4.0) is True
        assert len(injector.skipped) == 3

    def test_fault_event_rejects_unknown_kind(self):
        with pytest.raises(ValueError):
            FaultEvent(at=0.0, kind="meteor", target="w0")
        with pytest.raises(ValueError):
            ChaosSpec(worker_crashes=-1)
        with pytest.raises(ValueError):
            ChaosSpec(overload_bursts=-1)
        with pytest.raises(ValueError):
            ChaosSpec(burst_factor=0.5)

    def test_burst_free_spec_expands_to_the_pr6_schedule(self):
        # Appending the overload_burst draw must not move the RNG stream
        # of burst-free specs: per-seed schedules are pinned.
        spec = ChaosSpec(seed=7, worker_crashes=3, partitions=1,
                         flappy_workers=2)
        workers = [f"w{i}" for i in range(6)]
        base = FaultInjector(spec, workers, ("C",), ("a", "b")).schedule()
        assert not any(e.kind in ("overload_burst", "burst_end")
                       for e in base)
        with_bursts = FaultInjector(
            dataclasses.replace(spec, overload_bursts=2, burst_factor=3.0),
            workers, ("C",), ("a", "b"),
        ).schedule()
        assert [e for e in with_bursts
                if e.kind not in ("overload_burst", "burst_end")] == list(base)
        bursts = [e for e in with_bursts if e.kind == "overload_burst"]
        assert len(bursts) == 2
        assert all(e.target in ("a", "b") and e.value == 3.0
                   for e in bursts)


# ---------------------------------------------------------------------------
# Simulation-level chaos: end-to-end ledger + determinism + bit-compat
# ---------------------------------------------------------------------------


class TestChaosSimulation:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_sim_ledger_conserved_under_crashes(self, seed):
        sim, result = run_chaos_case(
            test="sleep", seed=seed,
            chaos=chaos_benchmark_chaos(seed=seed, crashes=3),
        )
        stats = sim.platform.stats()
        check(ledger_ok(stats), seed=seed, invariant="sim-ledger",
              detail=str(stats))
        check(stats.inflight == 0, seed=seed, invariant="sim-ledger",
              detail=f"inflight {stats.inflight}")
        # Crashed requests either re-routed (retries > 0) or failed with
        # a crash error — never silently vanished. Every extra routing
        # pass is accounted for by the retry counter.
        assert stats.routed == len(result.records) + stats.retries
        for record in result.records:
            assert record.ok or record.error

    def test_sim_chaos_is_deterministic(self):
        _, a = run_chaos_case(
            test="sleep", seed=4, chaos=chaos_benchmark_chaos(seed=4))
        _, b = run_chaos_case(
            test="sleep", seed=4, chaos=chaos_benchmark_chaos(seed=4))
        assert a.records == b.records

    def test_chaos_off_is_bit_identical(self):
        # chaos=None AND a dormant RetryPolicy must not perturb a
        # fault-free run: same placements, same latencies, same RNG
        # draws as a platform with no retry machinery at all.
        _, plain = run_chaos_case(test="hellojs", seed=0, chaos=None,
                                  retry=None)
        _, armed = run_chaos_case(test="hellojs", seed=0, chaos=None,
                                  retry=RetryPolicy(max_attempts=3))
        assert plain.records == armed.records
        assert all(r.retries == 0 and r.retry_wait == 0.0
                   for r in armed.records)

    def test_chaos_run_recovers_all_requests_with_retry(self):
        sim, result = run_chaos_case(
            test="hellojs", seed=0,
            chaos=chaos_benchmark_chaos(seed=0, crashes=2),
        )
        # hellojs is short: crashes mostly land between requests, and
        # the retry policy re-routes whatever they do catch.
        assert result.failure_rate < 0.05
        retried = [r for r in result.records if r.retries]
        for record in retried:
            assert record.ok and record.retry_wait > 0.0

    def test_federated_chaos_conserves_ledger_across_zones(self):
        # Satellite (c): federation-wide conservation summed across
        # ZoneStats under partition + crash churn from multiple zones.
        sim, result = run_chaos_case(
            test="sleep", seed=1, federated=True,
            chaos=chaos_benchmark_chaos(seed=1, crashes=2, partitions=1),
        )
        stats = sim.platform.stats()
        agg = stats.aggregate
        check(ledger_ok(agg), seed=1, invariant="fed-ledger",
              detail=str(agg))
        assert agg.inflight == 0
        assert sum(z.inflight for z in stats.zones) == 0
        assert sum(z.entered for z in stats.zones) >= len(result.records)


# ---------------------------------------------------------------------------
# Overload chaos (PR 9): circuit breakers + overload bursts
# ---------------------------------------------------------------------------


class TestCircuitBreakerChaos:
    def _saturated_two_zone(self, breaker):
        f = chaos_federation(overload=OverloadSpec(breaker=breaker))
        # Saturate zone a (3 workers × 3 slots) so its entries forward,
        # then drain every b/c worker: forwards to b/c keep failing but
        # neither zone is all-DEAD, so forward_targets still offers them.
        live = [f.invoke("fn", entry_zone="a") for _ in range(9)]
        assert all(p.scheduled for p in live)
        for zone in ("b", "c"):
            for i in range(3):
                f.drain(f"{zone}{i}")
        return f

    def test_open_breaker_cuts_forward_attempts_to_probe_rate(self):
        spec = BreakerSpec(failure_threshold=3, probe_interval=5)
        f = self._saturated_two_zone(spec)
        # 3 failed invokes trip both (a→b) and (a→c): each invoke walks
        # both targets and fails both forwards.
        for _ in range(3):
            assert not f.invoke("fn", entry_zone="a").scheduled
        assert f.stats().open_circuits == (("a", "b"), ("a", "c"))
        tripped = f.stats().forward_attempts
        # While open, only every probe_interval-th suppressed attempt
        # pays a forward attempt (the half-open probe); the rest are
        # suppressed before any gateway is consulted.
        for _ in range(10):
            f.invoke("fn", entry_zone="a")
        probes = f.stats().forward_attempts - tripped
        assert probes == 4  # 10 suppressed per link → 2 probes per link
        # A probe failure restarts the cooldown; circuits stay open.
        assert f.stats().open_circuits == (("a", "b"), ("a", "c"))

    def test_successful_probe_closes_the_circuit(self):
        spec = BreakerSpec(failure_threshold=3, probe_interval=4)
        f = self._saturated_two_zone(spec)
        for _ in range(3):
            f.invoke("fn", entry_zone="a")
        assert f.stats().open_circuits
        for i in range(3):
            f.restore(f"b{i}")
        # The next probe (every 4th suppressed attempt) lands in b and
        # closes a→b; placements flow again.
        placed = [f.invoke("fn", entry_zone="a").scheduled
                  for _ in range(8)]
        assert any(placed)
        assert ("a", "b") not in f.stats().open_circuits
        assert ledger_ok(f.stats().aggregate)

    def test_breaker_feeds_on_severed_designated_hops(self):
        # A partition that keeps failing a designated cross-zone hop
        # eventually opens that link's breaker too.
        f = chaos_federation(
            overload=OverloadSpec(
                breaker=BreakerSpec(failure_threshold=2, probe_interval=8)
            )
        )
        f.sever("b", "a")
        for _ in range(2):
            f.invoke("fn", tag="pinned", entry_zone="b")
        assert ("b", "a") in f.stats().open_circuits


class TestOverloadBurstSimulation:
    def test_burst_amplifies_offered_load_deterministically(self):
        chaos = ChaosSpec(seed=2, horizon=60.0, overload_bursts=2,
                          burst_duration=8.0, burst_factor=4.0)
        _, base = run_chaos_case(test="hellojs", seed=1)
        _, a = run_chaos_case(
            test="hellojs", seed=1, chaos=chaos,
            overload=OverloadSpec(queue=QueueSpec(depth=16, deadline=2.0)),
            script=OVERLOAD_SCRIPT,
        )
        _, b = run_chaos_case(
            test="hellojs", seed=1, chaos=chaos,
            overload=OverloadSpec(queue=QueueSpec(depth=16, deadline=2.0)),
            script=OVERLOAD_SCRIPT,
        )
        assert len(a.records) > len(base.records)  # bursts injected load
        assert a.records == b.records

    def test_burst_saturation_queues_and_drains_with_wait_accounting(self):
        chaos = ChaosSpec(seed=2, horizon=60.0, overload_bursts=2,
                          burst_duration=8.0, burst_factor=4.0)
        sim, result = run_chaos_case(
            test="hellojs", seed=1, chaos=chaos,
            overload=OverloadSpec(queue=QueueSpec(depth=16, deadline=2.0)),
            script=OVERLOAD_SCRIPT,
        )
        assert result.n_queued > 0
        waits = result.queue_waits()
        assert waits and all(w > 0.0 for w in waits)
        stats = sim.platform.stats()
        assert ledger_ok(stats)
        assert stats.queued == result.n_queued + result.n_shed
        assert stats.inflight == 0 and stats.queue_depth == 0

    def test_sim_ledger_survives_bursts_plus_crashes(self):
        chaos = ChaosSpec(seed=5, horizon=60.0, worker_crashes=2,
                          crash_downtime=10.0, overload_bursts=1,
                          burst_duration=6.0, burst_factor=3.0)
        sim, result = run_chaos_case(
            test="hellojs", seed=3, chaos=chaos,
            overload=OverloadSpec(queue=QueueSpec(depth=8, deadline=1.5)),
            script=OVERLOAD_SCRIPT,
        )
        stats = sim.platform.stats()
        check(ledger_ok(stats), seed=3, invariant="burst-ledger",
              detail=str(stats))
        for record in result.records:
            assert record.ok or record.error


# ---------------------------------------------------------------------------
# Hypothesis variants (skipped when the plugin is absent)
# ---------------------------------------------------------------------------


class TestChaosHypothesis:
    def test_random_seeds_preserve_invariants(self):
        hypothesis = pytest.importorskip("hypothesis")
        st = pytest.importorskip("hypothesis.strategies")

        @hypothesis.given(seed=st.integers(min_value=0, max_value=2**16))
        @hypothesis.settings(max_examples=20, deadline=None)
        def run(seed):
            drive_schedule(seed)

        run()

    def test_random_specs_produce_valid_schedules(self):
        hypothesis = pytest.importorskip("hypothesis")
        st = pytest.importorskip("hypothesis.strategies")

        @hypothesis.given(
            seed=st.integers(min_value=0, max_value=2**16),
            crashes=st.integers(min_value=0, max_value=8),
            partitions=st.integers(min_value=0, max_value=4),
        )
        @hypothesis.settings(max_examples=30, deadline=None)
        def run(seed, crashes, partitions):
            spec = ChaosSpec(seed=seed, worker_crashes=crashes,
                             partitions=partitions)
            events = FaultInjector(
                spec, [f"w{i}" for i in range(4)], ("C",), ("a", "b"),
            ).schedule()
            assert list(events) == sorted(events, key=lambda e: e.at)
            assert all(0.0 <= e.at <= spec.horizon for e in events)

        run()
