"""tAPP language: parser, serializer, validator."""
import pytest

from repro.core.tapp import (
    Affinity,
    AntiAffinity,
    CapacityUsed,
    FollowupKind,
    MaxConcurrentInvocations,
    Overload,
    Strategy,
    TappParseError,
    TopologyTolerance,
    WorkerSet,
    invalidate_from_text,
    parse_tapp,
    script_to_yaml,
    validate_script,
)

AFFINITY_SCRIPT = """
- latency:
  - workers:
    - set: edge
      affinity: [cache_warmer]
    - set: cloud
      anti-affinity: noisy, batch
    anti-affinity: [batch]
  followup: default
- spread:
  - workers:
    - wrk: w0
      anti-affinity: [spread_fn]
    - wrk: w1
    affinity: [svc]
  followup: fail
"""

FIG5 = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- couchdb_query:
  - workers:
    - wrk: DB_worker1
    - wrk: DB_worker2
    strategy: random
    invalidate: capacity_used 50%
  - workers:
    - wrk: near_DB_worker1
    - wrk: near_DB_worker2
    strategy: best_first
    invalidate: max_concurrent_invocations 100
  followup: fail
"""

FIG6 = """
- critical:
  - controller: LocalCtl_1
    workers:
    - set: edge
    strategy: random
  followup: fail
- machine_learning:
  - controller: CloudCtl
    workers:
    - set: cloud
    topology_tolerance: same
  followup: default
- default:
  - controller: LocalCtl_1
    workers:
    - set: internal
      strategy: random
    - set: cloud
      strategy: random
    strategy: best_first
  - controller: LocalCtl_2
    workers:
    - set: internal
      strategy: random
    - set: cloud
      strategy: random
    strategy: best_first
  strategy: random
"""


class TestParse:
    def test_fig5(self):
        script = parse_tapp(FIG5)
        assert script.tag_names() == ["default", "couchdb_query"]
        cq = script.get("couchdb_query")
        assert len(cq.blocks) == 2
        b0, b1 = cq.blocks
        assert [w.label for w in b0.workers] == ["DB_worker1", "DB_worker2"]
        assert b0.strategy is Strategy.RANDOM
        assert b0.invalidate == CapacityUsed(50.0)
        assert b1.strategy is Strategy.BEST_FIRST
        assert b1.invalidate == MaxConcurrentInvocations(100)
        assert cq.effective_followup is FollowupKind.FAIL

    def test_fig6(self):
        script = parse_tapp(FIG6)
        crit = script.get("critical")
        assert crit.blocks[0].controller.label == "LocalCtl_1"
        assert crit.blocks[0].controller.topology_tolerance is TopologyTolerance.ALL
        ml = script.get("machine_learning")
        assert ml.blocks[0].controller.topology_tolerance is TopologyTolerance.SAME
        assert ml.effective_followup is FollowupKind.DEFAULT
        default = script.default
        assert default.effective_strategy is Strategy.RANDOM
        # default tag followup pinned to fail
        assert default.effective_followup is FollowupKind.FAIL
        # two blocks, each with two sets carrying inner strategies
        sets = default.blocks[0].workers
        assert all(isinstance(w, WorkerSet) for w in sets)
        assert sets[0].strategy is Strategy.RANDOM

    def test_blank_set_matches_all(self):
        script = parse_tapp("- t:\n  - workers:\n    - set:\n")
        ws = script.get("t").blocks[0].workers[0]
        assert isinstance(ws, WorkerSet) and ws.label is None

    def test_best_first_spelling_variant(self):
        # The paper's Fig. 8 writes 'best-first'.
        script = parse_tapp(
            "- t:\n  - workers:\n    - wrk: a\n    strategy: best-first\n"
        )
        assert script.get("t").blocks[0].strategy is Strategy.BEST_FIRST

    def test_affinity_clauses(self):
        script = parse_tapp(AFFINITY_SCRIPT)
        latency = script.get("latency").blocks[0]
        assert latency.anti_affinity == AntiAffinity(("batch",))
        edge, cloud = latency.workers
        assert edge.affinity == Affinity(("cache_warmer",))
        assert edge.anti_affinity is None
        # Comma-string form parses like the list form.
        assert cloud.anti_affinity == AntiAffinity(("noisy", "batch"))
        spread = script.get("spread").blocks[0]
        assert spread.affinity == Affinity(("svc",))
        assert spread.workers[0].anti_affinity == AntiAffinity(("spread_fn",))
        assert spread.workers[1].anti_affinity is None

    def test_default_effective_defaults(self):
        script = parse_tapp("- t:\n  - workers:\n    - wrk: a\n")
        tag = script.get("t")
        assert tag.effective_strategy is Strategy.BEST_FIRST
        assert tag.effective_followup is FollowupKind.DEFAULT


class TestParseErrors:
    @pytest.mark.parametrize(
        "text",
        [
            "- t:\n  - workers:\n    - wrk: a\n    strategy: bogus\n",
            "- t:\n  - workers:\n    - wrk: a\n    invalidate: sometimes\n",
            "- t:\n  - workers:\n    - wrk: a\n  followup: retry\n",
            "- t:\n  - strategy: random\n",                      # no workers key
            "- t: []\n",                                          # no blocks
            "- t:\n  - workers:\n    - wrk: a\n    - set: b\n",   # mixed wrk/set
            "- t:\n  - workers:\n    - set: x\n    topology_tolerance: same\n",
            "- t:\n  - workers:\n    - wrk: a\n- t:\n  - workers:\n    - wrk: b\n",
            "not a list",
            "- t:\n  - workers:\n    - wrk: a\n    affinity: []\n",
            "- t:\n  - workers:\n    - wrk: a\n    affinity: 7\n",
            "- t:\n  - workers:\n    - wrk: a\n      anti-affinity: [x, x]\n",
            "- t:\n  - workers:\n    - wrk: a\n    anti-affinity: 'a,,b'\n",
        ],
    )
    def test_rejects(self, text):
        with pytest.raises(TappParseError):
            parse_tapp(text)

    def test_capacity_bounds(self):
        with pytest.raises(ValueError):
            invalidate_from_text("capacity_used 150%")
        with pytest.raises(ValueError):
            invalidate_from_text("max_concurrent_invocations 0")
        assert invalidate_from_text("overload") == Overload()


class TestRoundTrip:
    @pytest.mark.parametrize("text", [FIG5, FIG6, AFFINITY_SCRIPT])
    def test_serialize_parse_identity(self, text):
        script = parse_tapp(text)
        again = parse_tapp(script_to_yaml(script))
        assert again.tags == script.tags


class TestValidate:
    def test_default_followup_default_is_error(self):
        script = parse_tapp(
            "- default:\n  - workers:\n    - set:\n  followup: default\n"
        )
        report = validate_script(script)
        assert not report.ok

    def test_missing_default_warns(self):
        script = parse_tapp("- t:\n  - workers:\n    - wrk: a\n")
        report = validate_script(script)
        assert report.ok
        assert any("no default" in w.message for w in report.warnings)

    def test_contradictory_affinity_warns(self):
        script = parse_tapp(
            "- t:\n  - workers:\n    - set:\n      affinity: [x, y]\n"
            "    anti-affinity: [y]\n  followup: fail\n"
        )
        report = validate_script(script)
        assert report.ok  # warning, not error
        assert any("unsatisfiable" in w.message for w in report.warnings)

    def test_item_override_clears_conflict(self):
        # Item-level anti-affinity overrides the block's conflicting one.
        script = parse_tapp(
            "- t:\n  - workers:\n    - set:\n      affinity: [x]\n"
            "      anti-affinity: [z]\n    anti-affinity: [x]\n"
            "  followup: fail\n"
        )
        report = validate_script(script)
        assert not any("unsatisfiable" in w.message for w in report.warnings)

    def test_topology_warnings(self):
        script = parse_tapp(FIG6)
        report = validate_script(
            script,
            known_controllers=["LocalCtl_1"],
            known_worker_labels=[],
            known_set_labels=["edge"],
        )
        assert report.ok  # warnings only
        msgs = " ".join(w.message for w in report.warnings)
        assert "CloudCtl" in msgs and "cloud" in msgs
