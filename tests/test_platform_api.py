"""Platform API v1: façade construction, policy lifecycle, unified
invoke→admit→complete flow, typed explain, stats equivalence, and the
curated scheduler surface."""
import warnings

import pytest

from repro.core.platform import (
    ClusterSpec,
    ControllerSpec,
    PolicyError,
    TappPlatform,
    WorkerSpec,
)
from repro.core.scheduler import Gateway, Invocation, Watcher, make_cluster
from repro.core.scheduler.topology import DistributionPolicy

SPEC = ClusterSpec(
    controllers=(
        ControllerSpec("EdgeCtl", zone="edge"),
        ControllerSpec("CloudCtl", zone="cloud"),
    ),
    workers=(
        WorkerSpec("e0", zone="edge", sets=("edge", "any"), capacity_slots=2),
        WorkerSpec("e1", zone="edge", sets=("edge", "any"), capacity_slots=2),
        WorkerSpec("c0", zone="cloud", sets=("cloud", "any"), capacity_slots=4),
    ),
)

SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- edge_only:
  - controller: EdgeCtl
    workers:
    - set: edge
    topology_tolerance: none
  followup: fail
"""

CLOUD_SCRIPT = """
- default:
  - controller: CloudCtl
    workers:
    - set: cloud
    topology_tolerance: all
"""


def platform(**kwargs) -> TappPlatform:
    return TappPlatform(
        SPEC, distribution=DistributionPolicy.SHARED, seed=0, **kwargs
    )


# ---------------------------------------------------------------------------
# Declarative construction + topology lifecycle
# ---------------------------------------------------------------------------


class TestClusterSpec:
    def test_build_materialises_workers_and_controllers(self):
        p = platform()
        assert set(p.cluster.workers) == {"e0", "e1", "c0"}
        assert set(p.cluster.controllers) == {"EdgeCtl", "CloudCtl"}
        assert p.cluster.workers["e0"].sets == frozenset({"edge", "any"})

    def test_of_coerces_dicts(self):
        spec = ClusterSpec.of(
            workers=[dict(name="w0", zone="z", sets=["a"])],
            controllers=[dict(name="C", zone="z")],
        )
        assert spec.workers[0].sets == ("a",)
        assert spec.build().workers["w0"].zone == "z"

    def test_shuffled_permutes_registration_order(self):
        orders = {
            tuple(w.name for w in SPEC.shuffled(seed).workers)
            for seed in range(8)
        }
        assert len(orders) > 1
        assert all(sorted(o) == ["c0", "e0", "e1"] for o in orders)

    def test_duplicate_worker_rejected_at_build(self):
        spec = ClusterSpec(workers=(WorkerSpec("w"), WorkerSpec("w")))
        with pytest.raises(ValueError, match="duplicate"):
            spec.build()

    def test_restore_notifies_like_drain(self):
        p = platform()
        events = []
        p.subscribe(events.append)
        p.drain("e0")
        p.restore("e0")
        assert events.count("topology") == 2

    def test_lifecycle_routes_through_watcher_epoch(self):
        p = platform()
        epoch = p.cluster.topology_epoch
        p.add_worker(WorkerSpec("e2", zone="edge", sets=("edge", "any")))
        assert p.cluster.topology_epoch == epoch + 1
        p.drain("e2")
        assert p.cluster.topology_epoch == epoch + 2
        assert not p.cluster.workers["e2"].healthy
        p.restore("e2")
        assert p.cluster.workers["e2"].healthy
        p.remove_worker("e2")
        assert "e2" not in p.cluster.workers

    def test_drained_worker_not_scheduled(self):
        p = platform(policy=SCRIPT)
        p.drain("e0")
        p.drain("e1")
        placement = p.invoke("f", tag="edge_only")
        assert not placement.scheduled and placement.failed_by_policy

    def test_drain_blocks_every_invalidate_kind(self):
        # capacity_used / max_concurrent clauses never consult health, so
        # drain must act through the preliminary (reachability) condition.
        script = (
            "- cap:\n  - workers:\n    - set: edge\n"
            "    invalidate: capacity_used 95%\n  followup: fail\n"
            "- conc:\n  - workers:\n    - set: edge\n"
            "    invalidate: max_concurrent_invocations 99\n  followup: fail\n"
        )
        p = platform(policy=script)
        ticket = p.invoke("f", tag="cap")
        assert ticket.scheduled  # sanity: schedulable before the drain
        p.drain("e0")
        p.drain("e1")
        for tag in ("cap", "conc"):
            placement = p.invoke("f", tag=tag)
            assert not placement.scheduled, tag
            assert not placement.admitted, tag
        ticket.complete()  # running work still retires after the drain
        assert p.stats().completed == 1


# ---------------------------------------------------------------------------
# Policy lifecycle: apply / dry-run / rollback
# ---------------------------------------------------------------------------


class TestPolicyLifecycle:
    def test_apply_returns_versioned_handle(self):
        p = platform()
        h1 = p.apply_policy(SCRIPT)
        h2 = p.apply_policy(CLOUD_SCRIPT)
        assert h2.version > h1.version
        assert p.policy is h2
        assert p.policy_history == (h1,)

    def test_strict_rejects_unknown_set_and_controller(self):
        p = platform()
        bad_set = "- t:\n  - workers:\n    - set: ghost_set\n  followup: fail\n"
        bad_ctl = (
            "- t:\n  - controller: GhostCtl\n    workers:\n    - set:\n"
            "  followup: fail\n"
        )
        for script in (bad_set, bad_ctl):
            with pytest.raises(PolicyError):
                p.apply_policy(script, strict=True)
            assert p.policy is None  # nothing swapped
            assert p.watcher.script is None
        # Lenient mode accepts, with the findings on the handle.
        handle = p.apply_policy(bad_set, strict=False)
        assert handle.dry_run.topology_findings

    def test_strict_rejects_contradictory_affinity(self):
        p = platform()
        script = (
            "- t:\n  - workers:\n    - set:\n"
            "    affinity: [fn_x]\n    anti-affinity: [fn_x]\n  followup: fail\n"
        )
        with pytest.raises(PolicyError, match="dry-run"):
            p.apply_policy(script, strict=True)
        assert p.apply_policy(script, strict=False).dry_run.constraint_findings

    def test_dry_run_does_not_swap(self):
        p = platform(policy=SCRIPT)
        version = p.policy.version
        report = p.dry_run_policy(CLOUD_SCRIPT)
        assert report.ok and report.ok_strict()
        assert p.policy.version == version
        assert "edge" in report.known_sets and "cloud" in report.known_zones

    def test_failing_compile_is_all_or_nothing(self, monkeypatch):
        import repro.core.platform.facade as facade

        p = platform(policy=SCRIPT)
        before = (p.policy, p.watcher.script, tuple(p.policy_history))

        def boom(script):
            raise RuntimeError("lowering exploded")

        monkeypatch.setattr(facade, "compile_script", boom)
        with pytest.raises(RuntimeError, match="lowering exploded"):
            p.apply_policy(CLOUD_SCRIPT)
        assert (p.policy, p.watcher.script, tuple(p.policy_history)) == before
        # The previous policy still schedules.
        monkeypatch.undo()
        assert p.invoke("f", tag="edge_only").scheduled

    def test_parse_error_is_all_or_nothing(self):
        p = platform(policy=SCRIPT)
        before = p.policy
        with pytest.raises(Exception):
            p.apply_policy("workers: [not tapp")
        assert p.policy is before

    @pytest.mark.parametrize("compiled", [True, False])
    def test_rollback_restores_bit_identical_decisions(self, compiled):
        probes = [
            Invocation("f", tag="edge_only"),
            Invocation("g", tag="edge_only"),
            Invocation("h"),  # untagged → default tag (round-robin block)
        ]

        def decisions(p):
            # explain() probes without admitting, so cluster state is
            # untouched between policy generations.
            return [
                (r.scheduled, r.worker, r.controller, r.tag,
                 r.zone_restriction, [e for e in r.trace])
                for r in (p.explain(i) for i in probes)
            ]

        p = TappPlatform(
            SPEC, distribution=DistributionPolicy.SHARED, seed=0,
            compiled=compiled, policy=SCRIPT,
        )
        original = decisions(p)
        p.apply_policy(CLOUD_SCRIPT)
        flipped = decisions(p)
        assert flipped != original  # the new policy really changed routing
        restored_handle = p.rollback()
        assert restored_handle is p.policy
        assert decisions(p) == original

    def test_rollback_to_no_policy_restores_vanilla(self):
        p = platform()
        p.apply_policy(SCRIPT)
        assert p.rollback() is None
        assert p.watcher.script is None
        placement = p.invoke("f")
        assert placement.scheduled  # vanilla fallback
        assert p.stats().vanilla_routed == 1

    def test_rollback_without_history_raises(self):
        with pytest.raises(PolicyError, match="history"):
            platform().rollback()

    def test_clear_policy_is_rollbackable(self):
        p = platform(policy=SCRIPT)
        p.clear_policy()
        assert p.policy is None and p.watcher.script is None
        restored = p.rollback()
        assert restored is not None
        assert p.watcher.script is not None

    def test_history_is_bounded(self):
        p = TappPlatform(SPEC, max_policy_history=2)
        handles = [p.apply_policy(SCRIPT) for _ in range(5)]
        assert p.policy_history == tuple(handles[2:4])

    def test_apply_policy_primes_compiled_plan(self):
        # The gate's lowering check doubles as the engine's plan: the
        # first decision after the swap must not recompile.
        p = platform()
        handle = p.apply_policy(SCRIPT)
        assert p.gateway._engine._plan_source is handle.script

    def test_policy_events_emitted(self):
        events = []
        p = platform()
        p.subscribe(events.append)
        p.apply_policy(SCRIPT)
        p.apply_policy(CLOUD_SCRIPT)
        p.rollback()
        assert events.count("policy") == 2
        assert events.count("rollback") == 1
        assert "script" in events  # watcher events forwarded


# ---------------------------------------------------------------------------
# Unified invocation flow
# ---------------------------------------------------------------------------


class TestInvokeFlow:
    def test_invoke_admits_and_complete_retires(self):
        p = platform(policy=SCRIPT)
        placement = p.invoke("fn_a", tag="edge_only")
        assert placement.scheduled and placement.admitted
        worker = p.cluster.workers[placement.worker]
        assert worker.inflight == 1
        assert worker.running_functions == {"fn_a": 1}
        placement.complete()
        assert worker.inflight == 0
        assert worker.running_functions == {}
        placement.complete()  # idempotent
        assert worker.inflight == 0
        stats = p.stats()
        assert stats.admitted == 1 and stats.completed == 1

    def test_unscheduled_placement_not_admitted(self):
        p = platform(policy=SCRIPT)
        p.mark_unreachable("e0")
        p.mark_unreachable("e1")
        placement = p.invoke("fn", tag="edge_only")
        assert not placement.scheduled and not placement.admitted
        assert placement.failed_by_policy
        placement.complete()  # no-op
        assert p.stats().admitted == 0

    def test_slow_completion_flags_capacity(self):
        p = platform(policy=SCRIPT)
        placement = p.invoke("fn", tag="edge_only")
        placement.complete(slow=True)
        assert p.cluster.workers[placement.worker].capacity_used_pct == 100.0

    def test_invoke_batch_matches_sequential_invokes(self):
        spread = """
- spread:
  - workers:
    - set:
    strategy: best_first
    invalidate: overload
    anti-affinity: [fn_s]
  - workers:
    - set:
  followup: fail
"""
        invs = [Invocation("fn_s", tag="spread", request_id=i)
                for i in range(5)]

        seq = platform(policy=spread)
        sequential = [seq.invoke(i) for i in invs]

        bat = platform(policy=spread)
        batched = bat.invoke_batch(invs)

        assert [(pl.worker, pl.controller, pl.scheduled) for pl in batched] \
            == [(pl.worker, pl.controller, pl.scheduled) for pl in sequential]
        for name in seq.cluster.workers:
            ws = seq.cluster.workers[name]
            wb = bat.cluster.workers[name]
            assert (ws.inflight, ws.running_functions) == (
                wb.inflight, wb.running_functions
            ), name
        # Anti-affinity saw same-batch placements: first three spread out.
        assert len({pl.worker for pl in batched[:3]}) == 3

    def test_invoke_batch_on_placement_fires_in_order(self):
        p = platform(policy=SCRIPT)
        seen = []
        placements = p.invoke_batch(
            [Invocation(f"f{i}") for i in range(4)],
            on_placement=lambda pl: seen.append(pl),
        )
        assert seen == placements

    def test_stats_snapshot_fields(self):
        p = platform(policy=SCRIPT)
        pls = [p.invoke(f"f{i}") for i in range(3)]
        pls[0].complete()
        stats = p.stats()
        assert stats.routed == 3 and stats.tapp_routed == 3
        assert stats.admitted == 3 and stats.completed == 1
        assert stats.inflight == 2
        assert stats.workers == 3 and stats.controllers == 2
        assert stats.policy_version == p.policy.version


# ---------------------------------------------------------------------------
# Typed explain reports
# ---------------------------------------------------------------------------


class TestExplain:
    def test_explain_reports_rejections_and_placement(self):
        p = platform(policy=SCRIPT)
        p.heartbeat("e0", healthy=False)
        report = p.explain("fn", tag="edge_only")
        assert report.scheduled and report.worker == "e1"
        assert report.tag == "edge_only"
        assert report.rejections()["e0"] == "unhealthy"
        candidates = {
            c.worker: c.valid for b in report.blocks for c in b.candidates
        }
        assert candidates == {"e0": False, "e1": True}
        assert "e1" in report.render()

    def test_explain_does_not_admit_or_count(self):
        p = platform(policy=SCRIPT)
        p.explain("fn", tag="edge_only")
        stats = p.stats()
        assert stats.routed == 0 and stats.admitted == 0
        assert stats.script_reloads == 0  # probes bypass the reload cache
        assert all(w.inflight == 0 for w in p.cluster.workers.values())

    def test_explain_empty_cluster_has_no_pseudo_workers(self):
        p = TappPlatform(ClusterSpec(
            controllers=(ControllerSpec("C", zone="z"),)
        ))
        report = p.explain("fn")  # vanilla path emits "no workers"
        assert not report.scheduled
        assert report.rejections() == {}
        assert any("no workers" in n
                   for b in report.blocks for n in b.controller_notes)

    def test_explain_failure_names_every_block(self):
        p = platform(policy=SCRIPT)
        for w in ("e0", "e1", "c0"):
            p.mark_unreachable(w)
        report = p.explain("fn", tag="edge_only")
        assert not report.scheduled and report.failed_by_policy
        assert set(report.rejections()) == {"e0", "e1"}
        assert all(r == "unreachable" for r in report.rejections().values())
        assert any("exhausted" in n for n in report.notes)

    def test_explain_vanilla_fallback(self):
        p = platform()  # no policy
        report = p.explain("fn")
        assert report.scheduled
        assert report.blocks  # vanilla candidates still reported

    @pytest.mark.parametrize("script", [
        None,  # vanilla fallback (round-robin cursor)
        "- t:\n  - workers:\n    - set:\n    strategy: random\n"
        "  followup: fail\n",  # RNG stream + round-robin cursor
    ], ids=["vanilla", "random-strategy"])
    def test_explain_is_side_effect_free(self, script):
        tag = None if script is None else "t"

        def build():
            p = TappPlatform(
                SPEC, distribution=DistributionPolicy.SHARED, seed=7
            )
            if script is not None:
                p.apply_policy(script)
            return p

        undisturbed, probed = build(), build()
        reference = [undisturbed.invoke("f", tag=tag).worker
                     for _ in range(4)]
        seen = []
        for _ in range(4):
            probed.explain("f", tag=tag)  # must not perturb the stream
            seen.append(probed.invoke("f", tag=tag).worker)
        assert seen == reference


# ---------------------------------------------------------------------------
# Satellite: Gateway.route_batch stats equivalence
# ---------------------------------------------------------------------------


class TestGatewayBatchStats:
    def _watcher(self, script):
        watcher = Watcher(
            make_cluster(
                workers=[
                    dict(name="e0", zone="edge", sets=["edge", "any"],
                         capacity_slots=2),
                    dict(name="c0", zone="cloud", sets=["cloud", "any"],
                         capacity_slots=1),
                ],
                controllers=[dict(name="EdgeCtl", zone="edge"),
                             dict(name="CloudCtl", zone="cloud")],
            )
        )
        if script is not None:
            watcher.load_script(script)
        return watcher

    @pytest.mark.parametrize("script", [None, SCRIPT],
                             ids=["vanilla", "tapp"])
    def test_route_batch_stats_equal_sequential(self, script):
        # Mix of schedulable, vanilla, and policy-failing invocations; the
        # edge_only ones fail once the edge worker saturates (slots=2).
        invs = [Invocation("fn", tag="edge_only") for _ in range(4)]
        invs += [Invocation("fn") for _ in range(3)]

        g_seq = Gateway(self._watcher(script),
                        distribution=DistributionPolicy.SHARED, seed=1)
        rt_seq = g_seq._watcher  # admissions via watcher ledger
        for inv in invs:
            d = g_seq.route(inv)
            if d.scheduled:
                rt_seq.record_admission(d.worker, d.controller or "?",
                                        inv.function)

        g_bat = Gateway(self._watcher(script),
                        distribution=DistributionPolicy.SHARED, seed=1)
        rt_bat = g_bat._watcher

        def admit(inv, d):
            if d.scheduled:
                rt_bat.record_admission(d.worker, d.controller or "?",
                                        inv.function)

        g_bat.route_batch(invs, on_decision=admit)

        for field in ("routed", "tapp_routed", "vanilla_routed", "failed",
                      "script_reloads"):
            assert getattr(g_bat.stats, field) == getattr(g_seq.stats, field), field
        assert g_seq.stats.routed == len(invs)
        if script is None:
            assert g_seq.stats.vanilla_routed == len(invs)
            assert g_seq.stats.tapp_routed == 0
        else:
            assert g_seq.stats.tapp_routed == len(invs)
            assert g_seq.stats.failed > 0  # saturation made edge_only fail


# ---------------------------------------------------------------------------
# Satellite: curated scheduler surface + deprecated shims
# ---------------------------------------------------------------------------


class TestCuratedSurface:
    def test_curated_all_imports_cleanly(self):
        import repro.core.scheduler as sched

        with warnings.catch_warnings():
            warnings.simplefilter("error")  # any DeprecationWarning fails
            for name in sched.__all__:
                assert getattr(sched, name) is not None, name

    def test_legacy_names_not_in_all(self):
        import repro.core.scheduler as sched

        for name in ("is_invalid", "invalid_reason", "resolve_invalidate"):
            assert name not in sched.__all__

    def test_legacy_shims_warn_and_still_work(self):
        import repro.core.scheduler as sched
        from repro.core.scheduler.state import WorkerState
        from repro.core.tapp import Overload

        with pytest.warns(DeprecationWarning, match="is_invalid"):
            is_invalid = sched.is_invalid
        with pytest.warns(DeprecationWarning, match="invalid_reason"):
            invalid_reason = sched.invalid_reason
        with pytest.warns(DeprecationWarning, match="resolve_invalidate"):
            resolve_invalidate = sched.resolve_invalidate

        w = WorkerState(name="w", reachable=False)
        assert is_invalid(w, Overload())
        assert invalid_reason(w, Overload()) == "unreachable"
        assert resolve_invalidate(None, None) == Overload()

    def test_unknown_attribute_raises(self):
        import repro.core.scheduler as sched

        with pytest.raises(AttributeError):
            sched.definitely_not_a_name

    def test_legacy_sim_signature_warns_and_works(self):
        from repro.core.sim.core import (
            NetworkModel,
            SimConfig,
            Simulation,
            vanilla_scheduler,
        )

        watcher = Watcher(SPEC.build())
        with pytest.warns(DeprecationWarning):
            sched = vanilla_scheduler()
            sim = Simulation(
                watcher, sched, NetworkModel(rtt={}, bandwidth={}),
                {}, SimConfig(), is_tapp=False,
            )
        assert sim.platform.watcher is watcher

    def test_sim_rejects_positional_arity_mistakes(self):
        from repro.core.sim.core import NetworkModel, SimConfig, Simulation

        p = platform()
        network = NetworkModel(rtt={}, bandwidth={})
        with pytest.raises(TypeError, match="at most"):
            # old positional is_tapp slot must not be silently dropped
            Simulation(p, network, {}, SimConfig(), False)
        with pytest.raises(TypeError, match="scheduler"):
            Simulation(p, lambda inv, cluster: None, network, {})


# ---------------------------------------------------------------------------
# Index-layer wiring: prewarm + ledger event counter
# ---------------------------------------------------------------------------


class TestIndexWiring:
    def test_prewarm_builds_block_indexes(self):
        p = platform(policy=SCRIPT)
        warmed = p.prewarm()
        # 2 controllers x (1 default block + 1 edge_only block).
        assert warmed == 4
        # The epoch-cached entries now hold the block indexes.
        total = sum(
            len(entry._block_indexes) for entry in p.cluster.view_cache.values()
        )
        assert total == 4
        # Prewarmed decisions match a cold platform's decisions.
        cold = platform(policy=SCRIPT)
        for i in range(6):
            assert (
                p.invoke(f"fn{i}").worker == cold.invoke(f"fn{i}").worker
            )

    def test_prewarm_noop_without_policy_or_compiled(self):
        assert platform().prewarm() == 0
        assert platform(policy=SCRIPT, compiled=False).prewarm() == 0

    def test_stats_count_load_events(self):
        p = platform(policy=SCRIPT)
        assert p.stats().load_events == 0
        placement = p.invoke("fn")
        assert p.stats().load_events == 1  # the admission
        placement.complete()
        assert p.stats().load_events == 2  # the completion
        p.heartbeat("e0", capacity_used_pct=12.5)
        assert p.stats().load_events == 3  # volatile heartbeat
        p.heartbeat("e0", healthy=True)  # structural no-op: not an event
        assert p.stats().load_events == 3
