"""Warm-pool instance lifecycle (PR 10).

Covers the PR-10 contracts:
* ``LifecycleSpec`` validation and the off-by-default arming discipline;
* cold→warm→idle→term mechanics fed by the admission ledger: first use
  is cold, completion parks the instance warm, reuse is MRU and O(1);
* the deterministic expiration janitor: keep-alive resolution (worker >
  controller > spec), ``max_idle`` caps, clockless completions never
  expiring, and expiry under drain/deregister churn never stranding a
  ledger ticket (``admitted == completed + evicted + inflight``);
* ``warm-first`` routing: warm workers are tried before cold ones, the
  unarmed path is bit-identical to a lifecycle-free platform, and
  ``explain`` annotates per-candidate warmth;
* the ``FunctionProfile.warm_ttl`` deprecation shim: old scenarios keep
  their sim-local TTL semantics bit-for-bit (with a warning), armed
  platforms ignore ``warm_ttl`` entirely;
* validator findings: tag-level ``warm-first`` is a structural error,
  block-level ``warm-first`` shadowed by explicit inner strategies lints.
"""
import random
import warnings

import pytest

from repro.core.platform import (
    ClusterSpec,
    ControllerSpec,
    LifecycleSpec,
    TappPlatform,
    WorkerSpec,
)
from repro.core.sim import (
    FunctionProfile,
    NetworkModel,
    SimConfig,
    Simulation,
    WorkloadSpec,
)
from repro.core.tapp import parse_tapp, validate_script


WARM_FIRST_SCRIPT = """
- default:
  - workers:
    - set:
      strategy: warm-first
"""


def _spec(n_workers=3, slots=2, worker_keep_alive=None,
          controller_keep_alive=None):
    return ClusterSpec(
        controllers=(
            ControllerSpec("C1", keep_alive=controller_keep_alive),
        ),
        workers=tuple(
            WorkerSpec(
                f"w{i}", sets=("pool", "any"), capacity_slots=slots,
                keep_alive=worker_keep_alive,
            )
            for i in range(n_workers)
        ),
    )


def _platform(lifecycle=LifecycleSpec(), *, policy=WARM_FIRST_SCRIPT,
              seed=0, **spec_kwargs):
    return TappPlatform(
        _spec(**spec_kwargs), seed=seed, policy=policy, lifecycle=lifecycle,
    )


class TestLifecycleSpec:
    def test_defaults(self):
        spec = LifecycleSpec()
        assert spec.keep_alive == 600.0
        assert spec.max_idle is None

    @pytest.mark.parametrize("keep_alive", [0.0, -1.0])
    def test_non_positive_keep_alive_rejected(self, keep_alive):
        with pytest.raises(ValueError, match="keep_alive"):
            LifecycleSpec(keep_alive=keep_alive)

    def test_negative_max_idle_rejected(self):
        with pytest.raises(ValueError, match="max_idle"):
            LifecycleSpec(max_idle=-1)

    @pytest.mark.parametrize("keep_alive", [0.0, -2.0])
    def test_worker_keep_alive_validated(self, keep_alive):
        with pytest.raises(ValueError, match="keep_alive"):
            WorkerSpec("w0", keep_alive=keep_alive)

    @pytest.mark.parametrize("keep_alive", [0.0, -2.0])
    def test_controller_keep_alive_validated(self, keep_alive):
        with pytest.raises(ValueError, match="keep_alive"):
            ControllerSpec("C", keep_alive=keep_alive)

    def test_unarmed_platform_has_no_lifecycle(self):
        p = TappPlatform(_spec(), policy=WARM_FIRST_SCRIPT)
        assert p.lifecycle_spec is None
        assert p.lifecycle is None
        assert p.expire_instances(1e9) == 0
        snap = p.lifecycle_snapshot()
        assert set(snap.values()) == {0}


class TestWarmPoolMechanics:
    def test_cold_then_warm_reuse(self):
        p = _platform()
        p1 = p.invoke("fn", now=0.0)
        assert p1.scheduled and p1.warm_hit is False
        assert p.stats().cold_starts == 1
        p1.complete(now=1.0)
        snap = p.lifecycle_snapshot()
        assert snap["idle_instances"] == 1 and snap["busy_instances"] == 0
        p2 = p.invoke("fn", now=2.0)
        assert p2.warm_hit is True
        assert p2.decision.worker == p1.decision.worker
        assert p.stats().warm_hits == 1
        assert p.stats().cold_starts == 1

    def test_instances_are_per_function(self):
        p = _platform(n_workers=1)
        p1 = p.invoke("fn_a", now=0.0)
        p1.complete(now=1.0)
        p2 = p.invoke("fn_b", now=2.0)
        assert p2.warm_hit is False  # fn_a's instance serves only fn_a
        assert p.lifecycle_snapshot()["pools"] == 2

    def test_keep_alive_expiry(self):
        p = _platform(LifecycleSpec(keep_alive=5.0), n_workers=1)
        p.invoke("fn", now=0.0).complete(now=1.0)
        # Within keep-alive: warm. Past it: the janitor reaps first.
        warm = p.invoke("fn", now=3.0)
        assert warm.warm_hit is True
        warm.complete(now=4.0)
        cold = p.invoke("fn", now=20.0)
        assert cold.warm_hit is False
        assert p.stats().expirations == 1
        assert p.lifecycle_snapshot()["idle_instances"] == 0

    def test_explicit_janitor_tick(self):
        p = _platform(LifecycleSpec(keep_alive=5.0), n_workers=1)
        p.invoke("fn", now=0.0).complete(now=1.0)
        assert p.expire_instances(5.9) == 0   # deadline is 1.0 + 5.0
        assert p.expire_instances(6.0) == 1
        assert p.lifecycle_snapshot()["idle_instances"] == 0

    def test_clockless_completions_never_expire(self):
        p = _platform(LifecycleSpec(keep_alive=0.001), n_workers=1)
        p.invoke("fn").complete()            # no clock anywhere
        assert p.expire_instances(1e12) == 0
        assert p.invoke("fn", now=1e12).warm_hit is True

    def test_max_idle_caps_parked_instances(self):
        p = _platform(LifecycleSpec(max_idle=1), n_workers=1)
        a = p.invoke("fn", now=0.0)
        b = p.invoke("fn", now=0.0)
        assert a.warm_hit is False and b.warm_hit is False
        a.complete(now=1.0)
        b.complete(now=1.0)                  # pool full → terminated
        snap = p.lifecycle_snapshot()
        assert snap["idle_instances"] == 1
        assert snap["expirations"] == 1

    def test_worker_keep_alive_overrides_spec(self):
        p = _platform(LifecycleSpec(keep_alive=1000.0), n_workers=1,
                      worker_keep_alive=2.0)
        p.invoke("fn", now=0.0).complete(now=1.0)
        assert p.invoke("fn", now=10.0).warm_hit is False

    def test_controller_keep_alive_overrides_spec(self):
        p = _platform(LifecycleSpec(keep_alive=1000.0), n_workers=1,
                      controller_keep_alive=2.0)
        p.invoke("fn", now=0.0).complete(now=1.0)
        assert p.invoke("fn", now=10.0).warm_hit is False

    def test_mru_reuse_order(self):
        # Two instances parked; the most recently parked is reused first,
        # so the older one is the one the janitor reaps.
        p = _platform(LifecycleSpec(keep_alive=10.0), n_workers=1)
        a = p.invoke("fn", now=0.0)
        b = p.invoke("fn", now=0.0)
        a.complete(now=1.0)                  # older deadline: 11.0
        b.complete(now=5.0)                  # newer deadline: 15.0
        c = p.invoke("fn", now=6.0)          # reuses b's instance (MRU)
        assert c.warm_hit is True
        assert p.expire_instances(12.0) == 1  # a's instance expires alone
        c.complete(now=12.5)
        assert p.invoke("fn", now=13.0).warm_hit is True


class TestWarmFirstRouting:
    def test_warm_first_sticks_to_warm_worker(self):
        p = _platform(seed=3, n_workers=4)
        first = p.invoke("fn", now=0.0)
        first.complete(now=1.0)
        warm_worker = first.decision.worker
        for step in range(8):
            pl = p.invoke("fn", now=2.0 + step)
            assert pl.decision.worker == warm_worker, step
            assert pl.warm_hit is True
            pl.complete(now=2.5 + step)

    def test_warm_first_overflows_to_cold_then_returns(self):
        p = _platform(seed=1, n_workers=3, slots=1)
        a = p.invoke("fn", now=0.0)
        a.complete(now=1.0)
        warm_worker = a.decision.worker
        b = p.invoke("fn", now=2.0)          # takes the warm slot
        assert b.decision.worker == warm_worker and b.warm_hit is True
        c = p.invoke("fn", now=2.0)          # warm worker full → cold spill
        assert c.decision.worker != warm_worker and c.warm_hit is False
        b.complete(now=3.0)
        d = p.invoke("fn", now=4.0)          # warm again → back home
        assert d.decision.worker == warm_worker and d.warm_hit is True

    def test_explain_annotates_warmth_when_armed(self):
        p = _platform(n_workers=3)
        first = p.invoke("fn", now=0.0)
        first.complete(now=1.0)
        report = p.explain("fn")
        verdicts = {
            c.worker: c.warm
            for block in report.blocks for c in block.candidates
        }
        assert verdicts[first.decision.worker] is True
        assert all(
            warm is False
            for worker, warm in verdicts.items()
            if worker != first.decision.worker
        )

    def test_explain_has_no_warmth_unarmed(self):
        p = TappPlatform(_spec(), policy=WARM_FIRST_SCRIPT)
        report = p.explain("fn")
        assert all(
            c.warm is None
            for block in report.blocks for c in block.candidates
        )

    def test_armed_all_cold_is_bit_identical_to_no_lifecycle(self):
        """Uniform warmth (every instance cold, nothing ever parked) keeps
        warm-first partitions the identity: an armed platform's decisions,
        traces, and RNG streams match a lifecycle-free one exactly."""
        for trial in range(4):
            plain = TappPlatform(_spec(n_workers=5, slots=64), seed=trial,
                                 policy=WARM_FIRST_SCRIPT)
            armed = TappPlatform(_spec(n_workers=5, slots=64), seed=trial,
                                 policy=WARM_FIRST_SCRIPT,
                                 lifecycle=LifecycleSpec(keep_alive=1e9))
            rng = random.Random(40 + trial)
            for step in range(50):
                fn = rng.choice(("fn_a", "fn_b"))
                p1 = plain.invoke(fn, trace=True)
                p2 = armed.invoke(fn, trace=True, now=float(step))
                ctx = f"trial={trial} step={step}"
                assert p1.decision.worker == p2.decision.worker, ctx
                assert p1.decision.trace == p2.decision.trace, ctx
            assert (
                plain.gateway._engine.scheduling_state()
                == armed.gateway._engine.scheduling_state()
            )

    def test_armed_lifecycle_invisible_to_non_warm_first_policies(self):
        """With no warm-first strategy in the script the lifecycle runs
        fully (pools fill, instances expire) but routing never reads the
        warmth — placements stay bit-identical to an unarmed platform
        under completion churn."""
        script = (
            "- default:\n"
            "  - workers:\n"
            "    - set:\n"
            "    strategy: platform\n"
            "- spread:\n"
            "  - workers:\n"
            "    - set: pool\n"
            "      strategy: random\n"
            "  followup: default\n"
        )
        for trial in range(4):
            plain = TappPlatform(_spec(n_workers=5), seed=trial,
                                 policy=script)
            armed = TappPlatform(_spec(n_workers=5), seed=trial,
                                 policy=script,
                                 lifecycle=LifecycleSpec(keep_alive=2.0))
            rng = random.Random(90 + trial)
            live = []
            for step in range(60):
                now = float(step)
                fn = rng.choice(("fn_a", "fn_b"))
                tag = rng.choice((None, "spread"))
                p1 = plain.invoke(fn, tag=tag, trace=True)
                p2 = armed.invoke(fn, tag=tag, trace=True, now=now)
                ctx = f"trial={trial} step={step}"
                assert p1.decision.worker == p2.decision.worker, ctx
                assert p1.decision.trace == p2.decision.trace, ctx
                if p1.admitted:
                    live.append((p1, p2))
                while len(live) > 4:
                    a, b = live.pop(0)
                    a.complete()
                    b.complete(now=now)
            assert (
                plain.gateway._engine.scheduling_state()
                == armed.gateway._engine.scheduling_state()
            )
            # The lifecycle really ran on the armed side — instances
            # were spawned (and possibly reused/expired) — yet routing
            # never diverged.
            assert armed.stats().cold_starts > 0


class TestJanitorChurn:
    def test_expiry_under_drain_and_deregister_never_strands(self):
        """Random invoke/complete/drain/restore/remove/add churn with the
        janitor ticking throughout: the ledger invariant holds and busy
        instances always equal inflight tickets."""
        for trial in range(4):
            p = _platform(LifecycleSpec(keep_alive=3.0), seed=trial,
                          n_workers=4, slots=2)
            rng = random.Random(70 + trial)
            live = []
            removed = set()
            for step in range(120):
                now = float(step) * 0.7
                roll = rng.random()
                if roll < 0.45:
                    pl = p.invoke(rng.choice(("fn_a", "fn_b")), now=now)
                    if pl.admitted:
                        live.append(pl)
                elif roll < 0.70 and live:
                    live.pop(rng.randrange(len(live))).complete(now=now)
                elif roll < 0.78:
                    name = f"w{rng.randrange(4)}"
                    if name not in removed:
                        p.drain(name)
                elif roll < 0.86:
                    name = f"w{rng.randrange(4)}"
                    if name not in removed:
                        p.restore(name)
                elif roll < 0.93:
                    name = f"w{rng.randrange(4)}"
                    if name not in removed:
                        p.remove_worker(name)
                        removed.add(name)
                        live = [pl for pl in live
                                if pl.decision.worker != name]
                else:
                    name = f"w{rng.randrange(4)}"
                    if name in removed:
                        p.add_worker(WorkerSpec(
                            name, sets=("pool", "any"), capacity_slots=2,
                        ))
                        removed.discard(name)
                p.expire_instances(now)
                stats = p.stats()
                snap = p.lifecycle_snapshot()
                ctx = f"trial={trial} step={step}"
                assert stats.admitted == (
                    stats.completed + stats.evicted + stats.inflight
                ), ctx
                assert snap["busy_instances"] == stats.inflight, ctx
            # Drain the survivors; every pool reconciles.
            now = 1e6
            for pl in live:
                pl.complete(now=now)
            stats = p.stats()
            assert stats.inflight == 0
            assert stats.admitted == stats.completed + stats.evicted
            assert p.lifecycle_snapshot()["busy_instances"] == 0

    def test_saturation_respawns_after_term(self):
        """Keep a single worker saturated across keep-alive windows: each
        round's instances expire (TERM) and the next round spawns cold
        again — counters and the ledger stay exact."""
        p = _platform(LifecycleSpec(keep_alive=1.0), n_workers=1, slots=2)
        now = 0.0
        for round_no in range(5):
            a = p.invoke("fn", now=now)
            b = p.invoke("fn", now=now)
            assert a.warm_hit is False and b.warm_hit is False, round_no
            overflow = p.invoke("fn", now=now)    # saturated → unscheduled
            assert not overflow.scheduled, round_no
            a.complete(now=now + 0.5)
            b.complete(now=now + 0.5)
            now += 10.0                           # idle past keep-alive
            assert p.expire_instances(now) == 2, round_no
        stats = p.stats()
        assert stats.cold_starts == 10
        assert stats.warm_hits == 0
        assert stats.expirations == 10
        assert stats.admitted == stats.completed == 10
        snap = p.lifecycle_snapshot()
        assert snap["idle_instances"] == snap["busy_instances"] == 0
        assert snap["pools"] == 0

    def test_dead_worker_pools_are_forgotten(self):
        p = _platform(LifecycleSpec(keep_alive=1e9), n_workers=2)
        pl = p.invoke("fn", now=0.0)
        pl.complete(now=1.0)
        victim = pl.decision.worker
        assert p.lifecycle_snapshot()["idle_instances"] == 1
        p.watcher.mark_dead(victim)
        assert p.lifecycle_snapshot()["idle_instances"] == 0
        nxt = p.invoke("fn", now=2.0)
        assert nxt.warm_hit is False      # fresh incarnations start cold


NET = NetworkModel(rtt={}, bandwidth={})


def _sim_platform(lifecycle=None):
    return TappPlatform(
        ClusterSpec(
            controllers=(ControllerSpec("C1", zone="cloud"),),
            workers=(
                WorkerSpec("w0", zone="cloud", capacity_slots=4),
            ),
        ),
        lifecycle=lifecycle,
    )


def _cold_profile(**overrides):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return FunctionProfile(
            name="cold-start", exec_time=0.030, exec_jitter=0.0,
            cold_start_time=2.8, **overrides
        )


class TestWarmTtlDeprecation:
    def test_non_default_warm_ttl_warns(self):
        with pytest.warns(DeprecationWarning, match="warm_ttl"):
            FunctionProfile(name="f", exec_time=0.1, warm_ttl=60.0)

    def test_default_warm_ttl_is_silent(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            FunctionProfile(name="f", exec_time=0.1)

    def test_throttled_scenario_pinned(self):
        """The §5.2 throttled cold-start case (scenarios.py): users pause
        past the 60s TTL, so *every* request is cold — unchanged by the
        deprecation shim."""
        profile = _cold_profile(warm_ttl=60.0)
        sim = Simulation(
            _sim_platform(), NET, {"cold-start": profile},
            SimConfig(seed=0),
        )
        result = sim.run([WorkloadSpec("cold-start", users=1,
                                       requests_per_user=3, pause=660.0)])
        assert [r.cold for r in result.records] == [True, True, True]
        for r in result.records:
            assert r.latency >= profile.cold_start_time

    def test_fast_chain_stays_warm_unarmed(self):
        profile = _cold_profile(warm_ttl=60.0)
        sim = Simulation(
            _sim_platform(), NET, {"cold-start": profile},
            SimConfig(seed=0),
        )
        result = sim.run([WorkloadSpec("cold-start", users=1,
                                       requests_per_user=3, pause=1.0)])
        assert [r.cold for r in result.records] == [True, False, False]

    def test_armed_platform_ignores_warm_ttl(self):
        """Armed lifecycle: keep_alive governs expiry; the 60s warm_ttl
        would have made every 660s-paused request cold, but a generous
        keep-alive keeps the chain warm."""
        profile = _cold_profile(warm_ttl=60.0)
        sim = Simulation(
            _sim_platform(lifecycle=LifecycleSpec(keep_alive=10_000.0)),
            NET, {"cold-start": profile}, SimConfig(seed=0),
        )
        result = sim.run([WorkloadSpec("cold-start", users=1,
                                       requests_per_user=3, pause=660.0)])
        assert [r.cold for r in result.records] == [True, False, False]
        stats = sim.platform.stats()
        assert stats.cold_starts == 1 and stats.warm_hits == 2

    def test_armed_platform_expires_by_keep_alive(self):
        profile = _cold_profile()           # default (ignored) warm_ttl
        sim = Simulation(
            _sim_platform(lifecycle=LifecycleSpec(keep_alive=60.0)),
            NET, {"cold-start": profile}, SimConfig(seed=0),
        )
        result = sim.run([WorkloadSpec("cold-start", users=1,
                                       requests_per_user=3, pause=660.0)])
        assert [r.cold for r in result.records] == [True, True, True]
        assert sim.platform.stats().expirations == 2


class TestValidatorWarmFirst:
    def test_tag_level_warm_first_is_an_error(self):
        script = parse_tapp(
            "- alpha:\n"
            "  - workers:\n"
            "    - set:\n"
            "  strategy: warm-first\n"
        )
        report = validate_script(script)
        assert not report.ok
        assert any("warm-first" in f.message for f in report.errors)

    def test_block_and_set_warm_first_are_fine(self):
        script = parse_tapp(WARM_FIRST_SCRIPT)
        assert validate_script(script).ok
        block_level = parse_tapp(
            "- alpha:\n"
            "  - workers:\n"
            "    - set: east\n"
            "    - set: west\n"
            "    strategy: warm-first\n"
        )
        report = validate_script(block_level)
        assert report.ok
        assert not any("warm-first" in f.message for f in report.warnings)

    def test_shadowed_block_warm_first_lints(self):
        script = parse_tapp(
            "- alpha:\n"
            "  - workers:\n"
            "    - set: east\n"
            "      strategy: random\n"
            "    - set: west\n"
            "      strategy: best_first\n"
            "    strategy: warm-first\n"
        )
        report = validate_script(script)
        assert report.ok                      # a lint, not an error
        assert any(
            "warm-first" in f.message and f.level == "warning"
            for f in report.findings
        )

    def test_partially_inherited_sets_do_not_lint(self):
        script = parse_tapp(
            "- alpha:\n"
            "  - workers:\n"
            "    - set: east\n"
            "      strategy: random\n"
            "    - set: west\n"
            "    strategy: warm-first\n"
        )
        report = validate_script(script)
        assert not any(
            "warm-first" in f.message for f in report.warnings
        )
