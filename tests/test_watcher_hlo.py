"""Watcher elasticity/live-reload + HLO cost-model unit tests."""
import textwrap

import pytest

from repro.core.scheduler import (
    ControllerState,
    Gateway,
    Invocation,
    Watcher,
    WorkerState,
)
from repro.core.tapp import TappValidationError
from repro.roofline.hlo import analyze_hlo


class TestWatcher:
    def _watcher(self):
        w = Watcher()
        w.register_controller(ControllerState(name="C", zone="z"))
        w.register_worker(WorkerState(name="a", zone="z",
                                      sets=frozenset({"s1", "any"})))
        return w

    def test_elastic_join_leave(self):
        w = self._watcher()
        v0 = w.cluster.version
        w.register_worker(WorkerState(name="b", zone="z"))
        assert "b" in w.cluster.workers and w.cluster.version > v0
        w.deregister_worker("b")
        assert "b" not in w.cluster.workers

    def test_subscribers_notified(self):
        w = self._watcher()
        events = []
        w.subscribe(events.append)
        w.register_worker(WorkerState(name="b"))
        w.load_script("- default:\n  - workers:\n    - set:\n")
        assert events == ["topology", "script"]

    def test_live_reload_versioning(self):
        w = self._watcher()
        w.load_script("- default:\n  - workers:\n    - set:\n")
        v1 = w.script_version
        s2 = w.load_script(
            "- default:\n  - workers:\n    - set: s1\n"
        )
        assert w.script_version > v1
        assert s2.get("default").blocks[0].workers[0].label == "s1"

    def test_strict_reload_rejects_bad_script_keeps_old(self):
        w = self._watcher()
        w.load_script("- default:\n  - workers:\n    - set:\n")
        old = w.script
        bad = "- default:\n  - workers:\n    - set:\n  followup: default\n"
        with pytest.raises(TappValidationError):
            w.load_script(bad, strict=True)
        assert w.script is old  # previous script preserved

    def test_heartbeat_updates(self):
        w = self._watcher()
        w.update_worker("a", capacity_used_pct=88.0, inflight=3)
        assert w.cluster.workers["a"].capacity_used_pct == 88.0
        w.mark_unreachable("a")
        assert not w.cluster.workers["a"].reachable

    def test_snapshot_labels(self):
        w = self._watcher()
        snap = w.snapshot_labels()
        assert snap["workers"]["a"]["zone"] == "z"
        assert "s1" in snap["workers"]["a"]["sets"]
        assert snap["controllers"]["C"]["zone"] == "z"

    def test_gateway_cache_invalidation(self):
        w = self._watcher()
        w.load_script("- default:\n  - workers:\n    - set:\n")
        g = Gateway(w)
        g.route(Invocation("f"))
        g.route(Invocation("f"))
        reloads_before = g.stats.script_reloads
        g.route(Invocation("f"))
        assert g.stats.script_reloads == reloads_before  # cached
        w.load_script("- default:\n  - workers:\n    - set: s1\n")
        g.route(Invocation("f"))
        assert g.stats.script_reloads == reloads_before + 1

    def test_no_script_falls_back_to_vanilla(self):
        w = self._watcher()
        g = Gateway(w)
        d = g.route(Invocation("f"))
        assert d.scheduled
        assert g.stats.vanilla_routed == 1
        w.load_script("- default:\n  - workers:\n    - set:\n")
        g.route(Invocation("f"))
        assert g.stats.tapp_routed == 1


SYNTHETIC_HLO = textwrap.dedent("""\
    HloModule test, is_scheduled=true

    %region_body (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = f32[8,16]{1,0} parameter(0)
      %dotop = f32[8,16]{1,0} dot(%p, %w16), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %w16 = f32[16,16]{1,0} parameter(1)
      %ar = f32[8,16]{1,0} all-reduce(%dotop), replica_groups=[2,4]<=[8], to_apply=%add
    }

    %region_cond (arg: (s32[], f32[8,16])) -> pred[] {
      %lt = pred[] compare(%i, %n), direction=LT
    }

    ENTRY %main (x: f32[8,16]) -> f32[8,16] {
      %x = f32[8,16]{1,0} parameter(0)
      %big = f32[8,16]{1,0} dot(%x, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %w2 = f32[16,16]{1,0} parameter(1)
      %loop = (s32[], f32[8,16]) while(%tup), condition=%region_cond, body=%region_body, backend_config={"known_trip_count":{"n":"5"}}
      %ag = f32[8,64]{1,0} all-gather(%x), replica_groups=[1,8]<=[8], dimensions={1}
    }
""")


class TestHloCostModel:
    def test_trip_count_multiplies_loop_body(self):
        hc = analyze_hlo(SYNTHETIC_HLO)
        # entry dot: 2*8*16*16 = 4096; body dot × 5 trips: 5*4096
        assert hc.dot_flops == pytest.approx(4096 + 5 * 4096)

    def test_collective_wire_factors(self):
        hc = analyze_hlo(SYNTHETIC_HLO)
        detail = hc.collective_detail
        # body all-reduce: bytes 8*16*4=512, group 4 → 2*(3/4)*512 = 768, ×5
        assert detail["all-reduce"]["wire_bytes"] == pytest.approx(5 * 768)
        # entry all-gather: 8*64*4 = 2048, group 8 → (7/8)*2048 = 1792
        assert detail["all-gather"]["wire_bytes"] == pytest.approx(1792)

    def test_counts_respect_trips(self):
        hc = analyze_hlo(SYNTHETIC_HLO)
        assert hc.collective_detail["all-reduce"]["count"] == 5
        assert hc.collective_detail["all-gather"]["count"] == 1


class TestEngineTrace:
    def test_explain_shows_candidates_and_controller(self):
        from repro.core.scheduler import (
            DistributionPolicy,
            TappEngine,
            make_cluster,
        )
        from repro.core.tapp import parse_tapp

        cluster = make_cluster(
            workers=[dict(name="w0", zone="z", sets=["any"], reachable=False),
                     dict(name="w1", zone="z", sets=["any"])],
            controllers=[dict(name="C", zone="z")],
        )
        script = parse_tapp("- default:\n  - workers:\n    - set:\n")
        d = TappEngine(DistributionPolicy.SHARED, seed=0).schedule(
            Invocation("f"), script, cluster, trace=True
        )
        text = d.explain()
        assert "w1: VALID" in text
        assert "gateway" in text  # controller resolution traced
        assert d.worker == "w1"
