"""Optimizer, data pipeline, checkpointing, sharding specs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep; see requirements-dev.txt
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.optim.adamw import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    lr_at,
)
from repro.optim.compression import ef_compress, ef_init, int8_roundtrip

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


class TestAdamW:
    def _quadratic(self):
        target = jnp.asarray([1.5, -2.0, 0.5])
        params = {"w": jnp.zeros(3)}

        def loss(p):
            return jnp.sum((p["w"] - target) ** 2)

        return params, loss, target

    def test_converges_on_quadratic(self):
        params, loss, target = self._quadratic()
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                          total_steps=300, schedule="constant")
        state = adamw_init(cfg, params)
        for _ in range(300):
            grads = jax.grad(loss)(params)
            params, state, _ = adamw_update(cfg, grads, state, params)
        assert float(loss(params)) < 1e-3

    def test_int8_moments_track_f32(self):
        params, loss, _ = self._quadratic()
        cfg32 = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                            total_steps=100, schedule="constant")
        cfg8 = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                           total_steps=100, schedule="constant",
                           moment_dtype="int8")
        p32, s32 = dict(params), adamw_init(cfg32, params)
        p8, s8 = dict(params), adamw_init(cfg8, params)
        for _ in range(100):
            g32 = jax.grad(loss)(p32)
            p32, s32, _ = adamw_update(cfg32, g32, s32, p32)
            g8 = jax.grad(loss)(p8)
            p8, s8, _ = adamw_update(cfg8, g8, s8, p8)
        assert float(loss(p8)) < 1e-2
        np.testing.assert_allclose(
            np.asarray(p8["w"]), np.asarray(p32["w"]), atol=0.05
        )

    def test_grad_clip(self):
        tree = {"a": jnp.full((4,), 100.0)}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert float(norm) == pytest.approx(200.0)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_schedule_shapes(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
        assert float(lr_at(cfg, jnp.asarray(0))) < 0.2
        assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0, rel=0.15)
        assert float(lr_at(cfg, jnp.asarray(99))) == pytest.approx(0.1, rel=0.15)

    def test_weight_decay_shrinks(self):
        params = {"w": jnp.full((4,), 10.0)}
        cfg = AdamWConfig(lr=0.1, weight_decay=0.5, warmup_steps=1,
                          schedule="constant")
        state = adamw_init(cfg, params)
        grads = {"w": jnp.zeros(4)}
        new, _, _ = adamw_update(cfg, grads, state, params)
        assert float(new["w"][0]) < 10.0


class TestCompression:
    @given(st.integers(min_value=1, max_value=5000))
    @settings(max_examples=20, deadline=None)
    def test_int8_roundtrip_error_bounded(self, n):
        x = jax.random.normal(jax.random.PRNGKey(n), (n,))
        out = int8_roundtrip({"g": x})["g"]
        blockmax = float(jnp.max(jnp.abs(x)))
        assert float(jnp.max(jnp.abs(out - x))) <= blockmax / 127.0 + 1e-6

    def test_error_feedback_reduces_bias(self):
        g = jnp.asarray([1e-4] * 512)  # tiny uniform gradient
        state = ef_init({"g": g})
        total = jnp.zeros_like(g)
        for _ in range(50):
            compressed, state = ef_compress({"g": g}, state)
            total = total + compressed["g"]
        # with EF, the accumulated compressed signal tracks 50*g
        np.testing.assert_allclose(
            np.asarray(total), np.asarray(50 * g), rtol=0.05
        )


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


class TestData:
    def cfg(self, **kw):
        return DataConfig(vocab_size=997, global_batch=8, seq_len=64, **kw)

    def test_deterministic_by_step(self):
        p = SyntheticTokens(self.cfg())
        a = p.batch_at(5)
        b = p.batch_at(5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = p.batch_at(6)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_host_sharding_partitions_batch(self):
        p = SyntheticTokens(self.cfg())
        full = p.batch_at(3)["tokens"]
        parts = [
            p.batch_at(3, host_index=i, host_count=4)["tokens"]
            for i in range(4)
        ]
        np.testing.assert_array_equal(np.concatenate(parts, 0), full)

    def test_tokens_in_vocab(self):
        p = SyntheticTokens(self.cfg())
        t = p.batch_at(0)["tokens"]
        assert t.min() >= 0 and t.max() < 997

    def test_frames_emitted(self):
        p = SyntheticTokens(self.cfg(frames_dim=32))
        b = p.batch_at(0)
        assert b["frames"].shape == (8, 64, 32)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


class TestCheckpointer:
    def tree(self):
        return {
            "params": {"w": jnp.arange(12.0).reshape(3, 4)},
            "step": jnp.asarray(7),
        }

    def test_roundtrip(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        tree = self.tree()
        ck.save(10, tree, extra={"note": "hi"})
        restored, step, extra = ck.restore(tree)
        assert step == 10 and extra["note"] == "hi"
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]), np.asarray(tree["params"]["w"])
        )

    def test_latest_and_gc(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep_last=2)
        tree = self.tree()
        for s in (1, 2, 3, 4):
            ck.save(s, tree)
        assert ck.latest_step() == 4
        kept = sorted(p.name for p in tmp_path.glob("step_*") if p.is_dir())
        assert len(kept) == 2

    def test_uncommitted_invisible(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, self.tree())
        # simulate crash: directory exists but marker removed
        (tmp_path / "step_000000001.COMMITTED").unlink()
        assert ck.latest_step() is None

    def test_async_save(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(2, self.tree(), blocking=False)
        ck.wait()
        assert ck.latest_step() == 2

    def test_restore_specific_step(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep_last=5)
        tree = self.tree()
        ck.save(1, tree)
        tree2 = {"params": {"w": tree["params"]["w"] * 2}, "step": jnp.asarray(8)}
        ck.save(2, tree2)
        restored, step, _ = ck.restore(tree, step=1)
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]), np.asarray(tree["params"]["w"])
        )


# ---------------------------------------------------------------------------
# Sharding specs
# ---------------------------------------------------------------------------


class TestShardingSpecs:
    def test_sanitize_drops_nondivisible(self):
        from jax.sharding import PartitionSpec as P

        from repro.launch.mesh import make_debug_mesh
        from repro.sharding.specs import sanitize_spec

        mesh = make_debug_mesh((1, 1), ("data", "model"))
        spec = sanitize_spec(P("data", "model"), (5, 7), mesh)
        # axis size 1 divides everything
        assert spec == P("data", "model")

    @given(
        dims=st.tuples(st.integers(1, 64), st.integers(1, 64)),
    )
    @settings(max_examples=30, deadline=None)
    def test_sanitize_always_divides(self, dims):
        from jax.sharding import PartitionSpec as P

        from repro.launch.mesh import make_debug_mesh
        from repro.sharding.specs import _axis_size, sanitize_spec

        mesh = make_debug_mesh((1, 1), ("data", "model"))
        spec = sanitize_spec(P("data", "model"), dims, mesh)
        for dim, axes in zip(dims, list(spec)):
            if axes is not None:
                assert dim % _axis_size(mesh, axes) == 0

    def test_param_spec_rules(self):
        from jax.sharding import PartitionSpec as P

        from repro.configs import get_config
        from repro.launch.mesh import make_debug_mesh
        from repro.sharding.specs import ShardingPolicy, param_spec

        cfg = get_config("qwen3_14b")
        mesh = make_debug_mesh((1, 1), ("data", "model"))
        policy = ShardingPolicy().for_mesh(mesh)
        # embed table vocab-parallel
        spec = param_spec(cfg, policy, mesh, ("embed", "table"), (151936, 5120))
        assert spec[0] == "model"
        # column parallel
        spec = param_spec(cfg, policy, mesh, ("blocks", "pos0", "attn", "wq"),
                          (40, 5120, 5120))
        assert spec == P(None, ("data",), "model")
        # row parallel
        spec = param_spec(cfg, policy, mesh, ("blocks", "pos0", "attn", "wo"),
                          (40, 5120, 5120))
        assert spec == P(None, "model", ("data",))
        # norm scales replicated
        spec = param_spec(cfg, policy, mesh, ("final_norm", "scale"), (5120,))
        assert spec == P(None)
