"""Property-based tests (hypothesis) for the scheduler's invariants."""
import pytest

pytest.importorskip("hypothesis")  # dev-only dep; see requirements-dev.txt
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.scheduler import (
    DistributionPolicy,
    Invocation,
    TappEngine,
    coprime_order,
    is_invalid,
    make_cluster,
    resolve_invalidate,
)
from repro.core.tapp import (
    CapacityUsed,
    TappScript,
    parse_tapp,
    script_to_yaml,
)
from repro.core.tapp.ast import (
    Affinity,
    AntiAffinity,
    Block,
    ControllerClause,
    FollowupKind,
    MaxConcurrentInvocations,
    Overload,
    Strategy,
    TagPolicy,
    TopologyTolerance,
    WorkerRef,
    WorkerSet,
)

# ---------------------------------------------------------------------------
# coprime schedule
# ---------------------------------------------------------------------------


@given(st.integers(min_value=1, max_value=256), st.integers(min_value=0))
def test_coprime_order_is_permutation(n, h):
    assert sorted(coprime_order(n, h)) == list(range(n))


@given(st.integers(min_value=1, max_value=64), st.integers(min_value=0))
def test_coprime_order_deterministic(n, h):
    assert coprime_order(n, h) == coprime_order(n, h)


# ---------------------------------------------------------------------------
# invalidation monotonicity
# ---------------------------------------------------------------------------


@given(
    pct=st.floats(min_value=0, max_value=100),
    threshold_a=st.floats(min_value=1, max_value=100),
    threshold_b=st.floats(min_value=1, max_value=100),
)
def test_capacity_used_monotone(pct, threshold_a, threshold_b):
    """If invalid at a high threshold, must be invalid at any lower one."""
    lo, hi = sorted((threshold_a, threshold_b))
    from repro.core.scheduler.state import WorkerState

    w = WorkerState(name="w", capacity_used_pct=pct)
    if is_invalid(w, CapacityUsed(hi)):
        assert is_invalid(w, CapacityUsed(lo))


# ---------------------------------------------------------------------------
# random scripts: serialize∘parse identity + engine safety
# ---------------------------------------------------------------------------

_labels = st.sampled_from(["a", "b", "c", "edge", "cloud", "w0", "w1"])
_strategies = st.sampled_from(list(Strategy)) | st.none()
_invalidates = st.one_of(
    st.none(),
    st.builds(CapacityUsed, st.integers(min_value=1, max_value=100).map(float)),
)

_worker_items = st.one_of(
    st.lists(
        st.builds(WorkerRef, label=_labels, invalidate=_invalidates),
        min_size=1, max_size=3,
    ),
    st.lists(
        st.builds(
            WorkerSet,
            label=st.one_of(st.none(), _labels),
            strategy=_strategies,
            invalidate=_invalidates,
        ),
        min_size=1, max_size=2,
    ),
)

_blocks = st.builds(
    Block,
    workers=_worker_items.map(tuple),
    strategy=_strategies,
    invalidate=_invalidates,
)

_tags = st.builds(
    TagPolicy,
    tag=st.sampled_from(["default", "t1", "t2", "ml"]),
    blocks=st.lists(_blocks, min_size=1, max_size=3).map(tuple),
    strategy=_strategies,
    followup=st.sampled_from([None, FollowupKind.FAIL]),
)


@st.composite
def _scripts(draw):
    tags = draw(st.lists(_tags, min_size=1, max_size=4))
    seen, unique = set(), []
    for t in tags:
        if t.tag not in seen:
            seen.add(t.tag)
            unique.append(t)
    return TappScript(tags=tuple(unique))


@given(_scripts())
@settings(max_examples=60, deadline=None)
def test_serialize_parse_roundtrip(script):
    assert parse_tapp(script_to_yaml(script)).tags == script.tags


# ---------------------------------------------------------------------------
# full-grammar round-trip: every clause the language defines, including the
# constraint-layer-v2 affinity extension
# ---------------------------------------------------------------------------

_fn_names = st.sampled_from(
    ["fn_a", "fn_b", "svc_cache", "noisy_batch", "latency_api"]
)
_fn_lists = st.lists(_fn_names, min_size=1, max_size=3, unique=True).map(tuple)
_affinities = st.one_of(st.none(), st.builds(Affinity, _fn_lists))
_anti_affinities = st.one_of(st.none(), st.builds(AntiAffinity, _fn_lists))
_full_invalidates = st.one_of(
    st.none(),
    st.just(Overload()),
    st.builds(CapacityUsed, st.integers(min_value=1, max_value=100).map(float)),
    st.builds(
        MaxConcurrentInvocations, st.integers(min_value=1, max_value=500)
    ),
)
_controllers = st.one_of(
    st.none(),
    st.builds(
        ControllerClause,
        label=st.sampled_from(["Ctl0", "Ctl1", "EdgeCtl"]),
        topology_tolerance=st.sampled_from(list(TopologyTolerance)),
    ),
)

_full_worker_items = st.one_of(
    st.lists(
        st.builds(
            WorkerRef,
            label=_labels,
            invalidate=_full_invalidates,
            affinity=_affinities,
            anti_affinity=_anti_affinities,
        ),
        min_size=1, max_size=3,
    ),
    st.lists(
        st.builds(
            WorkerSet,
            label=st.one_of(st.none(), _labels),
            strategy=_strategies,
            invalidate=_full_invalidates,
            affinity=_affinities,
            anti_affinity=_anti_affinities,
        ),
        min_size=1, max_size=3,
    ),
)

_full_blocks = st.builds(
    Block,
    workers=_full_worker_items.map(tuple),
    controller=_controllers,
    strategy=_strategies,
    invalidate=_full_invalidates,
    affinity=_affinities,
    anti_affinity=_anti_affinities,
)

_full_tags = st.builds(
    TagPolicy,
    tag=st.sampled_from(["default", "t1", "t2", "ml", "latency"]),
    blocks=st.lists(_full_blocks, min_size=1, max_size=3).map(tuple),
    strategy=_strategies,
    followup=st.sampled_from([None, FollowupKind.FAIL, FollowupKind.DEFAULT]),
)


@st.composite
def _full_scripts(draw):
    tags = draw(st.lists(_full_tags, min_size=1, max_size=5))
    seen, unique = set(), []
    for t in tags:
        if t.tag not in seen:
            seen.add(t.tag)
            unique.append(t)
    return TappScript(tags=tuple(unique))


@pytest.mark.slow
@given(_full_scripts())
@settings(max_examples=300, deadline=None)
def test_full_grammar_serialize_parse_roundtrip(script):
    """parse ∘ serialize is the identity over the FULL grammar: controller
    clauses with every tolerance, every invalidate kind, affinity and
    anti-affinity at block and item level, strategies, and followups."""
    assert parse_tapp(script_to_yaml(script)).tags == script.tags


@given(
    script=_scripts(),
    tag=st.sampled_from([None, "t1", "t2", "missing"]),
    down=st.lists(st.booleans(), min_size=4, max_size=4),
    policy=st.sampled_from(list(DistributionPolicy)),
    seed=st.integers(min_value=0, max_value=99),
)
@settings(max_examples=100, deadline=None)
def test_engine_never_picks_invalid_worker(script, tag, down, policy, seed):
    """Whatever the script/cluster, a scheduled worker must be reachable,
    and must satisfy the resolved invalidate condition of its block."""
    cluster = make_cluster(
        workers=[
            dict(name="a", zone="z1", sets=["edge", "any"],
                 capacity_slots=2, reachable=down[0]),
            dict(name="b", zone="z1", sets=["cloud", "any"],
                 capacity_slots=2, healthy=down[1]),
            dict(name="w0", zone="z2", sets=["edge", "any"],
                 capacity_slots=2, capacity_used_pct=75.0 if down[2] else 0.0),
            dict(name="w1", zone="z2", sets=["any"], capacity_slots=2,
                 inflight=2 if down[3] else 0),
        ],
        controllers=[dict(name="C1", zone="z1"), dict(name="C2", zone="z2")],
    )
    engine = TappEngine(policy, seed=seed)
    decision = engine.schedule(Invocation("f", tag=tag), script, cluster)
    if decision.scheduled:
        worker = cluster.workers[decision.worker]
        # Unreachability is the preliminary condition of EVERY invalidate
        # option (paper §3.3) — a scheduled worker must be reachable.
        # (An unhealthy worker MAY be picked under capacity_used /
        # max_concurrent conditions: those don't consult health.)
        assert worker.reachable
        assert decision.controller in cluster.controllers


@given(policy=st.sampled_from(list(DistributionPolicy)),
       seed=st.integers(min_value=0, max_value=20))
@settings(max_examples=30, deadline=None)
def test_engine_fails_when_all_unreachable(policy, seed):
    cluster = make_cluster(
        workers=[dict(name="a", reachable=False),
                 dict(name="b", reachable=False)],
        controllers=[dict(name="C1")],
    )
    script = parse_tapp("- default:\n  - workers:\n    - set:\n")
    decision = TappEngine(policy, seed=seed).schedule(
        Invocation("f"), script, cluster
    )
    assert not decision.scheduled


@given(
    item=st.one_of(st.none(), _invalidates),
    block=st.one_of(st.none(), _invalidates),
)
def test_resolve_invalidate_priority(item, block):
    resolved = resolve_invalidate(item, block)
    if item is not None:
        assert resolved == item
    elif block is not None:
        assert resolved == block
    else:
        from repro.core.scheduler import DEFAULT_INVALIDATE

        assert resolved == DEFAULT_INVALIDATE
