"""PR-7 batch routing kernel: bit-identity of ``schedule_batch``.

The vectorized batch path (mask-plane kernel + zero-draw cascade solver)
is an *optimization*, never a semantic fork: for any batch, backend, and
live-state churn pattern, ``schedule_batch`` must produce exactly the
decisions the sequential ``schedule`` loop would — same placements, same
traces, same RNG stream afterwards, same controller cursor, same
admission-ledger counters. This suite pins that contract:

* randomized property sweep (scripts × clusters × policies × entry
  zones × per-decision churn callbacks), numpy backend;
* traced batches (the scalar-fallback trigger) produce the sequential
  traces, untraced batches return empty traces;
* directed mid-batch saturation: a batch that fills a worker's slots
  partway through routes the tail exactly like the loop does;
* directed topology-epoch bumps (register/deregister) mid-batch;
* façade contracts: ``TappPlatform.invoke_batch`` and
  ``TappFederation.invoke_batch`` equal an ``invoke`` loop, including
  the PR-7 zone-sharded ledger snapshots and per-zone stats;
* jax backend spot-check (skipped when jax is unavailable).
"""
import random

import pytest

from repro.core.platform import (
    ClusterSpec,
    ControllerSpec,
    FederationSpec,
    TappFederation,
    TappPlatform,
    WorkerSpec,
)
from repro.core.scheduler import (
    DistributionPolicy,
    Invocation,
    TappEngine,
    WorkerState,
)
from repro.core.scheduler.watcher import Watcher
from tests.test_scheduler_compile import (
    mutate_cluster,
    random_cluster,
    random_script,
)

FUNCTIONS = ("fn_a", "fn_b", "fn_c")
TAGS = (None, "default", "alpha", "beta", "unk")


def _key(decision):
    return (
        decision.outcome,
        decision.worker,
        decision.controller,
        decision.tag,
        decision.used_default_fallback,
        decision.zone_restriction,
        decision.failed_by_policy,
    )


def _trace(decision):
    return [(e.kind, e.detail) for e in decision.trace]


def _run_pair(trial, policy, entry_zone, churn, backend, *, trace=False):
    """One batch through the sequential loop and through
    ``schedule_batch``, on twin clusters built from the same seed.

    Returns ``(seq_decisions, bat_decisions, (seq_engine, seq_watcher),
    (bat_engine, bat_watcher))`` for state comparison. The on-decision
    callback admits every placement (so later items see the batch's own
    load, the mid-batch feedback loop) and optionally churns the
    cluster between decisions — both sides replay the identical
    mutation stream.
    """
    rng = random.Random(trial)
    script = random_script(rng)
    w_seq = Watcher(random_cluster(random.Random(trial)))
    w_bat = Watcher(random_cluster(random.Random(trial)))
    seq = TappEngine(policy, seed=trial)
    bat = TappEngine(policy, seed=trial, batch_backend=backend)
    invocations = [
        Invocation(rng.choice(FUNCTIONS), tag=rng.choice(TAGS))
        for _ in range(24)
    ]
    mut_seq, mut_bat = random.Random(trial + 5), random.Random(trial + 5)

    def callback(watcher, mut):
        def on_decision(invocation, decision):
            if decision.scheduled:
                watcher.record_admission(
                    decision.worker, decision.controller, invocation.function
                )
            if churn and mut.random() < 0.3:
                mutate_cluster(mut, watcher)

        return on_decision

    seq_cb = callback(w_seq, mut_seq)
    seq_decisions = []
    for invocation in invocations:
        decision = seq.schedule(
            invocation,
            script,
            w_seq.cluster,
            trace=trace,
            entry_zone=entry_zone,
        )
        seq_cb(invocation, decision)
        seq_decisions.append(decision)
    bat_decisions = bat.schedule_batch(
        invocations,
        script,
        w_bat.cluster,
        trace=trace,
        entry_zone=entry_zone,
        on_decision=callback(w_bat, mut_bat),
    )
    return seq_decisions, bat_decisions, (seq, w_seq), (bat, w_bat)


def _assert_identical(seq_decisions, bat_decisions, seq_side, bat_side):
    seq, w_seq = seq_side
    bat, w_bat = bat_side
    assert [_key(d) for d in seq_decisions] == [
        _key(d) for d in bat_decisions
    ]
    assert [_trace(d) for d in seq_decisions] == [
        _trace(d) for d in bat_decisions
    ]
    # The batch path must consume exactly the sequential RNG stream and
    # leave the engine/ledger in the sequential end state.
    assert seq._rng.getstate() == bat._rng.getstate()
    assert seq._controller_cursor == bat._controller_cursor
    assert w_seq.cluster.load_seq == w_bat.cluster.load_seq


# ---------------------------------------------------------------------------
# Randomized property sweep
# ---------------------------------------------------------------------------


class TestBatchBitIdentity:
    @pytest.mark.parametrize("policy", list(DistributionPolicy))
    @pytest.mark.parametrize("entry_zone", [None, "edge"])
    def test_steady_state(self, policy, entry_zone):
        for trial in range(8):
            _assert_identical(
                *_run_pair(trial, policy, entry_zone, False, "numpy")
            )

    @pytest.mark.parametrize("policy", list(DistributionPolicy))
    @pytest.mark.parametrize("entry_zone", [None, "edge"])
    def test_under_churn(self, policy, entry_zone):
        # Per-decision cluster mutations (load, health, membership —
        # membership bumps the topology epoch mid-batch) force the
        # kernel through its cache-invalidation and scalar-fallback
        # paths; decisions must still match the loop bit-for-bit.
        for trial in range(8):
            _assert_identical(
                *_run_pair(trial, policy, entry_zone, True, "numpy")
            )

    def test_traced_batch_reproduces_sequential_traces(self):
        # trace=True is a scalar-fallback trigger: the batch path must
        # fall back without changing a single decision or trace event.
        for trial in range(4):
            _assert_identical(
                *_run_pair(
                    trial,
                    DistributionPolicy.DEFAULT,
                    None,
                    True,
                    "numpy",
                    trace=True,
                )
            )

    def test_untraced_batch_returns_empty_traces(self):
        _, bat_decisions, _, _ = _run_pair(
            3, DistributionPolicy.SHARED, None, False, "numpy"
        )
        assert all(d.trace == [] for d in bat_decisions)


# ---------------------------------------------------------------------------
# Directed scenarios
# ---------------------------------------------------------------------------

TINY_SCRIPT = """
- default:
  - workers:
    - set: pool
    strategy: platform
    invalidate: overload
"""


def _tiny_pair(policy=DistributionPolicy.DEFAULT, seed=0):
    def build():
        return Watcher(
            ClusterSpec(
                controllers=(ControllerSpec("C1", zone="z"),),
                workers=tuple(
                    WorkerSpec(
                        f"w{i}",
                        zone="z",
                        sets=("pool", "any"),
                        capacity_slots=1,
                    )
                    for i in range(2)
                ),
            ).build()
        )

    return (
        build(),
        build(),
        TappEngine(policy, seed=seed),
        TappEngine(policy, seed=seed, batch_backend="numpy"),
    )


class TestDirectedScenarios:
    def test_mid_batch_saturation(self):
        """A batch larger than the cluster's total slots: admissions
        made inside the batch must be visible to later items, exactly
        as in the sequential loop (2 workers x 1 slot -> decisions 3+
        find everything saturated)."""
        from repro.core.tapp import parse_tapp

        script = parse_tapp(TINY_SCRIPT)
        w_seq, w_bat, seq, bat = _tiny_pair()
        invocations = [Invocation("fn_a") for _ in range(6)]

        def admit(watcher):
            def on_decision(invocation, decision):
                if decision.scheduled:
                    watcher.record_admission(
                        decision.worker,
                        decision.controller,
                        invocation.function,
                    )

            return on_decision

        seq_cb = admit(w_seq)
        seq_decisions = []
        for invocation in invocations:
            decision = seq.schedule(invocation, script, w_seq.cluster)
            seq_cb(invocation, decision)
            seq_decisions.append(decision)
        bat_decisions = bat.schedule_batch(
            invocations, script, w_bat.cluster, on_decision=admit(w_bat)
        )
        assert [_key(d) for d in seq_decisions] == [
            _key(d) for d in bat_decisions
        ]
        # The scenario actually saturates: both slots get taken, and at
        # least one tail item cannot be placed.
        placed = [d for d in bat_decisions if d.scheduled]
        assert {d.worker for d in placed} == {"w0", "w1"}
        assert any(not d.scheduled for d in bat_decisions)
        assert seq._rng.getstate() == bat._rng.getstate()
        assert w_seq.cluster.load_seq == w_bat.cluster.load_seq

    def test_mid_batch_epoch_bumps(self):
        """Register a worker partway through and deregister another
        later: the topology epoch moves twice inside one batch, and the
        tail decisions must match the loop on the rebuilt views."""
        from repro.core.tapp import parse_tapp

        script = parse_tapp(TINY_SCRIPT)
        w_seq, w_bat, seq, bat = _tiny_pair(DistributionPolicy.SHARED)
        invocations = [Invocation("fn_a") for _ in range(8)]

        def mutating(watcher):
            state = {"i": 0}

            def on_decision(invocation, decision):
                if decision.scheduled:
                    watcher.record_admission(
                        decision.worker,
                        decision.controller,
                        invocation.function,
                    )
                if state["i"] == 2:
                    watcher.register_worker(
                        WorkerState(
                            name="late",
                            zone="z",
                            sets=frozenset({"pool", "any"}),
                            capacity_slots=4,
                        )
                    )
                elif state["i"] == 5:
                    watcher.deregister_worker("w1")
                state["i"] += 1

            return on_decision

        seq_cb = mutating(w_seq)
        seq_decisions = []
        for invocation in invocations:
            decision = seq.schedule(invocation, script, w_seq.cluster)
            seq_cb(invocation, decision)
            seq_decisions.append(decision)
        epoch_before = w_bat.cluster.topology_epoch
        bat_decisions = bat.schedule_batch(
            invocations, script, w_bat.cluster, on_decision=mutating(w_bat)
        )
        assert [_key(d) for d in seq_decisions] == [
            _key(d) for d in bat_decisions
        ]
        assert w_bat.cluster.topology_epoch > epoch_before
        assert any(d.worker == "late" for d in bat_decisions)
        assert seq._rng.getstate() == bat._rng.getstate()
        assert w_seq.cluster.load_seq == w_bat.cluster.load_seq


# ---------------------------------------------------------------------------
# Façade contracts (flat platform + federation), zone-sharded ledgers
# ---------------------------------------------------------------------------

FACADE_SPEC = ClusterSpec(
    controllers=(
        ControllerSpec("EdgeCtl", zone="edge"),
        ControllerSpec("CloudCtl", zone="cloud"),
    ),
    workers=(
        WorkerSpec("e0", zone="edge", sets=("edge", "any"), capacity_slots=2),
        WorkerSpec("e1", zone="edge", sets=("edge", "any"), capacity_slots=2),
        WorkerSpec("c0", zone="cloud", sets=("cloud", "any"),
                   capacity_slots=4),
    ),
)

FACADE_SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- edge_only:
  - controller: EdgeCtl
    workers:
    - set: edge
      strategy: random
  followup: default
"""


def _facade_platform():
    return TappPlatform(
        FACADE_SPEC,
        distribution=DistributionPolicy.SHARED,
        seed=0,
        policy=FACADE_SCRIPT,
    )


def _federation_spec():
    def zone(name, n):
        return ClusterSpec(
            controllers=(ControllerSpec(f"{name}Ctl", zone=name),),
            workers=tuple(
                WorkerSpec(
                    f"{name[0]}{i}",
                    zone=name,
                    sets=(name, "any"),
                    capacity_slots=2,
                )
                for i in range(n)
            ),
        )

    return FederationSpec.of({"east": zone("east", 3), "west": zone("west", 3)})


FED_SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
"""


class TestFacadeBatchContracts:
    def test_platform_invoke_batch_equals_invoke_loop(self):
        p_loop, p_bat = _facade_platform(), _facade_platform()
        invocations = [
            Invocation(FUNCTIONS[i % 3], tag="edge_only" if i % 4 == 0
                       else None)
            for i in range(12)
        ]
        loop_placements = [p_loop.invoke(inv) for inv in invocations]
        bat_placements = p_bat.invoke_batch(invocations)
        assert [_key(p.decision) for p in loop_placements] == [
            _key(p.decision) for p in bat_placements
        ]
        assert [p.admitted for p in loop_placements] == [
            p.admitted for p in bat_placements
        ]
        # Retire every other ticket on both sides: the zone-sharded
        # ledgers (PR-7) must agree shard by shard, not just in sum.
        for placement in loop_placements[::2]:
            placement.complete()
        for placement in bat_placements[::2]:
            placement.complete()
        assert p_loop.ledger_snapshot() == p_bat.ledger_snapshot()
        s_loop, s_bat = p_loop.stats(), p_bat.stats()
        assert (s_loop.routed, s_loop.admitted, s_loop.completed,
                s_loop.failed) == (s_bat.routed, s_bat.admitted,
                                   s_bat.completed, s_bat.failed)

    def test_platform_ledger_shards_sum_to_aggregate(self):
        p = _facade_platform()
        placements = p.invoke_batch(
            [Invocation(FUNCTIONS[i % 3]) for i in range(8)]
        )
        for placement in placements[:3]:
            placement.complete()
        snapshot = p.ledger_snapshot()
        stats = p.stats()
        assert sum(adm for adm, _, _ in snapshot.values()) == stats.admitted
        assert sum(cmp_ for _, cmp_, _ in snapshot.values()) \
            == stats.completed
        # Admissions landed on the workers' own zone shards.
        zones = {z for z, (adm, _, _) in snapshot.items() if adm}
        assert zones <= {"edge", "cloud"}

    def test_federation_invoke_batch_equals_invoke_loop(self):
        def build():
            return TappFederation(
                _federation_spec(), seed=0, policy=FED_SCRIPT
            )

        f_loop, f_bat = build(), build()
        invocations = [Invocation(FUNCTIONS[i % 3]) for i in range(10)]
        entry_zones = [("east", "west")[i % 2] for i in range(10)]
        loop_placements = [
            f_loop.invoke(inv, entry_zone=zone)
            for inv, zone in zip(invocations, entry_zones)
        ]
        bat_placements = f_bat.invoke_batch(
            invocations, entry_zones=entry_zones
        )
        assert [_key(p.decision) for p in loop_placements] == [
            _key(p.decision) for p in bat_placements
        ]
        assert [(p.entry_zone, p.hops) for p in loop_placements] == [
            (p.entry_zone, p.hops) for p in bat_placements
        ]
        assert f_loop.ledger_snapshot() == f_bat.ledger_snapshot()

    def test_armed_idle_overload_layer_keeps_batch_bit_identity(self):
        """PR 9: an OverloadSpec whose queue never fires must leave the
        batched invoke path bit-identical to an unarmed platform —
        decisions, ledger shards, and RNG-dependent stats alike."""
        from repro.core.platform import (
            BrownoutSpec,
            OverloadSpec,
            QueueSpec,
        )

        plain = _facade_platform()
        armed = TappPlatform(
            FACADE_SPEC,
            distribution=DistributionPolicy.SHARED,
            seed=0,
            policy=FACADE_SCRIPT,
            overload=OverloadSpec(
                queue=QueueSpec(depth=8, deadline=5.0),
                brownout=BrownoutSpec(),
            ),
        )
        # 8 invocations == total capacity: everything schedules, the
        # armed queue is never touched.
        invocations = [
            Invocation(FUNCTIONS[i % 3], tag="edge_only" if i % 4 == 0
                       else None)
            for i in range(8)
        ]
        plain_placements = plain.invoke_batch(invocations, now=0.0)
        armed_placements = armed.invoke_batch(invocations, now=0.0)
        assert [_key(p.decision) for p in plain_placements] == [
            _key(p.decision) for p in armed_placements
        ]
        assert all(not p.queued for p in armed_placements)
        for a, b in zip(plain_placements[::2], armed_placements[::2]):
            a.complete(now=1.0)
            b.complete(now=1.0)
        assert plain.ledger_snapshot() == armed.ledger_snapshot()
        armed_stats = armed.stats()
        assert armed_stats.queued == armed_stats.queue_depth == 0
        assert armed_stats.shed == armed_stats.brownout_reroutes == 0
        plain_stats = plain.stats()
        assert (plain_stats.routed, plain_stats.admitted,
                plain_stats.completed, plain_stats.failed) == (
            armed_stats.routed, armed_stats.admitted,
            armed_stats.completed, armed_stats.failed,
        )

    def test_federation_zone_stats_expose_ledger_shards(self):
        fed = TappFederation(_federation_spec(), seed=0, policy=FED_SCRIPT)
        placements = fed.invoke_batch(
            [Invocation("fn_a") for _ in range(8)],
            entry_zones=[("east", "west")[i % 2] for i in range(8)],
        )
        for placement in placements[:4]:
            placement.complete()
        snapshot = fed.ledger_snapshot()
        stats = fed.stats()
        for zone_name in ("east", "west"):
            zone = stats.zone(zone_name)
            admitted, completed, evicted = snapshot.get(zone_name, (0, 0, 0))
            assert (zone.admitted, zone.completed, zone.evicted) == (
                admitted, completed, evicted,
            )
        assert stats.aggregate.admitted == sum(
            adm for adm, _, _ in snapshot.values()
        )


WARM_FIRST_BATCH_SCRIPT = """
- default:
  - workers:
    - set:
      strategy: warm-first
- pinned:
  - workers:
    - set: edge
      strategy: warm-first
    - set: cloud
      strategy: warm-first
    strategy: warm-first
  followup: default
"""


class TestWarmFirstBatchBitIdentity:
    """PR 10: the batch kernel's warm-first bit-ops (warm & avail mask
    partitions) must reproduce the scalar path exactly while warmth is
    *live* — instances parking, being reused MRU, and expiring between
    batches."""

    def _armed(self):
        from repro.core.platform import LifecycleSpec

        return TappPlatform(
            FACADE_SPEC,
            distribution=DistributionPolicy.SHARED,
            seed=0,
            policy=WARM_FIRST_BATCH_SCRIPT,
            lifecycle=LifecycleSpec(keep_alive=15.0),
        )

    def test_warm_batches_equal_invoke_loop(self):
        p_loop, p_bat = self._armed(), self._armed()
        for rnd in range(6):
            # Rounds 0-2 run 10s apart (inside the 15s keep-alive, so
            # instances are reused); round 3 jumps 50s ahead, expiring
            # every parked instance through the batch path's janitor.
            now = 10.0 * rnd + (50.0 if rnd >= 3 else 0.0)
            invocations = [
                Invocation(FUNCTIONS[i % 2],
                           tag="pinned" if i % 3 == 0 else None)
                for i in range(6)
            ]
            loop_placements = [p_loop.invoke(inv, now=now)
                               for inv in invocations]
            bat_placements = p_bat.invoke_batch(invocations, now=now)
            assert [_key(p.decision) for p in loop_placements] == [
                _key(p.decision) for p in bat_placements
            ], rnd
            assert [p.warm_hit for p in loop_placements] == [
                p.warm_hit for p in bat_placements
            ], rnd
            # Retire everything so the next round sees parked warmth —
            # and, two rounds on (20s > keep_alive=15s), its expiry.
            for a, b in zip(loop_placements, bat_placements):
                a.complete(now=now + 1.0)
                b.complete(now=now + 1.0)
            assert p_loop.ledger_snapshot() == p_bat.ledger_snapshot(), rnd
            assert (p_loop.lifecycle_snapshot()
                    == p_bat.lifecycle_snapshot()), rnd
        assert (
            p_loop.gateway._engine.scheduling_state()
            == p_bat.gateway._engine.scheduling_state()
        )
        stats_loop, stats_bat = p_loop.stats(), p_bat.stats()
        assert (stats_loop.cold_starts, stats_loop.warm_hits,
                stats_loop.expirations) == (
            stats_bat.cold_starts, stats_bat.warm_hits,
            stats_bat.expirations,
        )
        # The sweep genuinely exercised both sides of the partition.
        assert stats_loop.warm_hits > 0
        assert stats_loop.expirations > 0


# ---------------------------------------------------------------------------
# jax backend
# ---------------------------------------------------------------------------


class TestJaxBackend:
    def test_jax_batch_matches_sequential(self):
        pytest.importorskip("jax")
        for trial in range(3):
            _assert_identical(
                *_run_pair(
                    trial, DistributionPolicy.DEFAULT, None, True, "jax"
                )
            )
