"""Compiled-vs-interpreted equivalence for the tAPP fast path.

The compiled engine (`TappEngine(compiled=True)`, the default) must
produce bit-identical placements AND traces to the reference interpreter
under a fixed seed, across randomized scripts, clusters, strategies, and
live-state churn. Also covers the epoch-cached topology views and the
`zone_restriction` regression.
"""
import random

import pytest

from repro.core.scheduler import (
    ClusterState,
    ControllerState,
    DistributionPolicy,
    Invocation,
    TappEngine,
    WorkerState,
    cached_view_entry,
    make_cluster,
)
from repro.core.scheduler.watcher import Watcher
from repro.core.tapp import compile_script, parse_tapp
from repro.core.tapp.ast import (
    Affinity,
    AntiAffinity,
    Block,
    CapacityUsed,
    ControllerClause,
    FollowupKind,
    MaxConcurrentInvocations,
    Overload,
    Strategy,
    TagPolicy,
    TappScript,
    TopologyTolerance,
    WorkerRef,
    WorkerSet,
)

ZONES = ("edge", "cloud", "far")
SET_LABELS = ("edge", "cloud", "far", "gpu", "any")
# WARM_FIRST rides the sweep with no lifecycle armed: every warm count
# is 0, so its partitions are the identity (and at tag level it degrades
# to best_first) — compiled, interpreted, and batch paths must all agree.
STRATEGIES = (None, Strategy.BEST_FIRST, Strategy.RANDOM, Strategy.PLATFORM,
              Strategy.WARM_FIRST)
CONDITIONS = (
    None,
    Overload(),
    CapacityUsed(50),
    CapacityUsed(80),
    MaxConcurrentInvocations(2),
    MaxConcurrentInvocations(8),
)
RUNNING_FNS = ("fn_a", "fn_b", "svc_cache", "noisy")
AFFINITIES = (
    None,
    None,  # weighted towards unconstrained items
    Affinity(("fn_a",)),
    Affinity(("svc_cache", "fn_b")),
)
ANTI_AFFINITIES = (
    None,
    None,
    AntiAffinity(("noisy",)),
    AntiAffinity(("fn_a", "noisy")),
)


# ---------------------------------------------------------------------------
# Randomized generators (plain `random`, seeded per trial — deterministic)
# ---------------------------------------------------------------------------


def random_cluster(rng: random.Random) -> ClusterState:
    cluster = ClusterState()
    for i in range(rng.randint(1, 3)):
        cluster.add_controller(
            ControllerState(
                name=f"C{i}",
                zone=rng.choice(ZONES),
                healthy=rng.random() > 0.2,
                reachable=rng.random() > 0.1,
            )
        )
    for i in range(rng.randint(1, 12)):
        sets = frozenset(
            l for l in SET_LABELS if rng.random() > 0.5
        )
        running = {
            fn: rng.randint(1, 3) for fn in RUNNING_FNS if rng.random() > 0.6
        }
        cluster.add_worker(
            WorkerState(
                name=f"w{i}",
                zone=rng.choice(ZONES),
                sets=sets,
                capacity_slots=rng.choice((1, 2, 4, 16)),
                inflight=rng.randint(0, 4),
                queued=rng.randint(0, 3),
                capacity_used_pct=rng.choice((0.0, 40.0, 60.0, 90.0, 100.0)),
                healthy=rng.random() > 0.25,
                reachable=rng.random() > 0.15,
                running_functions=running,
            )
        )
    return cluster


def random_block(rng: random.Random) -> Block:
    controller = None
    if rng.random() > 0.5:
        controller = ControllerClause(
            label=rng.choice(("C0", "C1", "C9")),  # C9: sometimes unknown
            topology_tolerance=rng.choice(tuple(TopologyTolerance)),
        )
    if rng.random() > 0.5:
        workers = tuple(
            WorkerRef(
                label=rng.choice(("w0", "w1", "w2", "w5", "ghost")),
                invalidate=rng.choice(CONDITIONS),
                affinity=rng.choice(AFFINITIES),
                anti_affinity=rng.choice(ANTI_AFFINITIES),
            )
            for _ in range(rng.randint(1, 3))
        )
    else:
        workers = tuple(
            WorkerSet(
                label=rng.choice((None,) + SET_LABELS),
                strategy=rng.choice(STRATEGIES),
                invalidate=rng.choice(CONDITIONS),
                affinity=rng.choice(AFFINITIES),
                anti_affinity=rng.choice(ANTI_AFFINITIES),
            )
            for _ in range(rng.randint(1, 3))
        )
    return Block(
        workers=workers,
        controller=controller,
        strategy=rng.choice(STRATEGIES),
        invalidate=rng.choice(CONDITIONS),
        affinity=rng.choice(AFFINITIES),
        anti_affinity=rng.choice(ANTI_AFFINITIES),
    )


def random_script(rng: random.Random) -> TappScript:
    tags = []
    if rng.random() > 0.2:  # usually include a default tag
        tags.append(
            TagPolicy(
                tag="default",
                blocks=tuple(random_block(rng) for _ in range(rng.randint(1, 2))),
                strategy=rng.choice(STRATEGIES),
            )
        )
    for name in ("alpha", "beta"):
        if rng.random() > 0.4:
            tags.append(
                TagPolicy(
                    tag=name,
                    blocks=tuple(
                        random_block(rng) for _ in range(rng.randint(1, 3))
                    ),
                    strategy=rng.choice(STRATEGIES),
                    followup=rng.choice((None, FollowupKind.FAIL, FollowupKind.DEFAULT)),
                )
            )
    if not tags:
        tags.append(
            TagPolicy(tag="default", blocks=(random_block(rng),))
        )
    return TappScript(tags=tuple(tags))


def mutate_cluster(rng: random.Random, watcher: Watcher) -> None:
    """Random live-state churn: load updates, health flips, membership."""
    cluster = watcher.cluster
    roll = rng.random()
    names = list(cluster.workers)
    if roll < 0.5 and names:
        # Volatile load update (must NOT invalidate cached views). Includes
        # the running-function multiset: the affinity signal is per-decision
        # churn, same as the inflight counters.
        name = rng.choice(names)
        watcher.update_worker(
            name,
            inflight=rng.randint(0, 5),
            queued=rng.randint(0, 3),
            capacity_used_pct=rng.choice((0.0, 55.0, 85.0, 100.0)),
            inflight_by={"C0": rng.randint(0, 2)},
            running_functions={
                fn: rng.randint(1, 3)
                for fn in RUNNING_FNS
                if rng.random() > 0.5
            },
        )
    elif roll < 0.7 and names:
        # Structural health/reachability transition.
        name = rng.choice(names)
        watcher.update_worker(
            name,
            healthy=rng.random() > 0.3,
            reachable=rng.random() > 0.2,
        )
    elif roll < 0.85:
        # Membership: add a worker.
        idx = len(names)
        watcher.register_worker(
            WorkerState(
                name=f"n{idx}_{rng.randint(0, 999)}",
                zone=rng.choice(ZONES),
                sets=frozenset(l for l in SET_LABELS if rng.random() > 0.5),
                capacity_slots=rng.choice((1, 4)),
            )
        )
    elif names:
        watcher.deregister_worker(rng.choice(names))


def assert_decisions_equal(d1, d2, context: str) -> None:
    assert d1.outcome == d2.outcome, context
    assert d1.worker == d2.worker, context
    assert d1.controller == d2.controller, context
    assert d1.tag == d2.tag, context
    assert d1.used_default_fallback == d2.used_default_fallback, context
    assert d1.zone_restriction == d2.zone_restriction, context
    assert d1.failed_by_policy == d2.failed_by_policy, context
    assert d1.trace == d2.trace, (
        context,
        "\n-- interpreted --\n" + d1.explain(),
        "\n-- compiled --\n" + d2.explain(),
    )


# ---------------------------------------------------------------------------
# Equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", list(DistributionPolicy))
def test_compiled_matches_interpreter_randomized(policy):
    """Placements, traces, RNG streams, and cursors stay bit-identical over
    decision sequences with interleaved cluster churn."""
    for trial in range(30):
        rng = random.Random(1000 * list(DistributionPolicy).index(policy) + trial)
        script = random_script(rng)
        watcher_i = Watcher(random_cluster(random.Random(trial)))
        watcher_c = Watcher(random_cluster(random.Random(trial)))
        interp = TappEngine(policy, seed=trial, compiled=False)
        comp = TappEngine(policy, seed=trial, compiled=True)
        mut_i, mut_c = random.Random(trial + 7), random.Random(trial + 7)
        for step in range(12):
            tag = rng.choice((None, "default", "alpha", "beta", "unknown"))
            inv = Invocation(function=rng.choice(("fn_a", "fn_b")), tag=tag)
            d1 = interp.schedule(inv, script, watcher_i.cluster, trace=True)
            d2 = comp.schedule(inv, script, watcher_c.cluster, trace=True)
            assert_decisions_equal(
                d1, d2, f"policy={policy} trial={trial} step={step} inv={inv}"
            )
            mutate_cluster(mut_i, watcher_i)
            mutate_cluster(mut_c, watcher_c)


def test_compiled_trace_off_same_placement():
    rng = random.Random(42)
    for trial in range(10):
        script = random_script(rng)
        cluster1 = random_cluster(random.Random(trial))
        cluster2 = random_cluster(random.Random(trial))
        traced = TappEngine(DistributionPolicy.SHARED, seed=5)
        fast = TappEngine(DistributionPolicy.SHARED, seed=5)
        for _ in range(6):
            inv = Invocation("fn", tag=rng.choice((None, "alpha")))
            d1 = traced.schedule(inv, script, cluster1, trace=True)
            d2 = fast.schedule(inv, script, cluster2)  # default: no trace
            assert d2.trace == []
            assert (d1.outcome, d1.worker, d1.controller, d1.zone_restriction) == (
                d2.outcome, d2.worker, d2.controller, d2.zone_restriction
            )


def test_schedule_batch_matches_sequential():
    rng = random.Random(9)
    script = random_script(rng)
    cluster_a = random_cluster(random.Random(3))
    cluster_b = random_cluster(random.Random(3))
    seq = TappEngine(DistributionPolicy.DEFAULT, seed=1)
    bat = TappEngine(DistributionPolicy.DEFAULT, seed=1)
    invs = [
        Invocation(f"fn{i % 3}", tag=rng.choice((None, "alpha", "beta")))
        for i in range(20)
    ]
    sequential = [seq.schedule(i, script, cluster_a, trace=True) for i in invs]
    seen = []
    batched = bat.schedule_batch(
        invs, script, cluster_b, trace=True,
        on_decision=lambda inv, d: seen.append(inv),
    )
    assert seen == invs  # callback fired per decision, in order
    for i, (d1, d2) in enumerate(zip(sequential, batched)):
        assert_decisions_equal(d1, d2, f"batch idx={i}")


# ---------------------------------------------------------------------------
# Stateful constraints: batch scheduling vs sequential with admissions
# ---------------------------------------------------------------------------


AFFINITY_SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- spread:
  - workers:
    - set:
    strategy: best_first
    invalidate: overload
    anti-affinity: [fn_s]
  - workers:
    - set:
  followup: default
- pinned:
  - workers:
    - set:
    strategy: best_first
    invalidate: overload
    affinity: [fn_s]
  followup: default
"""


def _affinity_watcher():
    return Watcher(
        make_cluster(
            workers=[
                dict(name=f"w{i}", zone="z", capacity_slots=8)
                for i in range(4)
            ],
            controllers=[dict(name="C0", zone="z")],
        )
    )


@pytest.mark.parametrize("compiled", [False, True])
def test_schedule_batch_stateful_affinity_matches_sequential(compiled):
    """Affinity/anti-affinity read state that earlier placements in the
    SAME batch mutate (admissions fired from on_decision): batch results
    must stay bit-identical to sequential schedule+admit calls."""
    from repro.core.scheduler import ControllerRuntime

    script = parse_tapp(AFFINITY_SCRIPT)
    invs = [
        Invocation("fn_s", tag="spread", request_id=i) for i in range(6)
    ] + [
        Invocation("fn_p", tag="pinned", request_id=10 + i) for i in range(3)
    ] + [
        Invocation("fn_s", tag="spread", request_id=20)
    ]

    w_seq = _affinity_watcher()
    seq_engine = TappEngine(DistributionPolicy.SHARED, seed=3, compiled=compiled)
    seq_rt = ControllerRuntime(w_seq)
    sequential = []
    for inv in invs:
        d = seq_engine.schedule(inv, script, w_seq.cluster, trace=True)
        if d.scheduled:
            seq_rt.admit(d.worker, d.controller, function=inv.function)
        sequential.append(d)

    w_bat = _affinity_watcher()
    bat_engine = TappEngine(DistributionPolicy.SHARED, seed=3, compiled=compiled)
    bat_rt = ControllerRuntime(w_bat)

    def _admit(inv, decision):
        if decision.scheduled:
            bat_rt.admit(
                decision.worker, decision.controller, function=inv.function
            )

    batched = bat_engine.schedule_batch(
        invs, script, w_bat.cluster, trace=True, on_decision=_admit
    )

    for i, (d1, d2) in enumerate(zip(sequential, batched)):
        assert_decisions_equal(d1, d2, f"stateful batch idx={i}")
    for name in w_seq.cluster.workers:
        ws = w_seq.cluster.workers[name]
        wb = w_bat.cluster.workers[name]
        assert ws.running_functions == wb.running_functions, name
        assert ws.inflight == wb.inflight, name

    # The policy did real work: the first four spread invocations must land
    # on four distinct workers (anti-affinity seeing same-batch placements),
    # and pinned ones only where fn_s already runs.
    spread_workers = [d.worker for d in batched[:4]]
    assert len(set(spread_workers)) == 4
    for d in batched[6:9]:
        assert d.scheduled
        assert w_bat.cluster.workers[d.worker].running_count("fn_s") > 0


def test_compiled_constraint_shapes():
    """Affinity clauses resolve item ▸ block and lower into the pre-bound
    invalid() closure."""
    script = parse_tapp(
        """
- t:
  - workers:
    - wrk: w0
      affinity: [warm]
    - wrk: w1
    invalidate: capacity_used 50%
    anti-affinity: [noisy]
"""
    )
    plan = compile_script(script)
    block = plan.tags["t"].blocks[0]
    w0, w1 = block.wrks
    assert w0.spec.affinity == Affinity(("warm",))
    assert w0.spec.anti_affinity == AntiAffinity(("noisy",))  # block-level
    assert w1.spec.affinity is None
    assert w1.spec.anti_affinity == AntiAffinity(("noisy",))
    assert w0.condition == CapacityUsed(50)  # legacy accessor still works

    idle = WorkerState(name="x")
    warm = WorkerState(name="y", running_functions={"warm": 1})
    noisy = WorkerState(name="z", running_functions={"warm": 1, "noisy": 2})
    assert w0.invalid(idle)        # affinity unmet
    assert not w0.invalid(warm)
    assert w0.invalid(noisy)       # anti-affinity hit
    assert not w1.invalid(idle)    # no affinity requirement
    assert w1.invalid(noisy)


def test_compile_script_shapes():
    script = parse_tapp(
        """
- default:
  - workers:
    - set:
    strategy: platform
- edge:
  - controller: EdgeCtl
    workers:
    - wrk: w0
      invalidate: capacity_used 50%
    - wrk: w1
    topology_tolerance: same
    invalidate: max_concurrent_invocations 4
  followup: default
"""
    )
    plan = compile_script(script)
    assert set(plan.tags) == {"default", "edge"}
    assert plan.default is plan.tags["default"]
    edge = plan.tags["edge"]
    assert edge.followup is FollowupKind.DEFAULT
    assert edge.sticky_same_labels == ("EdgeCtl",)
    block = edge.blocks[0]
    assert not block.uses_sets
    # Item-level condition overrides block-level; block-level fills the rest.
    assert block.wrks[0].condition == CapacityUsed(50)
    assert block.wrks[1].condition == MaxConcurrentInvocations(4)
    # Pre-bound predicates agree with the conditions.
    w = WorkerState(name="x", capacity_used_pct=60.0, inflight=1, queued=1)
    assert block.wrks[0].invalid(w)
    assert not block.wrks[1].invalid(w)
    d = plan.tags["default"].blocks[0]
    assert d.uses_sets and d.sets[0].strategy is Strategy.PLATFORM


# ---------------------------------------------------------------------------
# Indexed fast path: ledger churn, saturation, epoch bumps
# ---------------------------------------------------------------------------


def assert_placements_equal(d1, d2, context: str) -> None:
    """Trace-free comparison (the indexed fast path carries no trace)."""
    assert d1.outcome == d2.outcome, context
    assert d1.worker == d2.worker, context
    assert d1.controller == d2.controller, context
    assert d1.tag == d2.tag, context
    assert d1.used_default_fallback == d2.used_default_fallback, context
    assert d1.zone_restriction == d2.zone_restriction, context
    assert d1.failed_by_policy == d2.failed_by_policy, context


@pytest.mark.parametrize("policy", list(DistributionPolicy))
def test_indexed_matches_interpreter_under_ledger_churn(policy):
    """Interpreter (traced), compiled traced, and compiled *indexed*
    (trace=False) engines stay bit-identical — placements AND RNG
    streams — while admissions/completions churn through the watcher
    ledger, workers saturate and free up, and topology epochs bump."""
    for trial in range(25):
        rng = random.Random(5000 + 31 * list(DistributionPolicy).index(policy) + trial)
        script = random_script(rng)
        watchers = [Watcher(random_cluster(random.Random(trial))) for _ in range(3)]
        engines = [
            TappEngine(policy, seed=trial, compiled=False),
            TappEngine(policy, seed=trial, compiled=True),
            TappEngine(policy, seed=trial, compiled=True),
        ]
        outstanding = []  # (worker, controller, function) tickets
        for step in range(40):
            tag = rng.choice((None, "default", "alpha", "beta", "unknown"))
            fn = rng.choice(("fn_a", "fn_b", "svc_cache"))
            inv = Invocation(function=fn, tag=tag)
            ctx = f"policy={policy} trial={trial} step={step} inv={inv}"
            d_interp = engines[0].schedule(
                inv, script, watchers[0].cluster, trace=True
            )
            d_traced = engines[1].schedule(
                inv, script, watchers[1].cluster, trace=True
            )
            d_indexed = engines[2].schedule(
                inv, script, watchers[2].cluster
            )  # trace=False → indexed fast path
            assert_decisions_equal(d_interp, d_traced, ctx)
            assert d_indexed.trace == []
            assert_placements_equal(d_interp, d_indexed, ctx)

            # Admit the placement on every replica of the cluster, so the
            # index's availability bits are exercised by the ledger.
            if d_interp.scheduled:
                for w in watchers:
                    w.record_admission(
                        d_interp.worker, d_interp.controller or "?", fn
                    )
                outstanding.append(
                    (d_interp.worker, d_interp.controller or "?", fn)
                )

            roll = rng.random()
            if roll < 0.35 and outstanding:
                # Complete a random outstanding ticket on all replicas.
                ticket = outstanding.pop(rng.randrange(len(outstanding)))
                for w in watchers:
                    w.record_completion(*ticket)
            elif roll < 0.45:
                # Structural churn: epoch bump (indexes rebuilt). Draw the
                # worker's shape once, then build one fresh (unshared)
                # WorkerState per cluster replica.
                name = f"x{trial}_{step}"
                zone = rng.choice(ZONES)
                sets = frozenset(l for l in SET_LABELS if rng.random() > 0.5)
                slots = rng.choice((1, 2, 4))
                for w in watchers:
                    w.register_worker(
                        WorkerState(
                            name=name, zone=zone, sets=sets,
                            capacity_slots=slots,
                        )
                    )
            elif roll < 0.55:
                names = list(watchers[0].cluster.workers)
                if names:
                    victim = rng.choice(names)
                    for w in watchers:
                        w.deregister_worker(victim)
                    outstanding = [t for t in outstanding if t[0] != victim]
            elif roll < 0.7:
                # Volatile heartbeat (no epoch bump; index bits refresh).
                names = list(watchers[0].cluster.workers)
                if names:
                    name = rng.choice(names)
                    fields = dict(
                        capacity_used_pct=rng.choice((0.0, 55.0, 85.0, 100.0)),
                        queued=rng.randint(0, 3),
                    )
                    for w in watchers:
                        w.update_worker(name, **fields)


@pytest.mark.parametrize("compiled", [False, True])
def test_full_saturation_then_release_bit_identical(compiled):
    """Saturating every worker makes decisions fail on all paths; a
    single completion revives exactly the freed worker everywhere."""
    script = parse_tapp(
        """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
"""
    )
    watcher = Watcher(
        make_cluster(
            workers=[
                dict(name=f"w{i}", zone="z", sets=["any"], capacity_slots=2)
                for i in range(6)
            ],
            controllers=[dict(name="C0", zone="z")],
        )
    )
    ref = TappEngine(DistributionPolicy.SHARED, seed=0, compiled=False)
    eng = TappEngine(DistributionPolicy.SHARED, seed=0, compiled=compiled)
    inv = Invocation("fn")

    placed = []
    while True:
        d_ref = ref.schedule(inv, script, watcher.cluster, trace=True)
        d = eng.schedule(inv, script, watcher.cluster)
        assert (d.outcome, d.worker) == (d_ref.outcome, d_ref.worker)
        if not d.scheduled:
            break
        watcher.record_admission(d.worker, d.controller or "?", "fn")
        placed.append((d.worker, d.controller or "?"))
    assert len(placed) == 12  # 6 workers x 2 slots, all consumed
    assert d.failed_by_policy

    # Saturated cluster: repeated decisions keep failing identically (and
    # on the indexed path this is the O(1) empty-mask case).
    for _ in range(5):
        d_ref = ref.schedule(inv, script, watcher.cluster, trace=True)
        d = eng.schedule(inv, script, watcher.cluster)
        assert not d.scheduled and not d_ref.scheduled

    # One completion frees exactly one slot; both paths find it.
    worker, controller = placed[7]
    watcher.record_completion(worker, controller, "fn")
    d_ref = ref.schedule(inv, script, watcher.cluster, trace=True)
    d = eng.schedule(inv, script, watcher.cluster)
    assert d_ref.scheduled and d.scheduled
    assert d.worker == worker == d_ref.worker


def test_index_refresh_survives_load_log_compaction():
    """Blowing past the load-log limit forces the full-rebuild fallback;
    availability stays correct."""
    from repro.core.scheduler.state import _LOAD_LOG_LIMIT

    script = parse_tapp(
        "- default:\n  - workers:\n    - set:\n    invalidate: overload\n"
    )
    watcher = Watcher(
        make_cluster(
            workers=[
                dict(name="w0", zone="z", sets=["any"], capacity_slots=1),
                dict(name="w1", zone="z", sets=["any"], capacity_slots=1),
            ],
            controllers=[dict(name="C0", zone="z")],
        )
    )
    eng = TappEngine(DistributionPolicy.SHARED, seed=0, compiled=True)
    inv = Invocation("fn")
    d = eng.schedule(inv, script, watcher.cluster)
    assert d.worker == "w0"
    # Saturate w0, then churn the log far past the compaction limit.
    watcher.record_admission("w0", "C0", "fn")
    for _ in range(_LOAD_LOG_LIMIT + 10):
        watcher.record_admission("w1", "C0", "fn")
        watcher.record_completion("w1", "C0", "fn")
    assert watcher.cluster.load_trimmed > 0  # compaction actually happened
    d = eng.schedule(inv, script, watcher.cluster)
    assert d.worker == "w1"
    watcher.record_completion("w0", "C0", "fn")
    d = eng.schedule(inv, script, watcher.cluster)
    assert d.worker == "w0"


def test_split_spec_halves_agree_with_compiled_spec():
    """static(w) ∨ dynamic(w) == compile_spec(spec)(w) over randomized
    specs and worker states (the index-layer soundness contract)."""
    from repro.core.scheduler.constraints import (
        ConstraintSpec,
        compile_spec,
        split_spec,
    )

    rng = random.Random(99)
    for trial in range(300):
        spec = ConstraintSpec(
            invalidate=rng.choice(tuple(c for c in CONDITIONS if c is not None)),
            affinity=rng.choice(AFFINITIES),
            anti_affinity=rng.choice(ANTI_AFFINITIES),
        )
        worker = WorkerState(
            name="w",
            capacity_slots=rng.choice((1, 2, 4)),
            inflight=rng.randint(0, 5),
            queued=rng.randint(0, 3),
            capacity_used_pct=rng.choice((0.0, 40.0, 60.0, 90.0, 100.0)),
            healthy=rng.random() > 0.3,
            reachable=rng.random() > 0.3,
            running_functions={
                fn: rng.randint(1, 2) for fn in RUNNING_FNS if rng.random() > 0.5
            },
        )
        static_fn, dyn_fn = split_spec(spec)
        fused = compile_spec(spec)
        assert (static_fn(worker) or dyn_fn(worker)) == fused(worker), (
            spec,
            worker,
        )


# ---------------------------------------------------------------------------
# Epoch-cached topology views
# ---------------------------------------------------------------------------


class TestTopologyEpoch:
    def _watcher(self):
        cluster = make_cluster(
            workers=[
                dict(name="e0", zone="edge", sets=["edge", "any"]),
                dict(name="c0", zone="cloud", sets=["cloud", "any"]),
            ],
            controllers=[dict(name="C0", zone="edge")],
        )
        return Watcher(cluster)

    def test_load_updates_do_not_bump_epoch(self):
        w = self._watcher()
        epoch = w.cluster.topology_epoch
        w.update_worker("e0", inflight=3, capacity_used_pct=75.0,
                        inflight_by={"C0": 3})
        assert w.cluster.topology_epoch == epoch

    def test_structural_updates_bump_epoch(self):
        w = self._watcher()
        epoch = w.cluster.topology_epoch
        w.update_worker("e0", healthy=False)
        assert w.cluster.topology_epoch == epoch + 1
        w.update_worker("e0", zone="cloud")
        assert w.cluster.topology_epoch == epoch + 2
        # No-op write of the same value is not a transition.
        w.update_worker("e0", zone="cloud")
        assert w.cluster.topology_epoch == epoch + 2

    def test_membership_bumps_epoch_and_clears_cache(self):
        w = self._watcher()
        entry = cached_view_entry(
            w.cluster, "edge", DistributionPolicy.SHARED, controller_name="C0"
        )
        assert (
            cached_view_entry(
                w.cluster, "edge", DistributionPolicy.SHARED, controller_name="C0"
            )
            is entry
        )
        w.register_worker(WorkerState(name="e1", zone="edge"))
        fresh = cached_view_entry(
            w.cluster, "edge", DistributionPolicy.SHARED, controller_name="C0"
        )
        assert fresh is not entry
        assert "e1" in fresh.by_name

    def test_view_entry_reads_live_load(self):
        w = self._watcher()
        entry = cached_view_entry(
            w.cluster, "edge", DistributionPolicy.SHARED, controller_name="C0"
        )
        view = entry.by_name["e0"]
        assert not view.saturated
        w.update_worker("e0", inflight=16, inflight_by={"C0": 16})
        # Same cached entry object, but the live WorkerState shows the load.
        assert entry.by_name["e0"] is view
        assert view.saturated

    def test_set_members_cached_and_ordered_local_first(self):
        w = self._watcher()
        entry = cached_view_entry(
            w.cluster, "edge", DistributionPolicy.SHARED, controller_name="C0"
        )
        local, foreign = entry.set_members("any")
        assert [v.worker.name for v in local] == ["e0"]
        assert [v.worker.name for v in foreign] == ["c0"]
        assert entry.set_members("any") == (local, foreign)


# ---------------------------------------------------------------------------
# Batch admission
# ---------------------------------------------------------------------------


def test_admit_many_equals_sequential_admissions():
    from repro.core.scheduler import AdmissionError, ControllerRuntime

    def fresh():
        cluster = make_cluster(
            workers=[
                dict(name="w0", zone="z", capacity_slots=8),
                dict(name="w1", zone="z", capacity_slots=8),
            ],
            controllers=[dict(name="C0", zone="z"), dict(name="C1", zone="z")],
        )
        return Watcher(cluster)

    placements = [("w0", "C0"), ("w0", "C1"), ("w1", "C0"), ("w0", "C0")]

    w_seq, w_bat = fresh(), fresh()
    seq_rt, bat_rt = ControllerRuntime(w_seq), ControllerRuntime(w_bat)
    seq = [seq_rt.admit(w, c) for w, c in placements]
    bat = bat_rt.admit_many(placements)

    assert [(a.worker, a.controller) for a in bat] == placements
    assert [a.invocation_id for a in bat] == [a.invocation_id for a in seq]
    for name in ("w0", "w1"):
        ws, wb = w_seq.cluster.workers[name], w_bat.cluster.workers[name]
        assert (ws.inflight, ws.inflight_by, ws.capacity_used_pct) == (
            wb.inflight, wb.inflight_by, wb.capacity_used_pct
        )
    # Completion releases batch tickets exactly like sequential ones.
    for a in bat:
        bat_rt.complete(a)
    assert w_bat.cluster.workers["w0"].inflight == 0

    # Validate-before-mutate: a bad placement leaves the cluster untouched.
    w_err = fresh()
    err_rt = ControllerRuntime(w_err)
    with pytest.raises(AdmissionError):
        err_rt.admit_many([("w0", "C0"), ("ghost", "C0")])
    assert w_err.cluster.workers["w0"].inflight == 0


# ---------------------------------------------------------------------------
# zone_restriction regression (overwritten by earlier failed blocks)
# ---------------------------------------------------------------------------


SCRIPT_ZONE = """
- default:
  - workers:
    - set:
- t:
  - controller: EdgeCtl
    workers:
    - set:
    topology_tolerance: same
  - workers:
    - set:
  followup: fail
"""


@pytest.mark.parametrize("compiled", [False, True])
class TestZoneRestrictionReflectsSchedulingBlock:
    def _cluster(self):
        return make_cluster(
            workers=[
                dict(name="e0", zone="edge", sets=["any"], reachable=False),
                dict(name="c0", zone="cloud", sets=["any"]),
            ],
            controllers=[
                dict(name="EdgeCtl", zone="edge", healthy=False),
                dict(name="CloudCtl", zone="cloud"),
            ],
        )

    def test_scheduled_block_restriction_wins(self, compiled):
        # Block 1 (tolerance=same → restricted to 'edge') fails: e0 is
        # unreachable. Block 2 has no controller clause and schedules c0
        # unrestricted — the decision must NOT report the stale 'edge'
        # restriction from the failed block.
        cluster = self._cluster()
        engine = TappEngine(
            DistributionPolicy.SHARED, seed=0, compiled=compiled
        )
        d = engine.schedule(
            Invocation("f", tag="t"), parse_tapp(SCRIPT_ZONE), cluster,
            trace=True,
        )
        assert d.scheduled and d.worker == "c0"
        assert d.zone_restriction is None

    def test_failure_keeps_last_evaluated_restriction(self, compiled):
        # Remove the rescue block: with only the restricted block, failure
        # reports the last evaluated restriction (diagnostic value).
        script = parse_tapp(
            """
- t:
  - controller: EdgeCtl
    workers:
    - set:
    topology_tolerance: same
  followup: fail
"""
        )
        cluster = self._cluster()
        engine = TappEngine(
            DistributionPolicy.SHARED, seed=0, compiled=compiled
        )
        d = engine.schedule(Invocation("f", tag="t"), script, cluster)
        assert not d.scheduled
        assert d.zone_restriction == "edge"
        assert d.failed_by_policy
