"""Deployment API v2 (federation) tests.

Covers the PR-5 contracts:
* a single-zone ``TappFederation`` makes bit-identical decisions
  (placements + traces + RNG streams) to the flat ``TappPlatform`` on
  the same spec/policy/seed, under live churn;
* ``topology_tolerance: none`` / ``same`` never produce a placement
  outside the designated controller's zone, under saturation churn and
  from every entrypoint;
* cross-zone forwarding: spills happen, hops are recorded and priced,
  stats/explain expose them;
* the drain-path deregistration fix: removing a loaded worker does not
  strand admission ledger tickets.
"""
import random

import pytest

from repro.core.platform import (
    BreakerSpec,
    BrownoutSpec,
    ClusterSpec,
    ControllerSpec,
    FederationSpec,
    OverloadSpec,
    QueueSpec,
    RetryPolicy,
    TappFederation,
    TappPlatform,
    WorkerSpec,
)
from repro.core.scheduler.topology import DistributionPolicy


class Net:
    """Minimal duck-typed network model (symmetric constant RTT)."""

    def __init__(self, rtt=0.04, table=None):
        self._rtt = rtt
        self._table = table or {}

    def get_rtt(self, a, b):
        if a == b:
            return 0.0
        return self._table.get((a, b), self._table.get((b, a), self._rtt))


MULTI_TAG_SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- spread:
  - workers:
    - set: east
    strategy: random
    invalidate: capacity_used 60%
    anti-affinity: [noisy]
  - workers:
    - set: west
      strategy: random
  followup: default
- strict:
  - workers:
    - set: east
    strategy: best_first
    invalidate: max_concurrent_invocations 2
  followup: fail
"""


def _single_zone_spec(n_workers=6):
    return FederationSpec.of({
        "z0": ClusterSpec(
            controllers=(
                ControllerSpec("C1"),
                ControllerSpec("C2"),
            ),
            workers=tuple(
                WorkerSpec(
                    f"w{i}",
                    sets=("east" if i % 2 == 0 else "west", "any"),
                    capacity_slots=3,
                )
                for i in range(n_workers)
            ),
        ),
    })


def _assert_same_decision(d1, d2, context):
    assert d1.outcome == d2.outcome, context
    assert d1.worker == d2.worker, context
    assert d1.controller == d2.controller, context
    assert d1.tag == d2.tag, context
    assert d1.used_default_fallback == d2.used_default_fallback, context
    assert d1.failed_by_policy == d2.failed_by_policy, context
    assert d1.trace == d2.trace, (
        context,
        "\n-- flat --\n" + d1.explain(),
        "\n-- federated --\n" + d2.explain(),
    )


class TestSingleZoneEquivalence:
    @pytest.mark.parametrize(
        "policy", [DistributionPolicy.SHARED, DistributionPolicy.DEFAULT]
    )
    def test_bit_identical_to_flat_platform_under_churn(self, policy):
        """Placements, traces, and RNG streams match the flat platform
        decision-for-decision, with drains, heartbeats, and completions
        interleaved."""
        for trial in range(8):
            spec = _single_zone_spec()
            flat = TappPlatform(
                spec.merged(), distribution=policy, seed=trial,
                policy=MULTI_TAG_SCRIPT,
            )
            fed = TappFederation(
                spec, distribution=policy, seed=trial,
                policy=MULTI_TAG_SCRIPT,
            )
            rng = random.Random(100 + trial)
            live = []
            for step in range(40):
                tag = rng.choice((None, "spread", "strict", "unknown"))
                fn = rng.choice(("fn_a", "fn_b", "noisy"))
                p1 = flat.invoke(fn, tag=tag, trace=True)
                p2 = fed.invoke(fn, tag=tag, trace=True)
                context = f"policy={policy} trial={trial} step={step}"
                _assert_same_decision(p1.decision, p2.decision, context)
                assert p2.hops == (), context  # single zone never forwards
                if p1.admitted:
                    live.append((p1, p2))
                roll = rng.random()
                if roll < 0.2 and live:
                    a, b = live.pop(rng.randrange(len(live)))
                    a.complete()
                    b.complete()
                elif roll < 0.3:
                    name = f"w{rng.randrange(6)}"
                    flat.drain(name)
                    fed.drain(name)
                elif roll < 0.4:
                    name = f"w{rng.randrange(6)}"
                    flat.restore(name)
                    fed.restore(name)
                elif roll < 0.5:
                    name = f"w{rng.randrange(6)}"
                    pct = rng.choice((10.0, 70.0, 95.0))
                    flat.heartbeat(name, capacity_used_pct=pct)
                    fed.heartbeat(name, capacity_used_pct=pct)
            # The engines consumed identical RNG streams and cursors.
            flat_state = flat.gateway._engine.scheduling_state()
            fed_state = fed.zone_gateway("z0")._engine.scheduling_state()
            assert flat_state == fed_state

    def test_single_zone_stats_match_flat(self):
        spec = _single_zone_spec()
        flat = TappPlatform(spec.merged(), seed=0, policy=MULTI_TAG_SCRIPT)
        fed = TappFederation(spec, seed=0, policy=MULTI_TAG_SCRIPT)
        for _ in range(10):
            flat.invoke("fn", tag="spread")
            fed.invoke("fn", tag="spread")
        fs = flat.stats()
        agg = fed.stats().aggregate
        assert (fs.routed, fs.failed, fs.admitted, fs.inflight) == (
            agg.routed, agg.failed, agg.admitted, agg.inflight
        )
        assert fed.stats().forwards == 0


TWO_ZONE_NET = Net(table={("za", "zb"): 0.05})


def _two_zone_spec(slots=2, *, default_entry=None):
    def zone(name, ctl):
        return ClusterSpec(
            controllers=(ControllerSpec(ctl),),
            workers=tuple(
                WorkerSpec(f"{name}_w{i}", sets=(name, "any"),
                           capacity_slots=slots)
                for i in range(2)
            ),
        )

    return FederationSpec.of(
        {"za": zone("za", "ACtl"), "zb": zone("zb", "BCtl")},
        network=TWO_ZONE_NET,
        default_entry=default_entry,
    )


PINNED_NONE_SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- pinned:
  - controller: ACtl
    workers:
    - set:
    topology_tolerance: none
  followup: fail
"""

PINNED_SAME_SCRIPT = PINNED_NONE_SCRIPT.replace(
    "topology_tolerance: none", "topology_tolerance: same"
)


class TestToleranceEnforcement:
    def test_none_never_crosses_designated_zone_under_saturation_churn(self):
        """`tolerance: none` placements only ever land in the designated
        controller's zone, from both entrypoints, while the cluster
        saturates and drains randomly."""
        fed = TappFederation(
            _two_zone_spec(slots=1),
            distribution=DistributionPolicy.SHARED,
            seed=3,
            policy=PINNED_NONE_SCRIPT,
        )
        rng = random.Random(42)
        live = []
        scheduled = failed = 0
        for step in range(200):
            entry = rng.choice(("za", "zb"))
            placement = fed.invoke("locked", entry_zone=entry, tag="pinned")
            if placement.scheduled:
                scheduled += 1
                zone = fed.cluster.workers[placement.worker].zone
                assert zone == "za", (step, entry, placement.worker)
                live.append(placement)
            else:
                failed += 1
                assert placement.failed_by_policy
            while live and rng.random() < 0.6:
                live.pop(rng.randrange(len(live))).complete()
        assert scheduled > 0 and failed > 0  # churn hit both outcomes

    def test_none_fails_outright_when_designated_controller_down(self):
        fed = TappFederation(
            _two_zone_spec(), seed=0, policy=PINNED_NONE_SCRIPT,
            distribution=DistributionPolicy.SHARED,
        )
        fed.watcher.update_controller("ACtl", healthy=False)
        for entry in ("za", "zb"):
            placement = fed.invoke("locked", entry_zone=entry, tag="pinned")
            assert not placement.scheduled
            assert placement.failed_by_policy

    def test_same_stays_in_designated_zone_via_alternative_controller(self):
        """With the designated controller down, `same` lets another zone's
        controller manage the work but execution stays in the home zone."""
        fed = TappFederation(
            _two_zone_spec(), seed=0, policy=PINNED_SAME_SCRIPT,
            distribution=DistributionPolicy.SHARED,
        )
        fed.watcher.update_controller("ACtl", healthy=False)
        placements = [
            fed.invoke("locked", entry_zone=entry, tag="pinned")
            for entry in ("za", "zb", "zb", "za")
        ]
        for placement in placements:
            assert placement.scheduled
            assert fed.cluster.workers[placement.worker].zone == "za"
            assert placement.controller == "BCtl"  # the alternative manages
        # From zb the placement crossed into za: the hop is on the record.
        zb_entry = placements[1]
        assert zb_entry.forwarded or zb_entry.hops
        assert zb_entry.forward_rtt > 0


FORWARDING_SCRIPT = """
- default:
  - workers:
    - set:
    strategy: best_first
    invalidate: overload
"""


class TestForwarding:
    def test_spill_across_zones_with_hops_stats_and_explain(self):
        fed = TappFederation(
            _two_zone_spec(slots=1),
            distribution=DistributionPolicy.SHARED,
            seed=0,
            policy=FORWARDING_SCRIPT,
        )
        # Fill za (2 workers × 1 slot), entering za.
        local = [fed.invoke("fn", entry_zone="za") for _ in range(2)]
        for placement in local:
            assert fed.cluster.workers[placement.worker].zone == "za"
            assert placement.hops == ()
        # Third request spills to zb, paying the 50ms hop.
        spilled = fed.invoke("fn", entry_zone="za")
        assert spilled.scheduled
        assert fed.cluster.workers[spilled.worker].zone == "zb"
        assert spilled.forwarded
        assert spilled.forward_rtt == pytest.approx(0.05)
        assert [h.to_zone for h in spilled.hops] == ["zb"]

        stats = fed.stats()
        assert stats.forwards == 1
        assert stats.forward_attempts >= 1
        assert stats.cross_zone_rtt == pytest.approx(0.05)
        assert stats.zone("za").forwarded_out == 1
        assert stats.zone("zb").forwarded_in == 1
        assert stats.zone("za").entered == 3

        report = fed.explain("fn", entry_zone="za")
        assert report.scheduled and report.forwarded
        assert report.placement_zone == "zb"
        assert [h.zone for h in report.hops] == ["za", "zb"]
        assert not report.hops[0].forwarded and report.hops[1].forwarded
        assert report.forward_rtt == pytest.approx(0.05)
        # Entry-zone rejections are part of the hop report.
        assert any(w.startswith("za_") for w in report.rejections())
        # explain() was side-effect-free: stats unchanged.
        assert fed.stats().forward_attempts == stats.forward_attempts

    def test_exhausted_federation_reports_unplaced(self):
        fed = TappFederation(
            _two_zone_spec(slots=1),
            distribution=DistributionPolicy.SHARED,
            seed=0,
            policy=FORWARDING_SCRIPT,
        )
        placements = [fed.invoke("fn", entry_zone="za") for _ in range(5)]
        assert sum(p.scheduled for p in placements) == 4  # 2 zones × 2w × 1
        last = placements[-1]
        assert not last.scheduled
        assert [h.scheduled for h in last.hops] == [False]
        assert fed.stats().unplaced == 1

    def test_vanilla_fallback_is_zone_local_then_forwarded(self):
        """No policy: the zone-local pass runs vanilla over the entry
        zone's workers, and forwarding is unbounded (vanilla has no
        tolerance to honour)."""
        fed = TappFederation(
            _two_zone_spec(slots=1),
            distribution=DistributionPolicy.SHARED, seed=0,
        )
        local = [fed.invoke("fn", entry_zone="za") for _ in range(2)]
        assert all(
            fed.cluster.workers[p.worker].zone == "za" for p in local
        )
        spilled = fed.invoke("fn", entry_zone="za")
        assert spilled.scheduled
        assert fed.cluster.workers[spilled.worker].zone == "zb"
        assert spilled.forwarded

    def test_invoke_batch_matches_sequential(self):
        entries = ["za", "zb", "za", "za", "zb", None]
        functions = [f"fn{i % 3}" for i in range(len(entries))]

        fed_seq = TappFederation(
            _two_zone_spec(slots=1), seed=5, policy=MULTI_TAG_SCRIPT,
            distribution=DistributionPolicy.SHARED,
        )
        fed_batch = TappFederation(
            _two_zone_spec(slots=1), seed=5, policy=MULTI_TAG_SCRIPT,
            distribution=DistributionPolicy.SHARED,
        )
        sequential = [
            fed_seq.invoke(fn, entry_zone=zone)
            for fn, zone in zip(functions, entries)
        ]
        batched = fed_batch.invoke_batch(functions, entry_zones=entries)
        assert [p.worker for p in sequential] == [p.worker for p in batched]
        assert [p.hops for p in sequential] == [p.hops for p in batched]
        assert fed_seq.stats() == fed_batch.stats()

    def test_dynamically_added_zone_is_routable_and_counted(self):
        """Zones added to the live cluster after construction (no spec
        slice, no entrypoint) can still receive designated placements —
        the forwarding ledger must absorb them, not KeyError."""
        fed = TappFederation(
            _two_zone_spec(slots=1), seed=0,
            distribution=DistributionPolicy.SHARED,
            policy=PINNED_NONE_SCRIPT.replace("ACtl", "LabCtl"),
        )
        fed.add_controller("LabCtl", zone="lab")
        fed.add_worker(WorkerSpec("lab_w0", zone="lab", sets=("lab", "any"),
                                  capacity_slots=2))
        placement = fed.invoke("fn", entry_zone="za", tag="pinned")
        assert placement.scheduled
        assert fed.cluster.workers[placement.worker].zone == "lab"
        assert [h.to_zone for h in placement.hops] == ["lab"]
        stats = fed.stats()
        assert stats.forwards == 1
        with pytest.raises(KeyError):
            stats.zone("lab")  # only spec-declared zones get a row

    def test_unknown_entry_zone_raises(self):
        fed = TappFederation(_two_zone_spec(), seed=0)
        with pytest.raises(ValueError, match="unknown entry zone"):
            fed.invoke("fn", entry_zone="nowhere")

    def test_default_entry_zone_is_used(self):
        fed = TappFederation(
            _two_zone_spec(default_entry="zb"), seed=0,
            policy=FORWARDING_SCRIPT,
            distribution=DistributionPolicy.SHARED,
        )
        placement = fed.invoke("fn")
        assert placement.entry_zone == "zb"
        assert fed.cluster.workers[placement.worker].zone == "zb"

    def test_sim_default_entry_workload_records_actual_entry_zone(self):
        """A federated workload with entry_zone=None enters at the
        federation's default entry — the sim must record (and charge)
        that zone, not its flat gateway_zone config."""
        from repro.core.sim.core import (
            FunctionProfile,
            NetworkModel,
            SimConfig,
            Simulation,
            WorkloadSpec,
        )

        fed = TappFederation(
            _two_zone_spec(slots=4), seed=0, policy=FORWARDING_SCRIPT,
            distribution=DistributionPolicy.SHARED,
        )
        sim = Simulation(
            fed,
            NetworkModel(rtt={}, bandwidth={}),
            {"fn": FunctionProfile(name="fn", exec_time=0.01)},
            SimConfig(seed=0, gateway_zone="zb"),
        )
        result = sim.run([WorkloadSpec("fn", users=1, requests_per_user=3)])
        assert all(r.entry_zone == "za" for r in result.records)

    def test_prewarm_builds_zone_local_indexes(self):
        fed = TappFederation(
            _two_zone_spec(), seed=0, policy=MULTI_TAG_SCRIPT,
            distribution=DistributionPolicy.SHARED,
        )
        assert fed.prewarm() > 0


class TestPartitionRetryBudget:
    """PR 6 retry machinery × federation partitions (PR 9 satellite):
    with every remote zone severed, a retrying invoke must terminate
    within its attempt budget and the partition must be visible in
    ``explain()`` as ``unreachable_zones``."""

    def _partitioned(self, retry=None):
        fed = TappFederation(
            _two_zone_spec(slots=1), seed=0,
            distribution=DistributionPolicy.SHARED, retry=retry,
        )
        # Saturate za (vanilla path: 2 workers × 1 slot) so the only
        # remaining capacity sits across the severed link.
        for _ in range(2):
            assert fed.invoke("fn", entry_zone="za").scheduled
        fed.sever("za", "zb")
        return fed

    def test_scalar_invoke_terminates_within_budget(self):
        fed = self._partitioned(retry=RetryPolicy(max_attempts=3))
        placement = fed.invoke("fn", entry_zone="za")
        assert not placement.scheduled
        assert placement.attempts == 3  # budget spent, then terminated
        assert placement.retry_wait > 0.0
        report = fed.explain("fn", entry_zone="za")
        assert report.unreachable_zones == ("zb",)
        assert "unreachable" in report.render()

    def test_invoke_batch_terminates_and_reports_unreachable(self):
        fed = self._partitioned(retry=RetryPolicy(max_attempts=2))
        batch = fed.invoke_batch(["fn"] * 3, entry_zones=["za"] * 3)
        assert all(not p.scheduled for p in batch)
        assert all(p.attempts == 2 for p in batch)
        assert fed.explain("fn", entry_zone="za").unreachable_zones == (
            "zb",
        )
        # Healing the link restores forwarding on the next invoke.
        fed.heal("za", "zb")
        healed = fed.invoke("fn", entry_zone="za")
        assert healed.scheduled
        assert fed.cluster.workers[healed.worker].zone == "zb"
        assert fed.explain("fn", entry_zone="za").unreachable_zones == ()


class TestArmedIdleBitIdentity:
    """PR 9 acceptance: an OverloadSpec that never fires (queue + breaker
    + brownout armed, cluster never saturated) is bit-identical to an
    unarmed federation — decisions, traces, hops, RNG streams, ledgers."""

    def test_armed_idle_equals_unarmed_under_churn(self):
        armed_spec = OverloadSpec(
            queue=QueueSpec(depth=8, deadline=5.0),
            breaker=BreakerSpec(),
            brownout=BrownoutSpec(),
        )
        for trial in range(4):
            plain = TappFederation(
                _two_zone_spec(slots=4), seed=trial,
                distribution=DistributionPolicy.SHARED,
                policy=MULTI_TAG_SCRIPT,
            )
            armed = TappFederation(
                _two_zone_spec(slots=4), seed=trial,
                distribution=DistributionPolicy.SHARED,
                policy=MULTI_TAG_SCRIPT, overload=armed_spec,
            )
            rng = random.Random(200 + trial)
            live = []
            for step in range(60):
                entry = rng.choice(("za", "zb"))
                fn = rng.choice(("fn_a", "fn_b"))
                tag = rng.choice((None, "spread"))
                now = float(step)
                p1 = plain.invoke(fn, tag=tag, entry_zone=entry,
                                  trace=True, now=now)
                p2 = armed.invoke(fn, tag=tag, entry_zone=entry,
                                  trace=True, now=now)
                context = f"trial={trial} step={step}"
                _assert_same_decision(p1.decision, p2.decision, context)
                assert p1.hops == p2.hops, context
                assert not p2.queued and p2.queue_outcome is None, context
                live.append((p1, p2))
                # Retire early so capacity never runs out (the armed
                # machinery must stay idle, not merely agree).
                while len(live) > 6:
                    a, b = live.pop(0)
                    a.complete(now=now)
                    b.complete(now=now)
            for zone in ("za", "zb"):
                assert (
                    plain.zone_gateway(zone)._engine.scheduling_state()
                    == armed.zone_gateway(zone)._engine.scheduling_state()
                ), trial
            armed_stats = armed.stats()
            assert armed_stats.open_circuits == ()
            agg = armed_stats.aggregate
            assert agg.queued == agg.shed == agg.queue_depth == 0
            assert agg.brownout_reroutes == 0
            plain_agg = plain.stats().aggregate
            assert (agg.routed, agg.admitted, agg.inflight, agg.failed) == (
                plain_agg.routed, plain_agg.admitted, plain_agg.inflight,
                plain_agg.failed,
            )


class TestArmedLifecycleBitIdentity:
    """PR 10: a warm-pool lifecycle armed under a policy that never uses
    ``warm-first`` runs fully (instances spawn, park, reuse, expire) but
    routing never reads the warmth — the federated façade's decisions,
    traces, hops, and RNG streams stay bit-identical to an unarmed one."""

    def test_armed_lifecycle_equals_unarmed_under_churn(self):
        from repro.core.platform import LifecycleSpec

        for trial in range(4):
            plain = TappFederation(
                _two_zone_spec(slots=4), seed=trial,
                distribution=DistributionPolicy.SHARED,
                policy=MULTI_TAG_SCRIPT,
            )
            armed = TappFederation(
                _two_zone_spec(slots=4), seed=trial,
                distribution=DistributionPolicy.SHARED,
                policy=MULTI_TAG_SCRIPT,
                lifecycle=LifecycleSpec(keep_alive=3.0),
            )
            rng = random.Random(300 + trial)
            live = []
            for step in range(60):
                entry = rng.choice(("za", "zb"))
                fn = rng.choice(("fn_a", "fn_b"))
                tag = rng.choice((None, "spread"))
                now = float(step)
                p1 = plain.invoke(fn, tag=tag, entry_zone=entry,
                                  trace=True)
                p2 = armed.invoke(fn, tag=tag, entry_zone=entry,
                                  trace=True, now=now)
                context = f"trial={trial} step={step}"
                _assert_same_decision(p1.decision, p2.decision, context)
                assert p1.hops == p2.hops, context
                live.append((p1, p2))
                while len(live) > 6:
                    a, b = live.pop(0)
                    a.complete()
                    b.complete(now=now)
            for zone in ("za", "zb"):
                assert (
                    plain.zone_gateway(zone)._engine.scheduling_state()
                    == armed.zone_gateway(zone)._engine.scheduling_state()
                ), trial
            # The lifecycle genuinely ran on the armed side.
            snap = armed.lifecycle_snapshot()
            assert snap["cold_starts"] > 0
            assert plain.lifecycle_snapshot()["cold_starts"] == 0
            agg1 = plain.stats().aggregate
            agg2 = armed.stats().aggregate
            assert (agg1.routed, agg1.admitted, agg1.inflight,
                    agg1.failed) == (agg2.routed, agg2.admitted,
                                     agg2.inflight, agg2.failed)


class TestFederationSpec:
    def test_duplicate_zone_rejected(self):
        with pytest.raises(ValueError, match="duplicate federation zone"):
            FederationSpec(zones=(("za", ClusterSpec()),
                                  ("za", ClusterSpec())))

    def test_contradictory_member_zone_rejected(self):
        with pytest.raises(ValueError, match="contradictory zone"):
            FederationSpec.of({
                "za": ClusterSpec(workers=(WorkerSpec("w0", zone="zb"),)),
            })

    def test_members_adopt_their_slice_zone(self):
        spec = FederationSpec.of({
            "za": ClusterSpec(
                workers=(WorkerSpec("w0"),),
                controllers=(ControllerSpec("C"),),
            ),
        })
        cluster = spec.build()
        assert cluster.workers["w0"].zone == "za"
        assert cluster.controllers["C"].zone == "za"

    def test_unknown_default_entry_rejected(self):
        with pytest.raises(ValueError, match="default_entry"):
            FederationSpec.of({"za": ClusterSpec()}, default_entry="zb")

    def test_zone_order_is_latency_sorted(self):
        spec = FederationSpec.of(
            {"a": ClusterSpec(), "b": ClusterSpec(), "c": ClusterSpec()},
            network=Net(table={("a", "b"): 0.2, ("a", "c"): 0.01}),
        )
        assert spec.zone_order_from("a") == ("c", "b")
        # Without a network model: declaration order.
        flat = FederationSpec.of(
            {"a": ClusterSpec(), "b": ClusterSpec(), "c": ClusterSpec()}
        )
        assert flat.zone_order_from("b") == ("a", "c")

    def test_shuffled_permutes_within_zones_only(self):
        spec = _two_zone_spec()
        shuffled = spec.shuffled(9)
        for (zone, original), (zone2, permuted) in zip(
            spec.zones, shuffled.zones
        ):
            assert zone == zone2
            assert sorted(w.name for w in original.workers) == sorted(
                w.name for w in permuted.workers
            )
            assert all(w.zone == zone for w in permuted.workers)

    def test_network_must_quack(self):
        with pytest.raises(TypeError, match="get_rtt"):
            FederationSpec.of({"za": ClusterSpec()}, network=object())


class TestEvictionLedger:
    def _platform(self):
        return TappPlatform(
            ClusterSpec(
                controllers=(ControllerSpec("C1"),),
                workers=(
                    WorkerSpec("w0", sets=("any",), capacity_slots=4),
                    WorkerSpec("w1", sets=("any",), capacity_slots=4),
                ),
            ),
            seed=0,
            policy=FORWARDING_SCRIPT,
            distribution=DistributionPolicy.SHARED,
        )

    def test_removing_loaded_worker_does_not_strand_tickets(self):
        platform = self._platform()
        placements = [platform.invoke("fn") for _ in range(3)]
        on_w0 = [p for p in placements if p.worker == "w0"]
        assert on_w0  # best_first lands on w0 first
        before = platform.stats()
        assert before.admitted == 3 and before.inflight == 3

        platform.remove_worker("w0")
        stats = platform.stats()
        assert stats.evicted == len(on_w0)
        # Invariant: admitted == completed + evicted + live inflight.
        assert stats.admitted == stats.completed + stats.evicted + stats.inflight

        # Completing the dead placements neither double-counts nor raises.
        for placement in placements:
            placement.complete()
        stats = platform.stats()
        assert stats.admitted == stats.completed + stats.evicted
        assert stats.inflight == 0
        assert stats.completed == 3 - len(on_w0)

    def test_removing_idle_worker_evicts_nothing(self):
        platform = self._platform()
        platform.remove_worker("w1")
        assert platform.stats().evicted == 0

    def test_federation_shares_the_same_reconciliation(self):
        fed = TappFederation(
            _two_zone_spec(slots=4), seed=0, policy=FORWARDING_SCRIPT,
            distribution=DistributionPolicy.SHARED,
        )
        placement = fed.invoke("fn", entry_zone="za")
        fed.remove_worker(placement.worker)
        stats = fed.stats()
        assert stats.aggregate.evicted == 1
        placement.complete()
        assert fed.stats().aggregate.completed == 0

    def test_stale_ticket_never_retires_against_a_name_reusing_worker(self):
        """Remove a loaded worker, register a NEW worker under the same
        name, admit onto it: the dead placement's complete() must not
        decrement the replacement's counters or double-count the ticket."""
        platform = self._platform()
        stale = platform.invoke("fn")
        name = stale.worker
        platform.remove_worker(name)
        platform.add_worker(WorkerSpec(name, sets=("any",),
                                       capacity_slots=4))
        other = next(w for w in platform.cluster.workers if w != name)
        platform.drain(other)  # force the fresh admission onto the reused name
        fresh = platform.invoke("fn")
        assert fresh.worker == name  # the replacement took an admission
        assert platform.cluster.workers[name].inflight == 1

        stale.complete()  # the dead ticket
        assert platform.cluster.workers[name].inflight == 1  # untouched
        stats = platform.stats()
        assert stats.admitted == stats.completed + stats.evicted + stats.inflight
        fresh.complete()  # the live ticket still retires normally
        assert platform.cluster.workers[name].inflight == 0
        stats = platform.stats()
        assert (stats.admitted, stats.completed, stats.evicted) == (2, 1, 1)

    def test_remove_worker_routes_future_traffic_away(self):
        platform = self._platform()
        first = platform.invoke("fn")
        platform.remove_worker(first.worker)
        second = platform.invoke("fn")
        assert second.scheduled
        assert second.worker != first.worker
