"""Static policy verifier (PR 8): reachability / satisfiability /
starvation proofs over compiled tAPP plans, the apply_policy gate, the
dead-code lints, and the explain() inevitability annotation."""
import pytest

from repro.core.analysis import UNBOUNDED, analyze_plan
from repro.core.platform import (
    ClusterSpec,
    ControllerSpec,
    FederationSpec,
    PolicyError,
    TappFederation,
    TappPlatform,
    WorkerSpec,
)
from repro.core.scheduler.topology import DistributionPolicy
from repro.core.sim import scenarios
from repro.core.tapp import parse_tapp
from repro.core.tapp.compile import compile_script
from repro.core.tapp.validate import validate_script

SPEC = ClusterSpec(
    controllers=(
        ControllerSpec("EdgeCtl", zone="edge"),
        ControllerSpec("CloudCtl", zone="cloud"),
    ),
    workers=(
        WorkerSpec("e0", zone="edge", sets=("edge", "any"), capacity_slots=2),
        WorkerSpec("e1", zone="edge", sets=("edge", "any"), capacity_slots=2),
        WorkerSpec("c0", zone="cloud", sets=("cloud", "any"), capacity_slots=4),
    ),
)

BLANK_DEFAULT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
"""

#: affinity ∩ anti-affinity on the same function: no worker state can
#: ever satisfy both, so every admission of the tag is rejected.
CONTRADICTION_SCRIPT = BLANK_DEFAULT + """
- clash:
  - workers:
    - set:
    strategy: platform
    affinity: [f]
    anti-affinity: [f]
  followup: fail
"""

#: `critical` is pinned (tolerance none) to EdgeCtl's zone but its worker
#: set only has cloud members — the home zone is empty, so the pin can
#: never be satisfied from ANY entry zone (forwarding included).
EMPTY_HOME_SCRIPT = """
- critical:
  - controller: EdgeCtl
    workers:
    - set: cloud
    topology_tolerance: none
  followup: fail
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
"""


def flat_platform(**kw):
    kw.setdefault("distribution", DistributionPolicy.SHARED)
    return TappPlatform(SPEC, **kw)


def empty_home_federation() -> TappFederation:
    spec = FederationSpec.of(
        {
            "edge": ClusterSpec(controllers=(ControllerSpec("EdgeCtl"),)),
            "cloud": ClusterSpec(
                controllers=(ControllerSpec("CloudCtl"),),
                workers=(
                    WorkerSpec("C_1", sets=("cloud", "any"),
                               capacity_slots=2),
                ),
            ),
        },
        default_entry="edge",
    )
    return TappFederation(spec, distribution=DistributionPolicy.SHARED)


class TestUnplaceabilityProofs:
    def test_contradictory_affinity_is_proved_unplaceable(self):
        platform = flat_platform()
        dry = platform.dry_run_policy(CONTRADICTION_SCRIPT)
        assert dry.analysis is not None
        assert dry.proofs, "expected an unplaceability proof"
        assert dry.satisfiability_findings
        verdict = dry.analysis.tag("clash")
        assert verdict is not None
        assert not verdict.placeable
        assert verdict.starvation_bound == 0
        # The default tag is untouched by the clash.
        assert dry.analysis.tag("default").placeable

    def test_strict_apply_rejects_lenient_apply_warns(self):
        with pytest.raises(PolicyError):
            flat_platform().apply_policy(CONTRADICTION_SCRIPT, strict=True)
        handle = flat_platform().apply_policy(CONTRADICTION_SCRIPT)
        assert handle.dry_run.proofs
        assert handle.dry_run.ok
        assert not handle.dry_run.ok_strict()

    def test_federated_empty_home_zone_proved_per_entry_zone(self):
        federation = empty_home_federation()
        dry = federation.dry_run_policy(EMPTY_HOME_SCRIPT)
        assert dry.analysis is not None
        assert dry.proofs
        for zone in ("edge", "cloud"):
            verdict = dry.analysis.tag("critical", zone)
            assert verdict is not None
            assert not verdict.placeable, f"entry zone {zone}"
            # default spills cross-zone: placeable from both entries.
            assert dry.analysis.tag("default", zone).placeable

        with pytest.raises(PolicyError):
            empty_home_federation().apply_policy(EMPTY_HOME_SCRIPT,
                                                 strict=True)
        handle = empty_home_federation().apply_policy(EMPTY_HOME_SCRIPT)
        assert handle.dry_run.proofs

    def test_forwarding_prevents_false_local_proofs(self):
        # A controller-less tag with no local workers is NOT unplaceable
        # when a forward-target zone can take it: the verdict must fold
        # the forwarding walk in, or every shipped federation policy
        # would be rejected in strict mode.
        federation = empty_home_federation()
        report = federation.verify_policy(BLANK_DEFAULT)
        verdict = report.tag("default", "edge")
        assert verdict.placeable
        assert "C_1" in verdict.selectable


class TestVerifyPolicyApi:
    def test_verify_policy_defaults_to_active(self):
        platform = flat_platform()
        platform.apply_policy(BLANK_DEFAULT)
        report = platform.verify_policy()
        assert report.ok
        assert report.tag("default").placeable
        assert "analysis @epoch" in report.summary()
        text = report.verdict()
        assert "tag 'default'" in text
        assert "placeable" in text

    def test_verify_policy_without_active_raises(self):
        with pytest.raises(PolicyError):
            flat_platform().verify_policy()

    def test_starvation_floor_flags_thin_tags(self):
        platform = flat_platform()
        report = platform.verify_policy(
            BLANK_DEFAULT, starvation_floor=10_000
        )
        starving = [f for f in report.findings
                    if f.category == "starvation"]
        assert starving
        assert not report.proofs  # bound > 0: flagged, not proved dead

    def test_apply_policy_attaches_analysis(self):
        platform = flat_platform()
        handle = platform.apply_policy(BLANK_DEFAULT, strict=True)
        assert handle.dry_run.analysis is not None
        assert handle.dry_run.analysis.tag("default").placeable


class TestAnalyzeCore:
    def _analysis(self, script_text, **kw):
        plan = compile_script(parse_tapp(script_text))
        platform = flat_platform()
        cluster = platform._watcher.cluster
        return analyze_plan(plan, cluster, DistributionPolicy.SHARED, **kw)

    def test_admission_bound_counts_capacity(self):
        # Blank set + overload: every worker admits up to its slot count.
        report = self._analysis(BLANK_DEFAULT)
        verdict = report.tag("default")
        assert verdict.exact
        assert verdict.starvation_bound == 2 + 2 + 4
        assert dict(verdict.admissible) == {"e0": 2, "e1": 2, "c0": 4}

    def test_max_concurrent_invocations_ceiling(self):
        script = """
- default:
  - workers:
    - set:
    invalidate: max_concurrent_invocations 1
"""
        report = self._analysis(script)
        assert report.tag("default").starvation_bound == 3  # 1 per worker

    def test_capacity_used_100_percent_saturates_at_slots(self):
        script = """
- default:
  - workers:
    - set:
    invalidate: capacity_used 100%
"""
        report = self._analysis(script)
        verdict = report.tag("default")
        # The signal only reports 100% once every slot is taken, so each
        # worker absorbs exactly its slot count before invalidating.
        assert verdict.starvation_bound == 2 + 2 + 4

    def test_capacity_used_ceiling_defensive_over_100(self):
        # The grammar rejects >100%, but the ceiling helper stays total.
        from repro.core.analysis.verifier import _capacity_used_ceiling

        assert _capacity_used_ceiling(150.0, 4) == UNBOUNDED
        assert _capacity_used_ceiling(50.0, 0) == 0
        assert _capacity_used_ceiling(50.0, 4) == 2

    def test_dead_block_reported_once_per_tag(self):
        script = BLANK_DEFAULT + """
- pinned:
  - controller: NoSuchCtl
    workers:
    - set: edge
    topology_tolerance: none
  followup: fail
"""
        report = self._analysis(script)
        verdict = report.tag("pinned")
        assert not verdict.placeable
        dead = [b for b in verdict.blocks if not b.live]
        assert dead and dead[0].reason
        reach = [f for f in report.findings
                 if f.category == "reachability" and "pinned" in f.where]
        assert reach

    def test_tag_subset_analysis(self):
        report = self._analysis(CONTRADICTION_SCRIPT, tags=("clash",))
        assert {v.tag for v in report.verdicts} == {"clash"}
        assert report.selectable("clash") == frozenset()
        assert report.selectable("default") is None


class TestDryRunRender:
    def test_render_groups_by_category_with_location(self):
        platform = flat_platform()
        script = CONTRADICTION_SCRIPT + """
- dangling:
  - controller: GhostCtl
    workers:
    - set: nowhere
"""
        dry = platform.dry_run_policy(script)
        text = dry.render()
        lines = text.splitlines()
        for category in ("topology:", "constraint:", "satisfiability:"):
            assert any(line == category for line in lines), category
        # Category headers appear in the canonical order.
        order = [lines.index(c) for c in
                 ("topology:", "constraint:", "satisfiability:")]
        assert order == sorted(order)
        # Every finding line names its tag/block.
        for line in lines:
            if line.startswith("  ["):
                assert "tag:" in line or "script" in line
        assert "analysis @epoch" in text

    def test_render_no_findings(self):
        dry = flat_platform().dry_run_policy(BLANK_DEFAULT)
        assert not dry.findings
        assert "no findings" in dry.render()


class TestDeadCodeLints:
    def test_duplicate_wrk_items_in_block(self):
        script = parse_tapp("""
- default:
  - workers:
    - wrk: e0
    - wrk: e1
    - wrk: e0
""")
        report = validate_script(script, known_worker_labels=("e0", "e1"))
        dup = [f for f in report.findings if "listed 2 times" in f.message]
        assert len(dup) == 1
        assert "'e0'" in dup[0].message
        assert dup[0].level == "warning"
        assert dup[0].where == "tag:default.block[0]"

    def test_duplicate_set_items_in_block(self):
        script = parse_tapp("""
- default:
  - workers:
    - set: edge
    - set: edge
    - set:
    - set:
""")
        report = validate_script(script, known_set_labels=("edge",))
        messages = [f.message for f in report.findings]
        assert any("set 'edge' is listed 2 times" in m for m in messages)
        assert any("the blank set is listed 2 times" in m for m in messages)

    def test_unreferenced_declared_sets(self):
        script = parse_tapp("""
- default:
  - workers:
    - set: edge
""")
        report = validate_script(
            script, known_set_labels=("edge", "cloud", "spare")
        )
        unused = [f for f in report.findings
                  if "referenced by no block" in f.message]
        assert len(unused) == 1
        assert "'cloud'" in unused[0].message
        assert "'spare'" in unused[0].message

    def test_blank_set_reference_silences_unreferenced_lint(self):
        # A blank set covers every declared set; nothing is unreachable.
        script = parse_tapp(BLANK_DEFAULT)
        report = validate_script(script, known_set_labels=("edge", "cloud"))
        assert not [f for f in report.findings
                    if "referenced by no block" in f.message]

    def test_lints_never_block_strict_apply(self):
        platform = flat_platform()
        script = """
- default:
  - workers:
    - wrk: e0
    - wrk: e0
    invalidate: overload
"""
        handle = platform.apply_policy(script, strict=True)
        assert any("listed 2 times" in f.message
                   for f in handle.dry_run.warnings)


class TestExplainInevitability:
    def test_contradiction_rejections_marked_inevitable(self):
        platform = flat_platform()
        platform.apply_policy(CONTRADICTION_SCRIPT)
        report = platform.explain("f", tag="clash")
        assert not report.scheduled
        assert set(report.inevitable_workers) == {"e0", "e1", "c0"}
        assert "statically inevitable" in report.render()

    def test_dynamic_rejections_not_marked(self):
        platform = flat_platform()
        platform.apply_policy(BLANK_DEFAULT)
        # Saturate one worker: its rejection is load-dependent, not
        # statically inevitable.
        for _ in range(SPEC.workers[0].capacity_slots * 4):
            platform.invoke("f")
        report = platform.explain("f")
        assert report.inevitable_workers == ()

    def test_federated_explain_marks_inevitable_per_hop(self):
        # The contradictory tag rejects C_1 in whichever zone evaluates
        # it; the analyzer's empty selectable set marks that rejection
        # inevitable on the hop report.
        federation = empty_home_federation()
        federation.apply_policy(CONTRADICTION_SCRIPT)
        report = federation.explain("f", tag="clash", entry_zone="cloud")
        assert not report.scheduled
        assert any(
            "C_1" in hop.report.inevitable_workers for hop in report.hops
        ), "expected the clash rejection to be marked statically inevitable"


class TestBruteForceAgreement:
    """Seeded mirror of the hypothesis property suite (which needs the
    dev-only hypothesis package): analyzer verdicts vs exhaustive
    admission on small random topologies × affinity-free scripts."""

    @pytest.mark.parametrize("seed", range(12))
    def test_seeded_random_cases(self, seed):
        import random

        from tests._analysis_bruteforce import check_agreement

        rng = random.Random(seed)
        zones = ("z0", "z1")[: rng.randint(1, 2)]
        spec = ClusterSpec(
            controllers=tuple(
                ControllerSpec(f"C{i}", zone=zones[i % len(zones)])
                for i in range(rng.randint(1, 2))
            ),
            workers=tuple(
                WorkerSpec(
                    f"w{i}",
                    zone=rng.choice(zones),
                    sets=(rng.choice(("a", "b")), "any"),
                    capacity_slots=rng.randint(1, 3),
                )
                for i in range(rng.randint(1, 4))
            ),
        )
        invalidates = (
            "overload",
            "max_concurrent_invocations 1",
            "max_concurrent_invocations 2",
            "capacity_used 25%",
            "capacity_used 50%",
            "capacity_used 100%",
        )
        script = (
            "- default:\n"
            "  - workers:\n"
            "    - set:\n"
            "    strategy: platform\n"
            f"    invalidate: {rng.choice(invalidates)}\n"
        )
        if rng.random() < 0.7:
            tolerance = rng.choice((None, "none", "same", "all"))
            block = ["- t:"]
            if tolerance is not None:
                block.append(f"  - controller: {rng.choice(('C0', 'C1'))}")
                block.append("    workers:")
            else:
                block.append("  - workers:")
            block.append(f"    - set: {rng.choice(('', 'a', 'b', 'any'))}")
            block.append(f"    invalidate: {rng.choice(invalidates)}")
            if tolerance is not None:
                block.append(f"    topology_tolerance: {tolerance}")
            block.append(f"  followup: {rng.choice(('fail', 'default'))}")
            script += "\n".join(block) + "\n"
        distribution = rng.choice(tuple(DistributionPolicy))
        check_agreement(spec, script, distribution=distribution)


class TestZeroFalseBlockers:
    """Shipped scenario policies must verify clean (no errors, no proofs)."""

    CASES = [
        ("data_locality", scenarios.DATA_LOCALITY_SCRIPT,
         lambda: TappPlatform(scenarios.benchmark_cluster(),
                              distribution=DistributionPolicy.SHARED)),
        ("mqtt_flat", scenarios.MQTT_SCRIPT,
         lambda: TappPlatform(scenarios.mqtt_cluster(),
                              distribution=DistributionPolicy.SHARED)),
        ("mqtt_federated", scenarios.MQTT_SCRIPT,
         lambda: TappFederation(scenarios.mqtt_federation_spec(),
                                distribution=DistributionPolicy.SHARED)),
        ("colocation", scenarios.COLOCATION_SCRIPT,
         lambda: TappPlatform(scenarios.colocation_cluster(),
                              distribution=DistributionPolicy.SHARED)),
        ("colocation_federated", scenarios.COLOCATION_SCRIPT,
         lambda: TappFederation(scenarios.colocation_federation_spec(),
                                distribution=DistributionPolicy.SHARED)),
    ]

    @pytest.mark.parametrize("name,script,factory", CASES,
                             ids=[c[0] for c in CASES])
    def test_shipped_policy_verifies_clean(self, name, script, factory):
        dry = factory().dry_run_policy(script)
        assert dry.analysis is not None
        assert not dry.errors
        assert not dry.proofs, [str(f) for f in dry.proofs]
        # And strict apply accepts them.
        factory().apply_policy(script, strict=True)
