"""Property test: analyzer verdicts agree with brute-force enumeration.

For random small topologies × affinity-free scripts, the static
analyzer's per-tag verdicts must match what a real platform does when
invocations are exhaustively admitted until saturation:

- "statically unplaceable" ⟺ no admission sequence places the tag,
- the starvation bound equals the exact number of admissions absorbed,
- placed workers are always inside the analyzer's selectable set.

Requires hypothesis (requirements-dev.txt); skipped when absent.
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.platform import (  # noqa: E402
    ClusterSpec,
    ControllerSpec,
    WorkerSpec,
)
from repro.core.scheduler.topology import DistributionPolicy  # noqa: E402

from tests._analysis_bruteforce import check_agreement  # noqa: E402

ZONES = ("z0", "z1")
SET_LABELS = ("a", "b")


@st.composite
def cluster_specs(draw):
    n_zones = draw(st.integers(1, 2))
    zones = ZONES[:n_zones]
    controllers = tuple(
        ControllerSpec(f"C{i}", zone=zones[i % n_zones])
        for i in range(draw(st.integers(1, 2)))
    )
    workers = tuple(
        WorkerSpec(
            f"w{i}",
            zone=draw(st.sampled_from(zones)),
            sets=(draw(st.sampled_from(SET_LABELS)), "any"),
            capacity_slots=draw(st.integers(1, 3)),
        )
        for i in range(draw(st.integers(1, 4)))
    )
    return ClusterSpec(controllers=controllers, workers=workers)


_INVALIDATES = st.sampled_from(
    (
        "overload",
        "max_concurrent_invocations 1",
        "max_concurrent_invocations 2",
        "max_concurrent_invocations 3",
        "capacity_used 25%",
        "capacity_used 50%",
        "capacity_used 100%",
    )
)


def _block(set_label, invalidate, controller=None, tolerance=None):
    lines = []
    if controller is not None:
        lines.append(f"  - controller: {controller}")
        lines.append("    workers:")
    else:
        lines.append("  - workers:")
    lines.append(f"    - set: {set_label or ''}")
    lines.append("    strategy: platform")
    lines.append(f"    invalidate: {invalidate}")
    if tolerance is not None:
        lines.append(f"    topology_tolerance: {tolerance}")
    return "\n".join(lines)


@st.composite
def scripts(draw):
    parts = [
        "- default:",
        _block(
            draw(st.sampled_from((None, "any"))),
            draw(_INVALIDATES),
        ),
    ]
    if draw(st.booleans()):
        tolerance = draw(st.sampled_from((None, "none", "same", "all")))
        controller = (
            draw(st.sampled_from(("C0", "C1"))) if tolerance else None
        )
        parts.append("- t:")
        parts.append(
            _block(
                draw(st.sampled_from((None,) + SET_LABELS)),
                draw(_INVALIDATES),
                controller=controller,
                tolerance=tolerance,
            )
        )
        parts.append(
            f"  followup: {draw(st.sampled_from(('fail', 'default')))}"
        )
    return "\n".join(parts) + "\n"


@settings(max_examples=60, deadline=None)
@given(
    spec=cluster_specs(),
    script=scripts(),
    distribution=st.sampled_from(tuple(DistributionPolicy)),
)
def test_analyzer_agrees_with_brute_force(spec, script, distribution):
    # Scripts may name C1 when the cluster only has C0 — a legitimate
    # dead-designation case the analyzer must prove, not an error.
    check_agreement(spec, script, distribution=distribution)
