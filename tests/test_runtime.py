"""Runtime: fault-tolerant training loop + tAPP-scheduled serving engine."""
import dataclasses

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import smoke_config
from repro.core.scheduler.topology import DistributionPolicy
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.launch.steps import TrainState, make_train_step
from repro.models import Model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime.serve_engine import Replica, ServingEngine
from repro.runtime.train_loop import TrainLoopConfig, run_training

RNG = jax.random.PRNGKey(0)


def _training_setup(tmp_path, arch="smollm_135m", total=12):
    cfg = smoke_config(arch)
    model = Model(cfg)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=total,
                          schedule="constant")
    params = model.init_params(RNG)
    state = TrainState(params=params, opt=adamw_init(opt_cfg, params))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    pipeline = SyntheticTokens(
        DataConfig(vocab_size=cfg.vocab_size, global_batch=4, seq_len=32)
    )
    ck = Checkpointer(str(tmp_path), keep_last=3)
    return cfg, state, step_fn, pipeline, ck


class TestTrainLoop:
    def test_loss_decreases(self, tmp_path):
        _, state, step_fn, pipeline, ck = _training_setup(tmp_path, total=25)
        report = run_training(
            step_fn=step_fn, state=state, pipeline=pipeline,
            checkpointer=ck,
            config=TrainLoopConfig(total_steps=25, checkpoint_every=10,
                                   checkpoint_async=False),
        )
        assert report.steps_run == 25
        first = np.mean(report.losses[:5])
        last = np.mean(report.losses[-5:])
        assert last < first, (first, last)

    def test_restart_after_injected_failure(self, tmp_path):
        _, state, step_fn, pipeline, ck = _training_setup(tmp_path, total=15)
        report = run_training(
            step_fn=step_fn, state=state, pipeline=pipeline, checkpointer=ck,
            config=TrainLoopConfig(
                total_steps=15, checkpoint_every=5, checkpoint_async=False,
                inject_failure_at=8,
            ),
        )
        assert report.restarts == 1
        assert report.final_step == 14
        assert ck.latest_step() == 14

    def test_resume_from_checkpoint(self, tmp_path):
        cfg, state, step_fn, pipeline, ck = _training_setup(tmp_path, total=10)
        run_training(
            step_fn=step_fn, state=state, pipeline=pipeline, checkpointer=ck,
            config=TrainLoopConfig(total_steps=6, checkpoint_every=5,
                                   checkpoint_async=False),
        )
        # Second invocation resumes from the saved step, not from scratch.
        report = run_training(
            step_fn=step_fn, state=state, pipeline=pipeline, checkpointer=ck,
            config=TrainLoopConfig(total_steps=10, checkpoint_every=5,
                                   checkpoint_async=False),
        )
        assert report.steps_run <= 5  # only the remaining steps ran


def _small_replica(name, zone, sets=(), slots=2, seed=0):
    cfg = dataclasses.replace(smoke_config("smollm_135m"), n_layers=2)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    return Replica(name, cfg, params, zone=zone, sets=sets, slots=slots,
                   max_len=48)


ZONED_SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- edge_only:
  - controller: EdgeCtl
    workers:
    - set: edge
    topology_tolerance: none
  followup: fail
"""


class TestServingEngine:
    def test_completes_requests(self):
        engine = ServingEngine(tapp_script=ZONED_SCRIPT)
        engine.add_controller("EdgeCtl", zone="edge")
        engine.add_controller("CloudCtl", zone="cloud")
        engine.add_replica(_small_replica("r-edge", "edge", ["edge"]))
        engine.add_replica(_small_replica("r-cloud", "cloud", ["cloud"]))
        reqs = [
            engine.submit("smollm-135m", [1, 2, 3], max_new_tokens=4)
            for _ in range(5)
        ]
        engine.run_until_done(max_ticks=100)
        assert all(r.state == "done" for r in reqs)
        assert all(len(r.output) == 4 for r in reqs)

    def test_tagged_requests_pinned_to_zone(self):
        engine = ServingEngine(tapp_script=ZONED_SCRIPT)
        engine.add_controller("EdgeCtl", zone="edge")
        engine.add_controller("CloudCtl", zone="cloud")
        engine.add_replica(_small_replica("r-edge", "edge", ["edge"]))
        engine.add_replica(_small_replica("r-cloud", "cloud", ["cloud"]))
        reqs = [
            engine.submit("smollm-135m", [1, 2, 3], tag="edge_only",
                          max_new_tokens=3)
            for _ in range(4)
        ]
        engine.run_until_done(max_ticks=100)
        assert all(r.state == "done" for r in reqs)
        assert {r.replica for r in reqs} == {"r-edge"}

    def test_federated_engine_routes_by_entry_zone_and_forwards(self):
        """A federation-backed engine serves multi-entry traffic: requests
        enter their zone's gateway; edge-pinned work submitted at the
        cloud entry is forwarded to (and only to) the edge replica."""
        from repro.core.platform import (
            ClusterSpec,
            ControllerSpec,
            FederationSpec,
        )

        spec = FederationSpec.of({
            "edge": ClusterSpec(controllers=(ControllerSpec("EdgeCtl"),)),
            "cloud": ClusterSpec(controllers=(ControllerSpec("CloudCtl"),)),
        })
        engine = ServingEngine(tapp_script=ZONED_SCRIPT, federation=spec)
        engine.add_replica(_small_replica("r-edge", "edge", ["edge"]))
        engine.add_replica(_small_replica("r-cloud", "cloud", ["cloud"]))
        pinned = [
            engine.submit("smollm-135m", [1, 2, 3], tag="edge_only",
                          entry_zone="cloud", max_new_tokens=3)
            for _ in range(2)
        ]
        generic = engine.submit("smollm-135m", [4, 5], entry_zone="cloud",
                                max_new_tokens=3)
        engine.run_until_done(max_ticks=100)
        assert all(r.state == "done" for r in pinned + [generic])
        assert {r.replica for r in pinned} == {"r-edge"}
        assert generic.replica == "r-cloud"  # zone-local stays local
        stats = engine.platform.stats()
        assert stats.forwards >= 2
        assert stats.zone("edge").forwarded_in >= 2
        # The compat property resolves to the default entry's gateway.
        assert engine.gateway is engine.platform.zone_gateway("edge")

    def test_decode_is_deterministic_across_replicas(self):
        """Same weights on two replicas → same generation (placement-
        transparent serving)."""
        engine = ServingEngine(tapp_script=None)
        engine.add_controller("C", zone="z")
        r1 = _small_replica("r1", "z", seed=7)
        r2 = Replica("r2", r1.cfg, r1.params, zone="z", slots=2, max_len=48)
        engine.add_replica(r1)
        engine.add_replica(r2)
        a = engine.submit("smollm-135m", [5, 6, 7, 8], max_new_tokens=5)
        b = engine.submit("smollm-135m", [5, 6, 7, 8], max_new_tokens=5)
        engine.run_until_done(max_ticks=100)
        assert a.state == b.state == "done"
        assert a.output == b.output

    def test_failover_on_replica_loss(self):
        engine = ServingEngine(tapp_script=ZONED_SCRIPT)
        engine.add_controller("EdgeCtl", zone="edge")
        engine.add_controller("CloudCtl", zone="cloud")
        r_edge = _small_replica("r-edge", "edge", ["edge"], seed=1)
        engine.add_replica(r_edge)
        engine.add_replica(_small_replica("r-cloud", "cloud", ["cloud"], seed=1))
        reqs = [
            engine.submit("smollm-135m", [1, 2], max_new_tokens=6)
            for _ in range(3)
        ]
        engine.step_once()
        engine.remove_replica("r-edge")  # node failure mid-flight
        engine.run_until_done(max_ticks=200)
        assert all(r.state == "done" for r in reqs)
        assert all(r.replica == "r-cloud" for r in reqs)

    def test_edge_only_fails_when_zone_lost(self):
        engine = ServingEngine(tapp_script=ZONED_SCRIPT)
        engine.add_controller("EdgeCtl", zone="edge")
        engine.add_controller("CloudCtl", zone="cloud")
        engine.add_replica(_small_replica("r-cloud", "cloud", ["cloud"]))
        req = engine.submit("smollm-135m", [1, 2], tag="edge_only",
                            max_new_tokens=2)
        for _ in range(3):
            engine.step_once()
        assert req.state == "queued"  # policy refuses the cloud replica

    def test_capacity_spills_to_second_replica(self):
        engine = ServingEngine(
            tapp_script=None, distribution=DistributionPolicy.SHARED
        )
        engine.add_controller("C", zone="z")
        r1 = _small_replica("r1", "z", slots=1, seed=3)
        r2 = Replica("r2", r1.cfg, r1.params, zone="z", slots=1, max_len=48)
        engine.add_replica(r1)
        engine.add_replica(r2)
        reqs = [
            engine.submit("smollm-135m", [9, 9], max_new_tokens=6)
            for _ in range(2)
        ]
        engine.run_until_done(max_ticks=200)
        assert all(r.state == "done" for r in reqs)
        assert {r.replica for r in reqs} == {"r1", "r2"}


class TestStragglerMitigation:
    def test_slow_replica_is_flagged_and_routed_around(self, monkeypatch):
        import time as _time

        engine = ServingEngine(tapp_script=None, straggler_factor=2.0)
        engine.add_controller("C", zone="z")
        fast = _small_replica("fast", "z", slots=4, seed=5)
        slow = Replica("slow", fast.cfg, fast.params, zone="z", slots=4,
                       max_len=48)
        engine.add_replica(fast)
        engine.add_replica(slow)

        # Warm both replicas so each EMA exists (both get load: 8 reqs on
        # 2 replicas x 4 slots).
        for _ in range(8):
            engine.submit("smollm-135m", [1, 2], max_new_tokens=3)
        engine.run_until_done(max_ticks=80)
        assert fast.tick_times and slow.tick_times

        # Make 'slow' a straggler: its decode call stalls (timed region).
        orig_decode = slow._decode

        def slow_decode(*args, **kwargs):
            _time.sleep(0.25)
            return orig_decode(*args, **kwargs)

        monkeypatch.setattr(slow, "_decode", slow_decode)
        reqs = [engine.submit("smollm-135m", [3, 4], max_new_tokens=4)
                for _ in range(6)]
        engine.run_until_done(max_ticks=200)
        assert all(r.state == "done" for r in reqs)
        # The straggler was flagged at least once and reported saturated.
        assert engine.stragglers_flagged >= 1
