"""Overload-resilience layer (PR 9): admission queues, load shedding,
circuit breakers, brownout degradation — units and façade integration.

The companion chaos-level coverage (overload bursts, breaker probe rate
under drained zones) lives in ``tests/test_chaos.py``; the federated
armed-idle bit-identity property lives in ``tests/test_federation.py``.
"""
import pytest

from repro.core.platform import (
    AdmissionQueue,
    BreakerSpec,
    BrownoutController,
    BrownoutSpec,
    CircuitBreaker,
    ClusterSpec,
    ControllerSpec,
    OverloadSpec,
    QueueSpec,
    TappPlatform,
    WorkerSpec,
    degrade_script,
)
from repro.core.tapp import TappParseError, parse_tapp, script_to_yaml
from repro.core.tapp.ast import OnOverload, TopologyTolerance


def pool_cluster(n_workers: int = 3, slots: int = 2) -> ClusterSpec:
    return ClusterSpec(
        controllers=(ControllerSpec("Ctl"),),
        workers=tuple(
            WorkerSpec(f"w{i}", sets=("pool", "any"), capacity_slots=slots)
            for i in range(n_workers)
        ),
    )


DEFAULT_SCRIPT = (
    "- default:\n"
    "  - workers:\n"
    "    - set: pool\n"
    "    strategy: platform\n"
    "    invalidate: overload\n"
)

PRIORITY_SCRIPT = DEFAULT_SCRIPT + (
    "- hi:\n"
    "  - workers:\n"
    "    - set: pool\n"
    "    strategy: platform\n"
    "    invalidate: overload\n"
    "    priority: 5\n"
    "  followup: fail\n"
    "- lo:\n"
    "  - workers:\n"
    "    - set: pool\n"
    "    strategy: platform\n"
    "    invalidate: overload\n"
    "  followup: fail\n"
)

BROWNOUT_SCRIPT = DEFAULT_SCRIPT + (
    "- sticky:\n"
    "  - workers:\n"
    "    - set: pool\n"
    "      anti-affinity: [sticky_fn]\n"
    "    strategy: platform\n"
    "    invalidate: overload\n"
    "  followup: fail\n"
    "  on-overload: relax-affinity\n"
    "- never:\n"
    "  - workers:\n"
    "    - set: pool\n"
    "      anti-affinity: [never_fn]\n"
    "    strategy: platform\n"
    "    invalidate: overload\n"
    "  followup: fail\n"
    "  on-overload: reject\n"
)


def ledger_ok(stats) -> bool:
    return stats.admitted == stats.completed + stats.evicted + stats.inflight


# ---------------------------------------------------------------------------
# Spec validation
# ---------------------------------------------------------------------------


class TestSpecs:
    def test_queue_spec_validation(self):
        assert QueueSpec().discipline == "fifo"
        with pytest.raises(ValueError):
            QueueSpec(depth=0)
        with pytest.raises(ValueError):
            QueueSpec(deadline=0.0)
        with pytest.raises(ValueError):
            QueueSpec(discipline="lifo")

    def test_breaker_spec_validation(self):
        with pytest.raises(ValueError):
            BreakerSpec(failure_threshold=0)
        with pytest.raises(ValueError):
            BreakerSpec(probe_interval=0)
        with pytest.raises(ValueError):
            BreakerSpec(rtt_budget=-1.0)

    def test_brownout_spec_validation(self):
        with pytest.raises(ValueError):
            BrownoutSpec(high_water=0)
        with pytest.raises(ValueError):
            BrownoutSpec(high_water=4, low_water=4)
        with pytest.raises(ValueError):
            BrownoutSpec(sustain=0)

    def test_brownout_requires_a_queue(self):
        with pytest.raises(ValueError, match="requires a queue"):
            OverloadSpec(brownout=BrownoutSpec())
        OverloadSpec(queue=QueueSpec(), brownout=BrownoutSpec())  # ok


# ---------------------------------------------------------------------------
# AdmissionQueue unit behaviour
# ---------------------------------------------------------------------------


class _Stub:
    """Stand-in placement for queue-level tests."""

    def __init__(self, name):
        self.name = name


class TestAdmissionQueue:
    def test_fifo_head_order_and_drain_counters(self):
        q = AdmissionQueue(QueueSpec(depth=4))
        a, b = _Stub("a"), _Stub("b")
        assert q.offer(a, 0, now=0.0)[0] == "queued"
        assert q.offer(b, 0, now=1.0)[0] == "queued"
        head = q.head()
        assert head.placement is a
        assert q.remove(head, drained=True)
        assert q.head().placement is b
        snap = q.snapshot()
        assert snap == {"depth": 1, "queued_total": 2, "shed": 0,
                        "deadline_exceeded": 0, "drained": 1}

    def test_edf_orders_by_absolute_deadline(self):
        q = AdmissionQueue(QueueSpec(depth=4, deadline=10.0,
                                     discipline="edf"))
        late, early = _Stub("late"), _Stub("early")
        q.offer(late, 0, now=5.0)    # deadline 15
        q.offer(early, 0, now=1.0)   # deadline 11
        assert q.head().placement is early

    def test_full_queue_sheds_lowest_priority_entrant(self):
        q = AdmissionQueue(QueueSpec(depth=1))
        lo, hi, lo2 = _Stub("lo"), _Stub("hi"), _Stub("lo2")
        assert q.offer(lo, 0, now=0.0)[0] == "queued"
        # Higher-priority newcomer evicts the queued low-priority entry.
        status, victim = q.offer(hi, 5, now=0.0)
        assert status == "shed" and victim.placement is lo
        # Equal-or-lower newcomer loses against the incumbent.
        status, victim = q.offer(lo2, 0, now=0.0)
        assert status == "shed" and victim.placement is lo2
        assert q.head().placement is hi
        assert q.snapshot()["shed"] == 2

    def test_expire_removes_only_overdue_entries(self):
        q = AdmissionQueue(QueueSpec(depth=4, deadline=5.0))
        a, b = _Stub("a"), _Stub("b")
        q.offer(a, 0, now=0.0)   # deadline 5
        q.offer(b, 0, now=4.0)   # deadline 9
        expired = q.expire(now=6.0)
        assert [e.placement for e in expired] == [a]
        assert q.depth == 1 and q.snapshot()["deadline_exceeded"] == 1
        assert q.expire(now=None) == []


# ---------------------------------------------------------------------------
# CircuitBreaker unit behaviour
# ---------------------------------------------------------------------------


class TestCircuitBreaker:
    def test_opens_at_threshold_then_probes_deterministically(self):
        br = CircuitBreaker(BreakerSpec(failure_threshold=2,
                                        probe_interval=3))
        assert br.allow("a", "b")
        br.record_failure("a", "b")
        assert not br.is_open("a", "b")
        br.record_failure("a", "b")
        assert br.is_open("a", "b")
        # While open: every 3rd suppressed attempt is the half-open probe.
        pattern = [br.allow("a", "b") for _ in range(6)]
        assert pattern == [False, False, True, False, False, True]

    def test_probe_success_closes_failure_restarts_cooldown(self):
        br = CircuitBreaker(BreakerSpec(failure_threshold=1,
                                        probe_interval=2))
        br.record_failure("a", "b")
        assert br.open_circuits() == (("a", "b"),)
        assert [br.allow("a", "b") for _ in range(2)] == [False, True]
        br.record_failure("a", "b")  # probe failed: cooldown restarts
        assert [br.allow("a", "b") for _ in range(2)] == [False, True]
        br.record_success("a", "b")
        assert br.open_circuits() == ()
        assert br.allow("a", "b")

    def test_rtt_budget_counts_slow_success_as_failure(self):
        br = CircuitBreaker(BreakerSpec(failure_threshold=2,
                                        rtt_budget=0.05))
        br.record_success("a", "b", rtt=0.2)
        br.record_success("a", "b", rtt=0.2)
        assert br.is_open("a", "b")
        # A within-budget success is a real success.
        br2 = CircuitBreaker(BreakerSpec(failure_threshold=2,
                                         rtt_budget=0.05))
        br2.record_failure("a", "b")
        br2.record_success("a", "b", rtt=0.01)
        br2.record_failure("a", "b")
        assert not br2.is_open("a", "b")

    def test_links_are_independent(self):
        br = CircuitBreaker(BreakerSpec(failure_threshold=1))
        br.record_failure("a", "b")
        assert br.is_open("a", "b")
        assert not br.is_open("a", "c")
        assert br.allow("b", "a")
        assert br.open_circuits() == (("a", "b"),)


# ---------------------------------------------------------------------------
# BrownoutController hysteresis
# ---------------------------------------------------------------------------


class TestBrownoutController:
    def test_sustained_high_water_activates(self):
        ctl = BrownoutController(BrownoutSpec(high_water=4, low_water=1,
                                              sustain=3))
        assert not ctl.observe(4)
        assert not ctl.observe(5)
        assert ctl.observe(4)          # third consecutive observation
        assert ctl.activations == 1

    def test_dip_below_high_water_breaks_the_streak(self):
        ctl = BrownoutController(BrownoutSpec(high_water=4, low_water=1,
                                              sustain=2))
        assert not ctl.observe(4)
        assert not ctl.observe(3)      # between the marks: streak broken
        assert not ctl.observe(4)
        assert ctl.observe(4)

    def test_low_water_deactivates_between_marks_holds(self):
        ctl = BrownoutController(BrownoutSpec(high_water=4, low_water=1,
                                              sustain=1))
        assert ctl.observe(4)
        assert ctl.observe(2)          # hysteresis band: stays active
        assert not ctl.observe(1)      # low water: reverts
        assert not ctl.observe(2)


# ---------------------------------------------------------------------------
# degrade_script: the pre-compiled brownout plan
# ---------------------------------------------------------------------------


DEGRADE_SOURCE = """
- default:
  - workers:
    - set: pool
    strategy: platform
- soft:
  - workers:
    - set: edge
      affinity: [cache]
    anti-affinity: [noisy]
  followup: fail
  on-overload: relax-affinity
- wide:
  - controller: Ctl
    topology_tolerance: same
    workers:
    - set: edge
  followup: fail
  on-overload: any-zone
- hard:
  - workers:
    - set: edge
      affinity: [cache]
  followup: fail
  on-overload: reject
"""


class TestDegradeScript:
    def test_relax_affinity_strips_soft_constraints(self):
        degraded = degrade_script(parse_tapp(DEGRADE_SOURCE))
        soft = next(t for t in degraded.tags if t.tag == "soft")
        block = soft.blocks[0]
        assert block.affinity is None and block.anti_affinity is None
        assert all(item.affinity is None and item.anti_affinity is None
                   for item in block.workers)

    def test_any_zone_widens_topology_tolerance(self):
        degraded = degrade_script(parse_tapp(DEGRADE_SOURCE))
        wide = next(t for t in degraded.tags if t.tag == "wide")
        assert (wide.blocks[0].controller.topology_tolerance
                is TopologyTolerance.ALL)

    def test_reject_and_unopted_tags_pass_through(self):
        script = parse_tapp(DEGRADE_SOURCE)
        degraded = degrade_script(script)
        for name in ("default", "hard"):
            original = next(t for t in script.tags if t.tag == name)
            after = next(t for t in degraded.tags if t.tag == name)
            assert after == original

    def test_no_opt_in_means_no_degraded_plan(self):
        assert degrade_script(parse_tapp(DEFAULT_SCRIPT)) is None
        # reject alone needs no degraded *plan* either (handled at
        # admission time).
        reject_only = DEFAULT_SCRIPT.replace(
            "    invalidate: overload\n",
            "    invalidate: overload\n  on-overload: reject\n",
        )
        assert degrade_script(parse_tapp(reject_only)) is None


# ---------------------------------------------------------------------------
# Grammar: priority / on-overload lowering + round-trip
# ---------------------------------------------------------------------------


class TestOverloadGrammar:
    def test_priority_and_on_overload_parse(self):
        script = parse_tapp(BROWNOUT_SCRIPT)
        sticky = next(t for t in script.tags if t.tag == "sticky")
        assert sticky.on_overload is OnOverload.RELAX_AFFINITY
        never = next(t for t in script.tags if t.tag == "never")
        assert never.on_overload is OnOverload.REJECT
        hi = next(t for t in parse_tapp(PRIORITY_SCRIPT).tags
                  if t.tag == "hi")
        assert hi.blocks[0].priority == 5

    def test_priority_rejects_bool_and_negative(self):
        template = (
            "- t:\n"
            "  - workers:\n"
            "    - set: pool\n"
            "    priority: {value}\n"
        )
        for bad in ("true", "-1", "'2'"):
            with pytest.raises(TappParseError, match="priority"):
                parse_tapp(template.format(value=bad))

    def test_on_overload_rejects_unknown_and_duplicate(self):
        with pytest.raises(TappParseError):
            parse_tapp(
                "- t:\n"
                "  - workers:\n"
                "    - set: pool\n"
                "  on-overload: panic\n"
            )
        with pytest.raises(TappParseError, match="duplicate"):
            parse_tapp(
                "- t:\n"
                "  - workers:\n"
                "    - set: pool\n"
                "  - on-overload: reject\n"
                "  - on-overload: any-zone\n"
            )

    def test_serialize_round_trips_overload_clauses(self):
        script = parse_tapp(BROWNOUT_SCRIPT + (
            "- prio:\n"
            "  - workers:\n"
            "    - set: pool\n"
            "    priority: 7\n"
            "  followup: fail\n"
        ))
        rendered = script_to_yaml(script)
        assert "on-overload: relax-affinity" in rendered
        assert "priority: 7" in rendered
        assert parse_tapp(rendered).tags == script.tags


# ---------------------------------------------------------------------------
# Flat façade integration: queue / drain / expiry / shed / brownout
# ---------------------------------------------------------------------------


class TestFlatAdmissionQueue:
    def _tiny(self, **overload):
        return TappPlatform(
            pool_cluster(n_workers=1, slots=1), seed=0,
            policy=PRIORITY_SCRIPT,
            overload=OverloadSpec(**overload),
        )

    def test_saturated_invoke_parks_then_drains_on_complete(self):
        p = self._tiny(queue=QueueSpec(depth=4))
        first = p.invoke("fn", now=0.0)
        assert first.scheduled
        waiting = p.invoke("fn", now=0.0)
        assert not waiting.scheduled and waiting.queued
        assert p.stats().queue_depth == 1
        first.complete(now=2.0)
        assert waiting.scheduled and waiting.queue_outcome == "drained"
        assert waiting.queue_wait == 2.0
        waiting.complete(now=3.0)
        stats = p.stats()
        assert ledger_ok(stats) and stats.inflight == 0
        assert stats.queued == 1 and stats.queue_depth == 0

    def test_expired_entries_are_counted_and_never_placed(self):
        p = self._tiny(queue=QueueSpec(depth=4, deadline=5.0))
        first = p.invoke("fn", now=0.0)
        stale = p.invoke("fn", now=0.0)
        assert stale.queued
        first.complete(now=10.0)  # past the 5s deadline
        assert not stale.scheduled
        assert stale.queue_outcome == "deadline_exceeded"
        stats = p.stats()
        assert stats.deadline_exceeded == 1 and stats.queue_depth == 0
        assert ledger_ok(stats)

    def test_full_queue_sheds_by_tag_priority(self):
        p = self._tiny(queue=QueueSpec(depth=1))
        busy = p.invoke("fn", now=0.0)
        lo = p.invoke("fn", tag="lo", now=0.0)
        assert lo.queued and lo.queue_outcome is None
        hi = p.invoke("fn", tag="hi", now=0.0)
        # The higher-priority newcomer evicted the queued lo entry.
        assert hi.queued and lo.queue_outcome == "shed"
        lo2 = p.invoke("fn", tag="lo", now=0.0)
        assert not lo2.queued and lo2.queue_outcome == "shed"
        assert p.stats().shed == 2
        busy.complete(now=1.0)
        assert hi.scheduled and hi.queue_outcome == "drained"

    def test_explain_reports_queue_state(self):
        p = self._tiny(queue=QueueSpec(depth=2))
        p.invoke("fn", now=0.0)
        p.invoke("fn", now=0.0)
        report = p.explain("fn")
        note = "\n".join(report.failure_notes)
        assert "overload queue" in note and "depth 1/2" in note

    def test_unarmed_platform_has_zero_overload_counters(self):
        p = TappPlatform(pool_cluster(1, 1), seed=0, policy=PRIORITY_SCRIPT)
        p.invoke("fn")
        rejected = p.invoke("fn")
        assert not rejected.scheduled and not rejected.queued
        stats = p.stats()
        assert stats.queued == stats.shed == stats.queue_depth == 0


class TestBrownoutIntegration:
    def _platform(self):
        return TappPlatform(
            pool_cluster(n_workers=3, slots=2), seed=0,
            policy=BROWNOUT_SCRIPT,
            overload=OverloadSpec(
                queue=QueueSpec(depth=8),
                brownout=BrownoutSpec(high_water=2, low_water=0, sustain=2),
            ),
        )

    def _saturate_sticky(self, p):
        """Three sticky_fn placements make every worker anti-affine to
        the tag; later sticky invokes fail by policy and queue up.
        Depth is observed *before* each offer, so after three queued
        entries the sustain streak is one observation short of
        activating — the next overflow tips it."""
        live = [p.invoke("sticky_fn", tag="sticky", now=float(i))
                for i in range(3)]
        assert all(pl.scheduled for pl in live)
        queued = [p.invoke("sticky_fn", tag="sticky", now=3.0 + i)
                  for i in range(3)]
        assert all(pl.queued for pl in queued)
        assert not p.brownout_active
        return live, queued

    def test_sustained_saturation_reroutes_through_degraded_plan(self):
        p = self._platform()
        live, queued = self._saturate_sticky(p)
        # on-overload: relax-affinity → once sustained saturation flips
        # the brownout bit, the degraded plan drops the anti-affinity
        # clause and the free slots become eligible. The tipping invoke
        # itself is served through the degraded plan.
        rerouted = [p.invoke("sticky_fn", tag="sticky", now=7.0 + i)
                    for i in range(2)]
        assert p.brownout_active
        assert all(pl.scheduled for pl in rerouted)
        assert p.stats().brownout_reroutes == 2

    def test_reject_tags_shed_immediately_under_brownout(self):
        p = self._platform()
        self._saturate_sticky(p)
        tipping = p.invoke("sticky_fn", tag="sticky", now=7.0)
        assert tipping.scheduled and p.brownout_active
        # Fill remaining capacity so `never` cannot route normally.
        fillers = []
        while True:
            filler = p.invoke("filler", now=20.0)
            if not filler.scheduled:
                break
            fillers.append(filler)
        dropped = p.invoke("never_fn", tag="never", now=21.0)
        assert not dropped.scheduled and dropped.queue_outcome == "shed"
        assert p.stats().shed >= 1

    def test_brownout_reverts_at_low_water(self):
        p = self._platform()
        placements, queued = self._saturate_sticky(p)
        tipping = p.invoke("sticky_fn", tag="sticky", now=7.0)
        assert tipping.scheduled and p.brownout_active
        placements = placements + [tipping]
        # Retiring the live work drains the queue (anti-affinity clears
        # as sticky_fn instances finish) and depth falls to low water.
        for _ in range(4):  # drained entries need completes too
            for pl in list(placements) + list(queued):
                if pl.scheduled and not pl.completed:
                    pl.complete(now=30.0)
        assert not p.brownout_active
        stats = p.stats()
        assert stats.queue_depth == 0 and stats.inflight == 0
        assert ledger_ok(stats)


class TestDuplicateComplete:
    def test_double_complete_is_idempotent_but_loud(self):
        p = TappPlatform(pool_cluster(1, 1), seed=0, policy=DEFAULT_SCRIPT)
        placement = p.invoke("fn")
        assert placement.complete() is True
        before = p.stats()
        assert placement.complete() is False
        after = p.stats()
        assert after.duplicate_completions == 1
        assert before.duplicate_completions == 0
        # The ledger was not touched twice.
        assert after.completed == before.completed == 1
        assert ledger_ok(after)

    def test_unadmitted_complete_is_not_a_duplicate(self):
        p = TappPlatform(pool_cluster(1, 1), seed=0, policy=DEFAULT_SCRIPT)
        p.invoke("fn")
        rejected = p.invoke("fn")
        assert not rejected.admitted
        assert rejected.complete() is False
        assert rejected.complete() is False
        assert p.stats().duplicate_completions == 0


class TestDegradedDryRun:
    def test_dry_run_verifies_the_brownout_plan(self):
        p = TappPlatform(pool_cluster(3, 2), seed=0)
        dry = p.dry_run_policy(BROWNOUT_SCRIPT)
        assert dry.degraded_analysis is not None
        p2 = TappPlatform(pool_cluster(3, 2), seed=0)
        plain = p2.dry_run_policy(DEFAULT_SCRIPT)
        assert plain.degraded_analysis is None
