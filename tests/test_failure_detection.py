"""Failure-domain robustness (PR 6): heartbeat-lease failure detection,
retry/backoff re-routing, unknown-worker platform errors, idempotent
completion, and partition-tolerant federation forwarding."""
import dataclasses

import pytest

from repro.core.platform import (
    ClusterSpec,
    ControllerSpec,
    FederationSpec,
    HealthState,
    LeaseConfig,
    RetryPolicy,
    TappFederation,
    TappPlatform,
    UnknownWorkerError,
    WorkerSpec,
)
from repro.core.scheduler.gateway import forward_targets
from repro.core.scheduler.topology import DistributionPolicy
from repro.core.sim.core import NetworkModel
from repro.core.tapp import parse_tapp

SPEC = ClusterSpec(
    controllers=(ControllerSpec("Ctl", zone="z"),),
    workers=tuple(
        WorkerSpec(f"w{i}", zone="z", sets=("z", "any"), capacity_slots=4)
        for i in range(4)
    ),
)

BLANK = (
    "- default:\n"
    "  - workers:\n"
    "    - set:\n"
    "    strategy: platform\n"
    "    invalidate: overload\n"
)


def platform(**kwargs) -> TappPlatform:
    return TappPlatform(
        SPEC, distribution=DistributionPolicy.SHARED, seed=0, policy=BLANK,
        **kwargs
    )


def ledger_holds(stats) -> bool:
    return stats.admitted == stats.completed + stats.evicted + stats.inflight


# ---------------------------------------------------------------------------
# Heartbeat leases: HEALTHY → SUSPECT → DEAD → recovery
# ---------------------------------------------------------------------------


def lease_platform() -> TappPlatform:
    return platform(lease=LeaseConfig(suspect_after=1.0, dead_after=4.0))


class TestLeases:
    def test_fresh_lease_keeps_worker_healthy(self):
        p = lease_platform()
        p.heartbeat_lease("w0", 0.0)
        assert p.check_leases(0.5) == []
        assert p.cluster.workers["w0"].health is HealthState.HEALTHY

    def test_expired_lease_marks_suspect_then_dead(self):
        p = lease_platform()
        p.heartbeat_lease("w0", 0.0)
        [t] = p.check_leases(2.0)
        assert (t.worker, t.previous, t.state) == (
            "w0", HealthState.HEALTHY, HealthState.SUSPECT
        )
        w = p.cluster.workers["w0"]
        assert w.suspect and w.healthy and w.reachable  # still placeable
        [t] = p.check_leases(5.0)
        assert t.state is HealthState.DEAD
        assert w.dead and not w.healthy and not w.reachable

    def test_suspect_worker_sorts_after_healthy_peers(self):
        p = lease_platform()
        # Shared-distribution platform strategy picks the least-loaded
        # worker; make w0 the clear winner, then suspect it.
        for name in ("w1", "w2", "w3"):
            p.heartbeat(name, inflight=2)
        assert p.invoke("fn").worker == "w0"
        p.suspect_worker("w0")
        assert p.cluster.workers["w0"].suspect
        assert p.invoke("fn").worker != "w0"  # deprioritized, not excluded
        # With every worker suspect, w0 is placeable again.
        for name in ("w1", "w2", "w3"):
            p.suspect_worker(name)
        assert p.invoke("fn").scheduled

    def test_dead_worker_excluded_and_tickets_evicted(self):
        p = lease_platform()
        placements = [p.invoke("fn") for _ in range(4)]
        victim = placements[0].worker
        evicted = p.fail_worker(victim)
        assert evicted == sum(1 for pl in placements if pl.worker == victim)
        stats = p.stats()
        assert stats.dead_workers == 1 and ledger_holds(stats)
        for _ in range(8):
            assert p.invoke("fn").worker != victim
        # Completing an evicted ticket is a no-op, not a double-count.
        assert placements[0].complete() is False
        assert ledger_holds(p.stats())

    def test_lease_expiry_evicts_like_a_crash(self):
        p = lease_platform()
        pl = p.invoke("fn")
        p.heartbeat_lease(pl.worker, 0.0)
        transitions = p.check_leases(10.0)  # straight past dead_after
        dead = [t for t in transitions if t.state is HealthState.DEAD]
        assert dead and dead[0].evicted == 1
        assert ledger_holds(p.stats())
        assert pl.ticket_alive is False

    def test_recovery_heartbeat_restores_healthy(self):
        p = lease_platform()
        p.heartbeat_lease("w0", 0.0)
        p.check_leases(10.0)
        assert p.cluster.workers["w0"].dead
        t = p.heartbeat_lease("w0", 11.0)
        assert t is not None and t.state is HealthState.HEALTHY
        w = p.cluster.workers["w0"]
        assert w.healthy and w.reachable and not w.dead
        assert p.check_leases(11.5) == []
        # A revived worker takes placements again.
        assert any(p.invoke("fn").worker == "w0" for _ in range(8))

    def test_generation_guards_completion_across_crash_revival(self):
        p = lease_platform()
        pl = p.invoke("fn")
        p.fail_worker(pl.worker)
        p.restore(pl.worker)
        w = p.cluster.workers[pl.worker]
        assert w.generation == 1 and w.inflight == 0
        # The pre-crash ticket must not decrement the new incarnation.
        assert pl.complete() is False
        assert w.inflight == 0 and ledger_holds(p.stats())

    def test_check_leases_requires_config(self):
        p = platform()  # no LeaseConfig
        with pytest.raises(ValueError):
            p.check_leases(1.0)

    def test_lease_config_validation(self):
        with pytest.raises(ValueError):
            LeaseConfig(suspect_after=0.0)
        with pytest.raises(ValueError):
            LeaseConfig(suspect_after=5.0, dead_after=1.0)


# ---------------------------------------------------------------------------
# Satellite: unknown/deregistered workers raise UnknownWorkerError
# ---------------------------------------------------------------------------


class TestUnknownWorker:
    def test_heartbeat_unknown_worker_raises(self):
        p = platform()
        with pytest.raises(UnknownWorkerError) as err:
            p.heartbeat("ghost", inflight=1)
        assert err.value.worker == "ghost"
        assert isinstance(err.value, KeyError)
        assert "deregistered" in str(err.value)

    def test_mark_unhealthy_unknown_worker_raises(self):
        p = platform()
        with pytest.raises(UnknownWorkerError):
            p.mark_unhealthy("ghost")

    def test_heartbeat_never_resurrects_deregistered_worker(self):
        p = platform()
        p.remove_worker("w3")
        with pytest.raises(UnknownWorkerError):
            p.heartbeat("w3", inflight=0, healthy=True)
        assert "w3" not in p.cluster.workers

    def test_mark_unhealthy_after_deregistration_raises(self):
        p = platform()
        p.remove_worker("w3")
        with pytest.raises(UnknownWorkerError):
            p.mark_unhealthy("w3")

    def test_lease_and_failure_entry_points_wrapped_too(self):
        p = lease_platform()
        for call in (
            lambda: p.heartbeat_lease("ghost", 0.0),
            lambda: p.fail_worker("ghost"),
            lambda: p.suspect_worker("ghost"),
            lambda: p.drain("ghost"),
            lambda: p.restore("ghost"),
            lambda: p.mark_unreachable("ghost"),
        ):
            with pytest.raises(UnknownWorkerError):
                call()


# ---------------------------------------------------------------------------
# Satellite: Placement.complete() is idempotent
# ---------------------------------------------------------------------------


class TestIdempotentComplete:
    def test_double_complete_does_not_double_decrement(self):
        p = platform()
        pl = p.invoke("fn")
        assert pl.complete() is True
        assert pl.complete() is False
        stats = p.stats()
        assert stats.completed == 1 and stats.inflight == 0
        assert ledger_holds(stats)

    def test_complete_racing_deregistration_eviction(self):
        p = platform()
        pl = p.invoke("fn")
        p.remove_worker(pl.worker)
        evicted_before = p.stats().evicted
        assert evicted_before == 1
        # The ticket died with the worker: complete() must not turn the
        # eviction into a completion as well.
        assert pl.complete() is False
        stats = p.stats()
        assert (stats.completed, stats.evicted) == (0, evicted_before)
        assert ledger_holds(stats)

    def test_unadmitted_placement_complete_is_noop(self):
        p = TappPlatform(
            ClusterSpec(controllers=(ControllerSpec("C"),)),
            policy=BLANK,
        )
        pl = p.invoke("fn")
        assert not pl.scheduled
        assert pl.complete() is False
        assert ledger_holds(p.stats())


# ---------------------------------------------------------------------------
# RetryPolicy: resolution order, backoff, terminal policy failures
# ---------------------------------------------------------------------------


class TestRetryPolicy:
    def test_backoff_is_deterministic_exponential(self):
        policy = RetryPolicy(max_attempts=4, backoff_base=0.1,
                             backoff_multiplier=2.0)
        assert [policy.backoff(k) for k in (1, 2, 3)] == [0.1, 0.2, 0.4]
        assert policy.allows(3) and not policy.allows(4)

    def test_deadline_caps_cumulative_backoff(self):
        policy = RetryPolicy(max_attempts=10, backoff_base=1.0,
                             backoff_multiplier=2.0, deadline=2.5)
        assert policy.allows(1, 0.0)          # +1.0 <= 2.5
        assert not policy.allows(2, 1.0)      # 1.0 + 2.0 > 2.5

    def test_validation(self):
        with pytest.raises(ValueError):
            RetryPolicy(max_attempts=0)
        with pytest.raises(ValueError):
            RetryPolicy(backoff_multiplier=0.0)

    def test_retry_reroutes_around_failed_worker(self):
        p = platform(retry=RetryPolicy(max_attempts=3, backoff_base=0.05))
        pl = p.invoke("fn")
        p.fail_worker(pl.worker)
        replacement = p.retry(pl)
        assert replacement is not None and replacement.scheduled
        assert replacement.worker != pl.worker
        assert replacement.attempts == 2
        assert replacement.retry_wait == pytest.approx(0.05)
        assert replacement.failed_workers == (pl.worker,)
        assert p.stats().retries == 1

    def test_retry_excludes_every_previously_failed_worker(self):
        p = platform(retry=RetryPolicy(max_attempts=4))
        pl = p.invoke("fn")
        tried = [pl.worker]
        for _ in range(2):
            p.fail_worker(pl.worker)
            pl = p.retry(pl)
            assert pl is not None and pl.worker not in tried
            tried.append(pl.worker)
        assert pl.failed_workers == tuple(tried[:-1])

    def test_retry_mask_restores_reachability(self):
        p = platform(retry=RetryPolicy(max_attempts=2))
        pl = p.invoke("fn")
        victim = pl.worker
        p.fail_worker(victim)
        p.retry(pl)
        # Only the DEAD worker stays unreachable; the mask rolled back.
        assert all(
            w.reachable for n, w in p.cluster.workers.items() if n != victim
        )

    def test_retry_budget_exhaustion_returns_none(self):
        p = platform(retry=RetryPolicy(max_attempts=2))
        pl = p.invoke("fn")
        p.fail_worker(pl.worker)
        second = p.retry(pl)
        assert second is not None and second.attempts == 2
        assert p.retry(second) is None  # max_attempts spent

    def test_no_policy_means_no_retry(self):
        p = platform()
        pl = p.invoke("fn")
        assert p.retry(pl) is None
        assert p.stats().retries == 0

    def test_controller_policy_beats_platform_default(self):
        spec = dataclasses.replace(
            SPEC,
            controllers=(
                ControllerSpec("Ctl", zone="z",
                               retry=RetryPolicy(max_attempts=5)),
            ),
        )
        p = TappPlatform(spec, distribution=DistributionPolicy.SHARED,
                         seed=0, policy=BLANK,
                         retry=RetryPolicy(max_attempts=2))
        pl = p.invoke("fn")
        assert pl.controller == "Ctl"
        assert p._retry_policy_for(pl.controller, None).max_attempts == 5
        override = RetryPolicy(max_attempts=9)
        assert p._retry_policy_for(pl.controller, override) is override

    def test_followup_fail_is_terminal(self):
        script = (
            BLANK
            + "- pinned:\n"
            + "  - workers:\n"
            + "    - wrk: nope\n"
            + "  followup: fail\n"
        )
        p = TappPlatform(SPEC, distribution=DistributionPolicy.SHARED,
                         seed=0, policy=script,
                         retry=RetryPolicy(max_attempts=5))
        pl = p.invoke("fn", tag="pinned")
        assert not pl.scheduled and pl.failed_by_policy
        assert pl.attempts == 1          # the invoke loop never retried
        assert p.retry(pl) is None       # and neither does explicit retry
        assert p.stats().retries == 0

    def test_exhausted_route_is_policy_terminal_not_retried(self):
        # One worker, kill it: the route exhausts and the engine marks
        # the failure as the policy's verdict — invoke must not burn the
        # retry budget re-running a deterministic policy decision.
        p = TappPlatform(
            ClusterSpec(controllers=(ControllerSpec("Ctl"),),
                        workers=(WorkerSpec("only"),)),
            policy=BLANK,
            retry=RetryPolicy(max_attempts=3, backoff_base=0.1),
        )
        p.fail_worker("only")
        pl = p.invoke("fn")
        assert not pl.scheduled and pl.failed_by_policy
        assert pl.attempts == 1
        assert p.stats().retries == 0

    def test_invoke_batch_matches_sequential_invokes(self):
        kwargs = dict(distribution=DistributionPolicy.SHARED, seed=0,
                      policy=BLANK,
                      retry=RetryPolicy(max_attempts=2))
        a = TappPlatform(SPEC, **kwargs)
        b = TappPlatform(SPEC, **kwargs)
        a.fail_worker("w0")
        b.fail_worker("w0")
        seq = [a.invoke(f"fn{i % 3}") for i in range(12)]
        batch = b.invoke_batch([f"fn{i % 3}" for i in range(12)])
        assert [(p.worker, p.attempts) for p in seq] == [
            (p.worker, p.attempts) for p in batch
        ]


# ---------------------------------------------------------------------------
# Partition-tolerant federation
# ---------------------------------------------------------------------------


def zone_slice(prefix: str, ctl: str) -> ClusterSpec:
    return ClusterSpec(
        controllers=(ControllerSpec(ctl),),
        workers=tuple(
            WorkerSpec(f"{prefix}{i}", sets=(prefix, "any"), capacity_slots=4)
            for i in range(2)
        ),
    )


def federation(**kwargs) -> TappFederation:
    spec = FederationSpec.of(
        {
            "a": zone_slice("a", "ACtl"),
            "b": zone_slice("b", "BCtl"),
            "c": zone_slice("c", "CCtl"),
        },
        network=NetworkModel(
            rtt={("a", "b"): 0.010, ("a", "c"): 0.030, ("b", "c"): 0.020},
            bandwidth={},
        ),
    )
    return TappFederation(
        spec, distribution=DistributionPolicy.SHARED, seed=0, policy=BLANK,
        **kwargs
    )


HOME_B_SCRIPT = (
    BLANK
    + "- pinned_b:\n"
    + "  - controller: BCtl\n"
    + "    workers:\n"
    + "    - set: b\n"
    + "    topology_tolerance: none\n"
    + "  followup: fail\n"
    + "- home_b_roam:\n"
    + "  - controller: BCtl\n"
    + "    workers:\n"
    + "    - set: b\n"
    + "    topology_tolerance: all\n"
)


class TestPartitions:
    def test_sever_heal_bookkeeping(self):
        f = federation()
        f.sever("a", "b")
        f.sever("a", "b")  # idempotent
        assert f.partitioned("a", "b") and f.partitioned("b", "a")
        assert f.partitions == (("a", "b"),)
        f.heal("a", "b")
        assert f.partitions == ()
        with pytest.raises(ValueError):
            f.sever("a", "a")
        with pytest.raises(ValueError):
            f.sever("a", "nope")

    def test_forward_targets_skip_partitioned_zone(self):
        f = federation()
        script = parse_tapp(BLANK)
        targets = forward_targets(script, None, f.cluster, "a", ("b", "c"))
        assert targets == ["b", "c"]
        filtered = forward_targets(script, None, f.cluster, "a", ("b", "c"),
                                   unreachable=frozenset({"b"}))
        assert filtered == ["c"]

    def test_forwarding_routes_around_partition(self):
        f = federation()
        # Fill zone a so its local pass declines and forwarding kicks in.
        for w in ("a0", "a1"):
            f.heartbeat(w, inflight=4)
        assert f.cluster.workers["a0"].overloaded
        baseline = f.invoke("fn", entry_zone="a")
        assert baseline.scheduled
        assert f.cluster.workers[baseline.worker].zone == "b"  # nearest
        f.sever("a", "b")
        rerouted = f.invoke("fn", entry_zone="a")
        assert rerouted.scheduled
        assert f.cluster.workers[rerouted.worker].zone == "c"

    def test_tolerance_none_never_escapes_home_mid_partition(self):
        f = federation()
        f.apply_policy(HOME_B_SCRIPT)
        placed = f.invoke("fn", tag="pinned_b", entry_zone="a")
        assert placed.scheduled
        assert f.cluster.workers[placed.worker].zone == "b"
        f.sever("a", "b")
        for _ in range(6):
            pl = f.invoke("fn", tag="pinned_b", entry_zone="a")
            assert not pl.scheduled  # fails; never lands outside zone b
        # Entering AT the home zone still works: the partition only cuts
        # the a↔b link.
        assert f.invoke("fn", tag="pinned_b", entry_zone="b").scheduled
        f.heal("a", "b")
        healed = f.invoke("fn", tag="pinned_b", entry_zone="a")
        assert healed.scheduled
        assert f.cluster.workers[healed.worker].zone == "b"

    def test_dead_zone_skipped_without_explicit_partition(self):
        f = federation()
        for w in ("a0", "a1"):
            f.heartbeat(w, inflight=4)
        for w in ("b0", "b1"):
            f.fail_worker(w)
        pl = f.invoke("fn", entry_zone="a")
        assert pl.scheduled
        assert f.cluster.workers[pl.worker].zone == "c"
        report = f.explain("fn", entry_zone="a")
        assert "b" in report.unreachable_zones

    def test_federated_retry_reroutes_around_dead_zone(self):
        f = federation(retry=RetryPolicy(max_attempts=3))
        # Drain zone a so the baseline placement forwards to b.
        for w in ("a0", "a1"):
            f.drain(w)
        pl = f.invoke("fn", entry_zone="a")
        assert f.cluster.workers[pl.worker].zone == "b"
        for w in ("b0", "b1"):
            f.fail_worker(w)
        assert pl.ticket_alive is False
        replacement = f.retry(pl)
        assert replacement is not None and replacement.scheduled
        assert f.cluster.workers[replacement.worker].zone != "b"
        assert replacement.attempts == 2
        assert replacement.entry_zone == "a"
        stats = f.stats()
        assert stats.aggregate.retries == 1
        assert ledger_holds(stats.aggregate)

    def test_severed_designated_route_burns_retry_budget(self):
        # A partition failure is NOT a policy verdict, so the invoke
        # loop retries it; with the partition still up every attempt
        # fails deterministically and the budget is spent.
        f = federation(retry=RetryPolicy(max_attempts=3, backoff_base=0.1))
        f.apply_policy(HOME_B_SCRIPT)
        f.sever("a", "b")
        pl = f.invoke("fn", tag="pinned_b", entry_zone="a")
        assert not pl.scheduled and not pl.failed_by_policy
        assert pl.attempts == 3
        assert pl.retry_wait == pytest.approx(0.1 + 0.2)
        assert f.stats().aggregate.retries == 2
        f.heal("a", "b")
        healed = f.invoke("fn", tag="pinned_b", entry_zone="a")
        assert healed.scheduled and healed.attempts == 1

    def test_explain_mirrors_partitioned_route(self):
        f = federation()
        f.apply_policy(HOME_B_SCRIPT)
        f.sever("a", "b")
        report = f.explain("fn", tag="pinned_b", entry_zone="a")
        assert not report.scheduled
        assert report.unreachable_zones == ("b",)
        live = f.invoke("fn", tag="pinned_b", entry_zone="a")
        assert live.scheduled == report.scheduled

    def test_partition_preserves_forward_order_after_heal(self):
        f = federation()
        for w in ("a0", "a1"):
            f.heartbeat(w, inflight=4)
        before = f.invoke("fn", entry_zone="a").worker
        f.sever("a", "b")
        f.invoke("fn", entry_zone="a")
        f.heal("a", "b")
        after = f.invoke("fn", entry_zone="a")
        assert f.cluster.workers[after.worker].zone == (
            f.cluster.workers[before].zone
        )


# ---------------------------------------------------------------------------
# Satellite: federation-wide ledger conservation under churn
# ---------------------------------------------------------------------------


class TestFederationLedgerChurn:
    def test_conservation_under_drain_restore_deregister_churn(self):
        f = federation(retry=RetryPolicy(max_attempts=2))
        open_placements = []
        step = 0
        for round_no in range(6):
            for zone in ("a", "b", "c"):
                for _ in range(4):
                    pl = f.invoke(f"fn{step % 5}", entry_zone=zone)
                    step += 1
                    if pl.scheduled:
                        open_placements.append(pl)
            if round_no == 1:
                f.drain("b0")
            if round_no == 2:
                f.fail_worker("c1")
                f.sever("a", "c")
            if round_no == 3:
                f.restore("b0")
                f.heal("a", "c")
                f.remove_worker("a1")
            if round_no == 4:
                f.restore("c1")
                f.add_worker(WorkerSpec("a2", zone="a", sets=("a", "any"),
                                        capacity_slots=4))
            # Complete roughly half of what is open, oldest first.
            keep = []
            for index, pl in enumerate(open_placements):
                if index % 2 == 0:
                    pl.complete()
                else:
                    keep.append(pl)
            open_placements = keep
            stats = f.stats()
            assert ledger_holds(stats.aggregate), (round_no, stats.aggregate)
            # Zone inflight rows sum to the aggregate inflight.
            assert sum(z.inflight for z in stats.zones) == (
                stats.aggregate.inflight
            )
        for pl in open_placements:
            pl.complete()
        final = f.stats().aggregate
        assert final.inflight == 0
        assert ledger_holds(final)
        # entered splits across the three entry zones.
        by_zone = {z.zone: z.entered for z in f.stats().zones}
        assert sum(by_zone.values()) >= step
