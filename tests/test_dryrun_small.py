"""Small-mesh dry-run: lower+compile the sharded steps in a subprocess
with 8 host devices (the production dry-run uses 512; same code path).
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax
    from repro.configs import smoke_config
    from repro.launch.steps import (
        abstract_train_state, make_train_step, make_decode_step,
        train_state_shardings,
    )
    from repro.launch.mesh import make_debug_mesh
    from repro.models.api import Model, ShapeSpec
    from repro.optim.adamw import AdamWConfig
    from repro.sharding.ctx import activation_sharding
    from repro.sharding.specs import (
        ShardingPolicy, batch_shardings, cache_shardings, param_shardings,
    )
    from repro.roofline.analysis import normalize_cost, roofline_terms

    arch = {arch!r}
    cfg = dataclasses.replace(
        smoke_config(arch), d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    )
    mesh = make_debug_mesh((2, 4), ("data", "model"))
    policy = ShardingPolicy(fsdp_min_params=0).for_mesh(mesh)
    model = Model(cfg)
    shape = ShapeSpec("small", "train", 32, 8)
    results = {{}}
    with mesh, activation_sharding(mesh, policy.dp_axes, policy.tp_axis):
        # train step
        step = make_train_step(cfg, AdamWConfig())
        state = abstract_train_state(cfg)
        st_sh = train_state_shardings(cfg, policy, mesh, state)
        batch = model.input_specs(shape)
        b_sh = batch_shardings(cfg, policy, mesh, shape, batch)
        lowered = jax.jit(step, in_shardings=(st_sh, b_sh),
                          out_shardings=(st_sh, None)).lower(state, batch)
        compiled = lowered.compile()
        cost = normalize_cost(compiled.cost_analysis())
        results["train_flops"] = cost.get("flops", 0)
        terms = roofline_terms(
            cost=cost, hlo_text=compiled.as_text(),
            n_chips=8, model_flops_total=1.0,
        )
        results["train_collective_wire"] = terms.wire_bytes_per_device
        # decode step
        dshape = ShapeSpec("smalldec", "decode", 64, 8)
        dec = make_decode_step(cfg)
        params = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
        p_sh = param_shardings(cfg, policy, mesh, params)
        cache = model.cache_specs(dshape)
        c_sh = cache_shardings(cfg, policy, mesh, cache)
        ins = model.input_specs(dshape)
        i_sh = batch_shardings(cfg, policy, mesh, dshape, ins)
        dl = jax.jit(dec, in_shardings=(p_sh, c_sh, i_sh["token"],
                                        i_sh["position"]),
                     out_shardings=(None, c_sh)).lower(
            params, cache, ins["token"], ins["position"])
        dl.compile()
        results["decode_ok"] = True
    print("RESULT:" + json.dumps(results))
    """
)


def _run(arch: str) -> dict:
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT.format(arch=arch)],
        capture_output=True, text=True, timeout=420,
        env={**os.environ, "PYTHONPATH": str(ROOT / "src")},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise AssertionError(f"no RESULT line in: {proc.stdout[-500:]}")


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["smollm_135m", "phi3_5_moe_42b", "mamba2_2_7b"])
def test_sharded_lower_compile(arch):
    results = _run(arch)
    assert results["decode_ok"]
    assert results["train_flops"] > 0
    # a sharded train step must move bytes over the mesh
    assert results["train_collective_wire"] > 0
