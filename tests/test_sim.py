"""Simulator reproduction of the paper's evaluation (§5)."""
import statistics


from repro.core.sim.scenarios import (
    run_benchmark,
    run_colocation_case,
    run_mqtt_case,
)


class TestQualitativeMQTT:
    """§5.1: vanilla fails every invocation in the unlucky deployment;
    tAPP succeeds in every deployment."""

    def test_vanilla_fails_unlucky_deployment(self):
        results = run_mqtt_case(use_tapp=False, minutes=10, cloud_first=True)
        assert results["data-collection"].failure_rate == 1.0

    def test_vanilla_ok_lucky_deployment(self):
        results = run_mqtt_case(use_tapp=False, minutes=10, cloud_first=False)
        assert results["data-collection"].failure_rate == 0.0

    def test_tapp_succeeds_both_deployments(self):
        for cloud_first in (True, False):
            results = run_mqtt_case(use_tapp=True, minutes=10,
                                    cloud_first=cloud_first)
            for fn, res in results.items():
                assert res.failure_rate == 0.0, (fn, cloud_first)

    def test_tapp_pins_functions_to_zones(self):
        results = run_mqtt_case(use_tapp=True, minutes=10)
        dc_workers = {r.worker for r in results["data-collection"].records}
        fa_workers = {r.worker for r in results["feature-analysis"].records}
        assert dc_workers == {"W_1"}   # MQTT tag → edge only
        assert fa_workers == {"W_2"}   # Cloud tag → cloud only


def _avg_over_deployments(test, scheduler, tagged=False, n=6):
    means, stds = [], []
    for seed in range(n):
        _, res = run_benchmark(test, scheduler=scheduler, tagged=tagged,
                               seed=seed)
        s = res.summary()
        means.append(s["mean"])
        stds.append(s["std"])
    return statistics.fmean(means), statistics.pstdev(means)


class TestOverheadTests:
    """§5.4.1: topology-aware scheduling does not hurt — and the default
    policy outperforms vanilla on compute-style functions."""

    def test_no_failures(self):
        for sched in ("vanilla", "default", "isolated", "shared"):
            _, res = run_benchmark("hellojs", scheduler=sched, seed=0)
            assert res.failure_rate == 0.0

    def test_default_policy_not_worse_than_vanilla(self):
        v, _ = _avg_over_deployments("hellojs", "vanilla")
        d, _ = _avg_over_deployments("hellojs", "default")
        assert d <= v * 1.05

    def test_matrixmult_default_beats_vanilla(self):
        v, _ = _avg_over_deployments("matrixMult", "vanilla")
        d, _ = _avg_over_deployments("matrixMult", "default")
        assert d < v


class TestDataLocality:
    """§5.4.2: every policy beats vanilla; tagged tAPP is the most stable."""

    def test_policies_beat_vanilla_on_heavy_query(self):
        v, _ = _avg_over_deployments("data-locality", "vanilla")
        for sched in ("default", "min_memory", "isolated", "shared"):
            m, _ = _avg_over_deployments("data-locality", sched)
            assert m < v, sched

    def test_vanilla_has_the_worst_deployment_variance(self):
        _, v_spread = _avg_over_deployments("data-locality", "vanilla")
        _, t_spread = _avg_over_deployments("data-locality", "shared", tagged=True)
        assert t_spread < v_spread / 3

    def test_tagged_beats_untagged_shared_on_heavy_query(self):
        untagged, _ = _avg_over_deployments("data-locality", "shared")
        tagged, _ = _avg_over_deployments("data-locality", "shared", tagged=True)
        assert tagged < untagged

    def test_colocation_constraints_cut_interference(self):
        """Constraint layer v2: anti-affinity shields the latency-sensitive
        function from the noisy batch cruncher, and affinity co-locates the
        join with its cache warmer."""
        blank_means, constrained_means = [], []
        for seed in (0, 1):
            _, blank = run_colocation_case(
                constrained=False, seed=seed, requests_per_user=30
            )
            _, constrained = run_colocation_case(
                constrained=True, seed=seed, requests_per_user=30
            )
            assert blank.failure_rate == 0.0
            assert constrained.failure_rate == 0.0
            blank_means.append(
                blank.for_function("latency_api").summary()["mean"]
            )
            constrained_means.append(
                constrained.for_function("latency_api").summary()["mean"]
            )
            # Affinity: the join concentrates on cache_warmer workers.
            warm_hosts = set(
                constrained.for_function("cache_warmer").per_worker_counts()
            )
            join_counts = constrained.for_function(
                "feature_join"
            ).per_worker_counts()
            cohosted = sum(
                n for w, n in join_counts.items() if w in warm_hosts
            )
            assert cohosted / sum(join_counts.values()) > 0.5
        assert statistics.fmean(constrained_means) < statistics.fmean(
            blank_means
        )

    def test_tagged_is_stabler_on_light_query(self):
        # mongoDB: tagged is "a bit slower, but more stable" (paper wording).
        for seed in (0, 1):
            _, untagged = run_benchmark("mongoDB", scheduler="shared", seed=seed)
            _, tagged = run_benchmark("mongoDB", scheduler="shared",
                                      tagged=True, seed=seed)
            assert tagged.summary()["std"] <= untagged.summary()["std"]
