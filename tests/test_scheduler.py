"""Scheduler semantics: strategies, invalidation, engine, topology policies."""

from repro.core.scheduler import (
    ClusterState,
    ConstraintSpec,
    DistributionPolicy,
    Invocation,
    TappEngine,
    VanillaScheduler,
    WorkerState,
    compile_spec,
    constraint_reason,
    coprime_order,
    distribution_view,
    make_cluster,
    spec_predicate,
    spec_violated,
    stable_hash,
)
from repro.core.scheduler.constraints import invalid_reason, is_invalid
from repro.core.tapp import (
    Affinity,
    AntiAffinity,
    CapacityUsed,
    MaxConcurrentInvocations,
    Overload,
    parse_tapp,
)


def two_zone_cluster(**overrides) -> ClusterState:
    return make_cluster(
        workers=[
            dict(name="e0", zone="edge", sets=["edge", "any"], capacity_slots=2),
            dict(name="e1", zone="edge", sets=["edge", "any"], capacity_slots=2),
            dict(name="c0", zone="cloud", sets=["cloud", "any"], capacity_slots=4),
        ],
        controllers=[
            dict(name="EdgeCtl", zone="edge"),
            dict(name="CloudCtl", zone="cloud"),
        ],
    )


class TestStrategies:
    def test_coprime_order_is_permutation(self):
        for n in range(1, 40):
            for h in (0, 1, 17, stable_hash("fn")):
                order = coprime_order(n, h)
                assert sorted(order) == list(range(n))

    def test_coprime_home_is_stable(self):
        inv = Invocation(function="data-collection")
        first = coprime_order(5, inv.hash)[0]
        for _ in range(10):
            assert coprime_order(5, inv.hash)[0] == first


class TestInvalidate:
    def test_unreachable_always_invalid(self):
        w = WorkerState(name="w", reachable=False)
        for cond in (Overload(), CapacityUsed(99), MaxConcurrentInvocations(1000)):
            assert is_invalid(w, cond)
            assert invalid_reason(w, cond) == "unreachable"

    def test_overload(self):
        w = WorkerState(name="w", capacity_slots=2, inflight=2)
        assert is_invalid(w, Overload())
        assert not is_invalid(WorkerState(name="w", capacity_slots=2, inflight=1),
                              Overload())
        assert is_invalid(WorkerState(name="w", healthy=False), Overload())

    def test_capacity_used(self):
        w = WorkerState(name="w", capacity_used_pct=50.0)
        assert is_invalid(w, CapacityUsed(50))
        assert not is_invalid(w, CapacityUsed(51))

    def test_max_concurrent(self):
        w = WorkerState(name="w", inflight=40, queued=60)
        assert is_invalid(w, MaxConcurrentInvocations(100))
        assert not is_invalid(w, MaxConcurrentInvocations(101))


class TestConstraintLayer:
    """The predicate IR: spec resolution, evaluation paths agree, reasons."""

    def specs(self):
        return [
            ConstraintSpec(),
            ConstraintSpec(invalidate=CapacityUsed(50)),
            ConstraintSpec(affinity=Affinity(("warm",))),
            ConstraintSpec(anti_affinity=AntiAffinity(("noisy",))),
            ConstraintSpec(
                invalidate=MaxConcurrentInvocations(4),
                affinity=Affinity(("warm", "cache")),
                anti_affinity=AntiAffinity(("noisy", "batch")),
            ),
        ]

    def workers(self):
        return [
            WorkerState(name="idle"),
            WorkerState(name="gone", reachable=False),
            WorkerState(name="hot", capacity_used_pct=80.0, inflight=3,
                        queued=2),
            WorkerState(name="warmhost",
                        running_functions={"warm": 1, "cache": 2}),
            WorkerState(name="noisyhost",
                        running_functions={"warm": 1, "cache": 1, "noisy": 1}),
        ]

    def test_all_evaluation_paths_agree(self):
        """IR.violated == lowered closure == (reason is not None)."""
        for spec in self.specs():
            lowered = compile_spec(spec)
            predicate = spec_predicate(spec)
            for w in self.workers():
                expected = spec_violated(w, spec)
                assert lowered(w) == expected, (spec, w.name)
                assert predicate.violated(w) == expected, (spec, w.name)
                assert (constraint_reason(w, spec) is not None) == expected, (
                    spec, w.name,
                )

    def test_unreachable_is_preliminary_for_every_spec(self):
        gone = WorkerState(name="gone", reachable=False,
                           running_functions={"warm": 1})
        for spec in self.specs():
            assert spec_violated(gone, spec)
            assert constraint_reason(gone, spec) == "unreachable"

    def test_affinity_requires_all_listed(self):
        spec = ConstraintSpec(affinity=Affinity(("warm", "cache")))
        only_warm = WorkerState(name="w", running_functions={"warm": 3})
        both = WorkerState(name="w", running_functions={"warm": 1, "cache": 1})
        assert spec_violated(only_warm, spec)
        assert "cache" in constraint_reason(only_warm, spec)
        assert not spec_violated(both, spec)

    def test_anti_affinity_rejects_any_listed(self):
        spec = ConstraintSpec(anti_affinity=AntiAffinity(("noisy", "batch")))
        w = WorkerState(name="w", running_functions={"batch": 2})
        assert spec_violated(w, spec)
        assert "batch" in constraint_reason(w, spec)
        assert not spec_violated(WorkerState(name="w"), spec)

    def test_self_anti_affinity_spreads(self):
        """Listing a function's own name keeps a second instance off the
        worker — the spread idiom."""
        script = parse_tapp(
            "- f:\n  - workers:\n    - set:\n"
            "    anti-affinity: [f]\n  followup: fail\n"
        )
        cluster = two_zone_cluster()
        engine = TappEngine(DistributionPolicy.SHARED, seed=0)
        first = engine.schedule(Invocation("f", tag="f"), script, cluster)
        assert first.scheduled
        cluster.workers[first.worker].running_functions = {"f": 1}
        second = engine.schedule(Invocation("f", tag="f"), script, cluster)
        assert second.scheduled and second.worker != first.worker

    def test_engine_respects_affinity_via_script(self):
        script = parse_tapp(
            "- t:\n  - workers:\n    - set:\n"
            "    affinity: [svc]\n  followup: fail\n"
        )
        cluster = two_zone_cluster()
        engine = TappEngine(DistributionPolicy.SHARED, seed=0)
        d = engine.schedule(
            Invocation("f", tag="t"), script, cluster, trace=True
        )
        assert not d.scheduled and d.failed_by_policy  # svc runs nowhere
        assert any(
            "affinity: requires 'svc' running" in e.detail for e in d.trace
        )
        cluster.workers["c0"].running_functions = {"svc": 1}
        d = engine.schedule(Invocation("f", tag="t"), script, cluster)
        assert d.scheduled and d.worker == "c0"


class TestDistributionPolicies:
    def test_isolated_local_only(self):
        cluster = two_zone_cluster()
        views = distribution_view(cluster, "edge", DistributionPolicy.ISOLATED)
        assert {v.worker.name for v in views} == {"e0", "e1"}

    def test_default_splits_capacity(self):
        cluster = two_zone_cluster()
        views = distribution_view(cluster, "edge", DistributionPolicy.DEFAULT)
        by = {v.worker.name: v for v in views}
        assert by["c0"].slot_cap == 2  # 4 slots / 2 controllers
        assert not by["c0"].local

    def test_min_memory_foreign_gets_one_slot(self):
        cluster = two_zone_cluster()
        views = distribution_view(cluster, "edge", DistributionPolicy.MIN_MEMORY)
        by = {v.worker.name: v for v in views}
        assert by["c0"].slot_cap == 1
        assert by["e0"].slot_cap == 2

    def test_min_memory_unmanaged_zone_falls_back_to_default(self):
        cluster = two_zone_cluster()
        cluster.add_worker(
            WorkerState(name="x0", zone="nowhere", capacity_slots=4)
        )
        views = distribution_view(cluster, "edge", DistributionPolicy.MIN_MEMORY)
        by = {v.worker.name: v for v in views}
        assert by["x0"].slot_cap == 2  # default split, not the minimal slot

    def test_shared_orders_local_first(self):
        cluster = two_zone_cluster()
        views = distribution_view(cluster, "edge", DistributionPolicy.SHARED)
        assert [v.local for v in views] == [True, True, False]

    def test_zone_restriction_overrides(self):
        cluster = two_zone_cluster()
        views = distribution_view(
            cluster, "cloud", DistributionPolicy.SHARED, zone_restriction="edge"
        )
        assert {v.worker.name for v in views} == {"e0", "e1"}


SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- edge_only:
  - controller: EdgeCtl
    workers:
    - set: edge
    topology_tolerance: none
  followup: fail
- edge_pref:
  - workers:
    - wrk: e0
      invalidate: capacity_used 50%
    - wrk: e1
    strategy: best_first
  - workers:
    - set: cloud
  followup: default
- same_zone:
  - controller: EdgeCtl
    workers:
    - set:
    topology_tolerance: same
  followup: fail
"""


class TestEngine:
    def engine(self, policy=DistributionPolicy.SHARED):
        return TappEngine(policy, seed=7)

    def test_best_first_picks_first_valid(self):
        cluster = two_zone_cluster()
        script = parse_tapp(SCRIPT)
        d = self.engine().schedule(Invocation("f", tag="edge_pref"), script, cluster)
        assert d.scheduled and d.worker == "e0"

    def test_item_invalidate_overrides(self):
        cluster = two_zone_cluster()
        cluster.workers["e0"].capacity_used_pct = 60.0
        script = parse_tapp(SCRIPT)
        d = self.engine().schedule(Invocation("f", tag="edge_pref"), script, cluster)
        assert d.worker == "e1"

    def test_block_fallback_then_followup_default(self):
        cluster = two_zone_cluster()
        cluster.workers["e0"].capacity_used_pct = 99.0
        cluster.workers["e1"].reachable = False
        script = parse_tapp(SCRIPT)
        d = self.engine().schedule(Invocation("f", tag="edge_pref"), script, cluster)
        assert d.scheduled and d.worker == "c0"  # second block (cloud set)

    def test_followup_fail(self):
        cluster = two_zone_cluster()
        for w in cluster.workers.values():
            w.healthy = False
        script = parse_tapp(SCRIPT)
        d = self.engine().schedule(Invocation("f", tag="edge_only"), script, cluster)
        assert not d.scheduled

    def test_followup_default_reaches_default_tag(self):
        cluster = two_zone_cluster()
        cluster.workers["e0"].reachable = False
        cluster.workers["e1"].reachable = False
        script = parse_tapp(SCRIPT)
        d = self.engine().schedule(Invocation("f", tag="edge_pref"), script, cluster)
        # both blocks of edge_pref invalid except cloud set... cloud valid in
        # block 2, so default not needed; kill cloud too then expect fallback
        cluster.workers["c0"].healthy = False
        d = self.engine().schedule(Invocation("f", tag="edge_pref"), script, cluster)
        assert not d.scheduled
        assert d.used_default_fallback

    def test_unknown_tag_uses_default(self):
        cluster = two_zone_cluster()
        script = parse_tapp(SCRIPT)
        d = self.engine().schedule(Invocation("f", tag="nope"), script, cluster)
        assert d.tag == "default"
        assert d.scheduled

    def test_untagged_uses_default(self):
        cluster = two_zone_cluster()
        script = parse_tapp(SCRIPT)
        d = self.engine().schedule(Invocation("f"), script, cluster)
        assert d.tag == "default" and d.scheduled


class TestTopologyTolerance:
    def test_none_blocks_forwarding(self):
        cluster = two_zone_cluster()
        cluster.controllers["EdgeCtl"].healthy = False
        script = parse_tapp(SCRIPT)
        d = TappEngine(DistributionPolicy.SHARED, seed=1).schedule(
            Invocation("f", tag="edge_only"), script, cluster
        )
        assert not d.scheduled

    def test_same_restricts_zone(self):
        cluster = two_zone_cluster()
        cluster.controllers["EdgeCtl"].healthy = False
        script = parse_tapp(SCRIPT)
        d = TappEngine(DistributionPolicy.SHARED, seed=1).schedule(
            Invocation("f", tag="same_zone"), script, cluster
        )
        assert d.scheduled
        assert d.worker in ("e0", "e1")  # zone pinned to EdgeCtl's zone
        assert d.controller == "CloudCtl"

    def test_all_allows_any_zone(self):
        cluster = two_zone_cluster()
        cluster.controllers["EdgeCtl"].healthy = False
        cluster.workers["e0"].reachable = False
        cluster.workers["e1"].reachable = False
        script = parse_tapp(
            "- t:\n  - controller: EdgeCtl\n    workers:\n    - set:\n"
            "    topology_tolerance: all\n  followup: fail\n"
        )
        d = TappEngine(DistributionPolicy.SHARED, seed=1).schedule(
            Invocation("f", tag="t"), script, cluster
        )
        assert d.scheduled and d.worker == "c0"


class TestVanilla:
    def test_round_robin_controllers(self):
        cluster = two_zone_cluster()
        v = VanillaScheduler()
        seen = {v.schedule(Invocation("f"), cluster).controller for _ in range(4)}
        assert seen == {"EdgeCtl", "CloudCtl"}

    def test_home_worker_stable(self):
        cluster = two_zone_cluster()
        v = VanillaScheduler()
        homes = {v.schedule(Invocation("f"), cluster).worker for _ in range(6)}
        assert len(homes) == 1  # same function → same worker while not overloaded

    def test_overload_steps_to_next(self):
        cluster = two_zone_cluster()
        v = VanillaScheduler()
        home = v.schedule(Invocation("f"), cluster).worker
        cluster.workers[home].inflight = cluster.workers[home].capacity_slots
        second = v.schedule(Invocation("f"), cluster).worker
        assert second != home


# ---------------------------------------------------------------------------
# Satellites: cached Invocation.hash + per-epoch memoized cluster queries
# ---------------------------------------------------------------------------


class TestInvocationHash:
    def test_hash_matches_stable_hash(self):
        inv = Invocation("my_function")
        assert inv.hash == stable_hash("my_function")

    def test_hash_computed_once_at_construction(self):
        # The frozen dataclass stores the hash as a real field (set in
        # __post_init__), not a per-access property recomputing blake2b.
        inv = Invocation("fn")
        assert inv.__dict__["hash"] == stable_hash("fn")

    def test_hash_excluded_from_equality_and_repr(self):
        a, b = Invocation("fn", tag="t"), Invocation("fn", tag="t")
        assert a == b
        assert "hash" not in repr(a)

    def test_replace_recomputes(self):
        import dataclasses as _dc

        inv = _dc.replace(Invocation("fn"), function="other")
        assert inv.hash == stable_hash("other")


class TestClusterQueryMemoization:
    def _cluster(self):
        return make_cluster(
            workers=[
                dict(name="e0", zone="edge", sets=["edge", "any"]),
                dict(name="c0", zone="cloud", sets=["cloud", "any"]),
            ],
            controllers=[dict(name="C0", zone="edge")],
        )

    def test_queries_memoized_within_epoch(self):
        cluster = self._cluster()
        assert cluster.set_labels() == ["any", "cloud", "edge"]
        assert cluster.zones() == ["cloud", "edge"]
        assert [w.name for w in cluster.workers_in_set("any")] == ["e0", "c0"]
        # Cached tuples back the repeated calls (fresh lists returned).
        first = cluster.workers_in_set("any")
        second = cluster.workers_in_set("any")
        assert first == second and first is not second
        assert ("set", "any") in cluster._query_cache

    def test_epoch_bump_invalidates(self):
        cluster = self._cluster()
        cluster.set_labels(), cluster.zones(), cluster.workers_in_set("any")
        cluster.add_worker(WorkerState(name="g0", zone="gpuzone", sets=frozenset({"gpu"})))
        assert "gpu" in cluster.set_labels()
        assert "gpuzone" in cluster.zones()
        assert [w.name for w in cluster.workers_in_set("gpu")] == ["g0"]

    def test_structural_worker_update_invalidates_via_watcher(self):
        from repro.core.scheduler import Watcher

        watcher = Watcher(self._cluster())
        cluster = watcher.cluster
        assert cluster.set_labels() == ["any", "cloud", "edge"]
        watcher.update_worker("e0", sets=["edge", "any", "hot"])
        assert "hot" in cluster.set_labels()
