"""Brute-force oracle for the static policy analyzer.

Shared by the deterministic sweep in ``test_analysis.py`` and the
hypothesis property suite in ``test_analysis_property.py``: exhaustively
admits invocations through a real platform until saturation and checks
the analyzer's verdicts against what actually happened —

- ``placeable`` ⟺ at least one admission succeeded,
- ``starvation_bound`` == the number of admissions absorbed before the
  platform started rejecting (exact verdicts only; affinity-free scripts
  are always exact),
- every worker that received an admission is in the verdict's
  ``selectable`` set (the inevitability property behind ``explain()``).
"""
from __future__ import annotations

from typing import List, Tuple

from repro.core.platform import ClusterSpec, TappPlatform
from repro.core.scheduler.topology import DistributionPolicy


def saturate(platform: TappPlatform, tag: str, n_ctls: int,
             *, limit: int = 200) -> List[str]:
    """Admit invocations (never completing them) until the platform
    rejects ``n_ctls + 1`` in a row; returns the workers placed on."""
    placed: List[str] = []
    consecutive = 0
    while consecutive <= n_ctls and len(placed) < limit:
        placement = platform.invoke("fn", tag=tag)
        if placement.scheduled:
            placed.append(placement.worker)
            consecutive = 0
        else:
            consecutive += 1
    return placed


def check_agreement(
    spec: ClusterSpec,
    script: str,
    *,
    distribution: DistributionPolicy = DistributionPolicy.SHARED,
) -> Tuple[int, int]:
    """Assert analyzer verdicts == brute-force outcomes for every tag.

    Returns ``(tags checked, total admissions placed)`` for reporting.
    """
    analysis = TappPlatform(
        spec, distribution=distribution, seed=0
    ).verify_policy(script)
    n_ctls = len(spec.controllers)
    placed_total = 0
    for verdict in analysis.verdicts:
        fresh = TappPlatform(spec, distribution=distribution, seed=0)
        fresh.apply_policy(script)
        placed = saturate(fresh, verdict.tag, n_ctls)
        placed_total += len(placed)

        assert verdict.placeable == bool(placed), (
            f"tag {verdict.tag!r}: analyzer says placeable="
            f"{verdict.placeable} but brute force placed {len(placed)}\n"
            f"{analysis.verdict()}"
        )
        assert verdict.exact, (
            f"tag {verdict.tag!r}: affinity-free script must yield an "
            f"exact bound"
        )
        assert len(placed) == verdict.starvation_bound, (
            f"tag {verdict.tag!r}: analyzer bound "
            f"{verdict.starvation_bound}, brute force absorbed "
            f"{len(placed)} ({placed})\n{analysis.verdict()}"
        )
        extra = set(placed) - set(verdict.selectable)
        assert not extra, (
            f"tag {verdict.tag!r}: workers {sorted(extra)} received "
            f"admissions but are outside the selectable set "
            f"{sorted(verdict.selectable)}"
        )
    return len(analysis.verdicts), placed_total
