"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.moe_gmm import gmm
from repro.kernels.ops import flash_attention, moe_ffn_gmm, ssd_scan

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5
    )


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "b,s,t,h,kv,d,causal,bq,bk",
        [
            (2, 128, 128, 4, 2, 64, True, 64, 64),
            (1, 256, 256, 8, 8, 128, True, 128, 128),
            (2, 96, 96, 4, 1, 64, True, 64, 64),       # padding path (96 % 64)
            (1, 64, 256, 4, 4, 64, False, 64, 64),     # cross-attn style
            (1, 32, 32, 2, 2, 32, True, 32, 32),
        ],
    )
    def test_matches_reference(self, dtype, b, s, t, h, kv, d, causal, bq, bk):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, h, s, d)).astype(dtype)
        k = jax.random.normal(ks[1], (b, kv, t, d)).astype(dtype)
        v = jax.random.normal(ks[2], (b, kv, t, d)).astype(dtype)
        out = flash_attention_bhsd(
            q, k, v, causal=causal, bq=bq, bk=bk, interpret=True
        )
        expect = ref.ref_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32),
            **_tol(dtype),
        )

    def test_model_layout_wrapper(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (2, 64, 4, 32))
        k = jax.random.normal(ks[1], (2, 64, 2, 32))
        v = jax.random.normal(ks[2], (2, 64, 2, 32))
        out = flash_attention(q, k, v, causal=True, bq=32, bk=32)
        expect = ref.ref_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True,
        ).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-5, atol=2e-5)

    def test_softmax_rows_sum_to_one_effect(self):
        """Attention of constant V must return that constant (any mask)."""
        q = jax.random.normal(KEY, (1, 2, 64, 32))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 64, 32))
        v = jnp.ones((1, 2, 64, 32))
        out = flash_attention_bhsd(q, k, v, causal=True, bq=32, bk=32,
                                   interpret=True)
        np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5)


class TestGmm:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "e,c,k,n,bc,bn,bk",
        [
            (4, 64, 32, 48, 32, 32, 32),
            (2, 100, 64, 64, 32, 32, 32),   # padding path
            (8, 16, 128, 256, 16, 128, 64),
            (1, 8, 8, 8, 8, 8, 8),
        ],
    )
    def test_matches_reference(self, dtype, e, c, k, n, bc, bn, bk):
        ks = jax.random.split(KEY, 2)
        x = jax.random.normal(ks[0], (e, c, k)).astype(dtype)
        w = jax.random.normal(ks[1], (e, k, n)).astype(dtype)
        out = gmm(x, w, bc=bc, bn=bn, bk=bk, interpret=True)
        expect = ref.ref_gmm(x, w)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32),
            **_tol(dtype),
        )

    def test_moe_ffn_composition(self):
        from repro.configs import smoke_config
        from repro.models.layers.moe import init_moe

        cfg = smoke_config("phi3_5_moe_42b")
        params = init_moe(cfg, KEY)
        buffer = jax.random.normal(
            KEY, (cfg.moe_experts, 16, cfg.d_model), jnp.float32
        )
        out = moe_ffn_gmm(cfg, params, buffer)
        # reference: plain einsum path
        gate = ref.ref_gmm(buffer, params["w_gate"])
        up = ref.ref_gmm(buffer, params["w_up"])
        h = jax.nn.silu(gate) * up
        expect = ref.ref_gmm(h, params["w_down"])
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)


class TestSsdScan:
    @pytest.mark.parametrize(
        "b,s,h,p,g,n,chunk",
        [
            (2, 128, 4, 16, 1, 32, 32),
            (1, 64, 2, 8, 2, 16, 16),
            (1, 96, 4, 16, 1, 32, 32),      # padding path
            (2, 32, 8, 8, 1, 8, 8),
        ],
    )
    def test_matches_quadratic_reference(self, b, s, h, p, g, n, chunk):
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)))
        B_ = jax.random.normal(ks[3], (b, s, g, n))
        C_ = jax.random.normal(ks[4], (b, s, g, n))
        y, _ = ssd_scan(x, dt, a, B_, C_, chunk=chunk)
        xdt = (x * dt[..., None]).transpose(0, 2, 1, 3)
        da = (dt * a[None, None, :]).transpose(0, 2, 1)
        y_ref = ref.ref_ssd(
            xdt, da, B_.transpose(0, 2, 1, 3), C_.transpose(0, 2, 1, 3)
        ).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_matches_jnp_chunked_implementation(self):
        from repro.models.layers.ssm import ssd_chunked

        ks = jax.random.split(KEY, 5)
        b, s, h, p, g, n = 2, 64, 4, 8, 1, 16
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)))
        B_ = jax.random.normal(ks[3], (b, s, g, n))
        C_ = jax.random.normal(ks[4], (b, s, g, n))
        y_kernel, _ = ssd_scan(x, dt, a, B_, C_, chunk=16)
        y_jnp, _ = ssd_chunked(x, dt, a, B_, C_, 16)
        np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_jnp),
                                   rtol=1e-4, atol=1e-4)


class TestKernelsInsideModel:
    def test_use_kernels_config_path(self):
        """Route a full model forward through all three kernels."""
        import dataclasses

        from repro.configs import smoke_config
        from repro.models import Model

        for arch in ("phi3_5_moe_42b", "mamba2_2_7b", "qwen1_5_0_5b"):
            cfg = dataclasses.replace(
                smoke_config(arch), use_kernels=True, compute_dtype="float32",
                ssm_chunk=8,
            )
            ref_cfg = dataclasses.replace(cfg, use_kernels=False)
            model = Model(cfg)
            params = model.init_params(KEY)
            toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
            loss_k, _ = model.loss(params, {"tokens": toks})
            loss_r, _ = Model(ref_cfg).loss(params, {"tokens": toks})
            assert abs(float(loss_k) - float(loss_r)) < 2e-3, arch
