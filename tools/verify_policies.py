#!/usr/bin/env python
"""Statically verify every shipped tAPP policy against its deployment.

`make verify-policies` (and the CI job of the same name) runs the
:mod:`repro.core.analysis` verifier over every policy script the repo
ships — the examples/ demos and the simulation scenario families — each
against the cluster/federation spec its runner actually deploys. A case
fails on any error-level finding or analyzer *proof* (a tag no admission
sequence can place): shipped scripts must be free of false blockers, so
this doubles as the analyzer's zero-false-positive regression gate.

Run: PYTHONPATH=src:. python tools/verify_policies.py [-v]
"""
from __future__ import annotations

import argparse
import sys
from typing import Callable, List, Optional, Tuple

from repro.core.platform import (
    ClusterSpec,
    ControllerSpec,
    FederationSpec,
    TappFederation,
    TappPlatform,
    WorkerSpec,
)
from repro.core.scheduler.topology import DistributionPolicy
from repro.core.sim import scenarios
from repro.core.sim.core import NetworkModel

# (name, script text, platform factory). Factories build the deployment
# the script's runner/demo uses, so verdicts match what would go live.
Case = Tuple[str, str, Callable[[], object]]

# Federated brownout variant of the co-location policy (PR 9): the
# latency class may relax its anti-affinity under sustained saturation,
# the batch class may widen to any zone, and the join class refuses to
# degrade. Verified here against the same two-rack federation the
# chaos/overload sims deploy.
OVERLOAD_COLOCATION_SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- latency:
  - workers:
    - set:
    strategy: platform
    invalidate: capacity_used 90%
    anti-affinity: [batch_crunch]
    priority: 2
  followup: default
  on-overload: relax-affinity
- batch:
  - workers:
    - set:
    strategy: best_first
    invalidate: overload
    anti-affinity: [latency_api]
  followup: default
  on-overload: any-zone
- join:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
    affinity: [cache_warmer]
  followup: default
  on-overload: reject
"""


def _flat(spec: ClusterSpec, distribution: DistributionPolicy):
    return lambda: TappPlatform(spec, distribution=distribution)


def _federated(spec: FederationSpec, distribution: DistributionPolicy):
    return lambda: TappFederation(spec, distribution=distribution)


def _serve_topology_cluster() -> ClusterSpec:
    """The examples/serve_topology.py flat deployment, as a ClusterSpec.

    The demo registers these through ServingEngine replicas; the verifier
    only needs the topology shape (zones / sets / slots), mirrored here.
    """
    return ClusterSpec(
        controllers=(
            ControllerSpec("LocalCtl_1", zone="edge"),
            ControllerSpec("LocalCtl_2", zone="edge"),
            ControllerSpec("CloudCtl", zone="cloud"),
        ),
        workers=(
            WorkerSpec("W_1", zone="edge", sets=("edge", "internal"),
                       capacity_slots=2),
            WorkerSpec("W_2", zone="edge", sets=("edge", "internal"),
                       capacity_slots=2),
            WorkerSpec("W_3", zone="cloud", sets=("cloud",),
                       capacity_slots=2),
            WorkerSpec("W_4", zone="cloud", sets=("cloud",),
                       capacity_slots=2),
        ),
    )


def _serve_topology_federation() -> FederationSpec:
    """examples/serve_topology.py federation_demo(), replicas included."""
    return FederationSpec.of(
        {
            "edge": ClusterSpec(
                controllers=(ControllerSpec("EdgeCtl"),),
                workers=(WorkerSpec("E_1", sets=("edge",),
                                    capacity_slots=1),),
            ),
            "cloud": ClusterSpec(
                controllers=(ControllerSpec("CloudCtl"),),
                workers=(WorkerSpec("C_1", sets=("cloud",),
                                    capacity_slots=1),),
            ),
        },
        network=NetworkModel(rtt={("edge", "cloud"): 0.040}, bandwidth={}),
        default_entry="edge",
    )


def _example_scripts() -> List[Tuple[str, str]]:
    """(constant name, script) pairs lifted from the examples/ modules.

    The example modules import jax at module scope (they end in model-
    serving demos); where jax is unavailable the scripts are skipped
    with a notice rather than failing the gate.
    """
    out: List[Tuple[str, str]] = []
    try:
        from examples import quickstart, serve_topology
    except ImportError as exc:  # pragma: no cover - jax-less environments
        print(f"NOTE: skipping examples/ scripts ({exc})")
        return out
    out.append(("quickstart.SCRIPT", quickstart.SCRIPT))
    for name in ("CASE_STUDY_SCRIPT", "FLIPPED", "SPREAD_SCRIPT"):
        out.append((f"serve_topology.{name}", getattr(serve_topology, name)))
    out.append(("serve_topology.FEDERATION_SCRIPT",
                serve_topology.FEDERATION_SCRIPT))
    return out


def build_cases() -> List[Case]:
    cases: List[Case] = []
    examples = dict(_example_scripts())

    if "quickstart.SCRIPT" in examples:
        from examples.quickstart import SPEC as QUICKSTART_SPEC

        cases.append((
            "quickstart.SCRIPT",
            examples["quickstart.SCRIPT"],
            _flat(QUICKSTART_SPEC, DistributionPolicy.SHARED),
        ))
        serve_cluster = _serve_topology_cluster()
        for name in ("CASE_STUDY_SCRIPT", "FLIPPED", "SPREAD_SCRIPT"):
            cases.append((
                f"serve_topology.{name}",
                examples[f"serve_topology.{name}"],
                _flat(serve_cluster, DistributionPolicy.SHARED),
            ))
        cases.append((
            "serve_topology.FEDERATION_SCRIPT",
            examples["serve_topology.FEDERATION_SCRIPT"],
            _federated(_serve_topology_federation(),
                       DistributionPolicy.SHARED),
        ))

    # §5.2/§5.3 quantitative benchmark: the data-locality script runs
    # under every distribution policy the sweep exercises.
    for policy in DistributionPolicy:
        cases.append((
            f"scenarios.DATA_LOCALITY_SCRIPT[{policy.value}]",
            scenarios.DATA_LOCALITY_SCRIPT,
            _flat(scenarios.benchmark_cluster(), policy),
        ))

    # §5.1 qualitative MQTT case: flat (both registration orders) and
    # the two-entry federation.
    for cloud_first in (True, False):
        order = "cloud_first" if cloud_first else "edge_first"
        cases.append((
            f"scenarios.MQTT_SCRIPT[{order}]",
            scenarios.MQTT_SCRIPT,
            _flat(scenarios.mqtt_cluster(cloud_first=cloud_first),
                  DistributionPolicy.SHARED),
        ))
    cases.append((
        "scenarios.MQTT_SCRIPT[federated]",
        scenarios.MQTT_SCRIPT,
        _federated(scenarios.mqtt_federation_spec(),
                   DistributionPolicy.SHARED),
    ))

    # Co-location / interference family (constraint layer v2).
    for name in ("COLOCATION_BLANK_SCRIPT", "COLOCATION_SCRIPT"):
        script = getattr(scenarios, name)
        cases.append((
            f"scenarios.{name}",
            script,
            _flat(scenarios.colocation_cluster(), DistributionPolicy.SHARED),
        ))
        cases.append((
            f"scenarios.{name}[federated]",
            script,
            _federated(scenarios.colocation_federation_spec(),
                       DistributionPolicy.SHARED),
        ))

    # Overload family (PR 9): scripts with ``on-overload`` opt-ins
    # pre-compile a brownout-degraded plan at apply time; the verifier
    # must analyze BOTH plans (a brownout can never swap in a
    # proven-unplaceable policy), so these cases additionally require
    # the degraded analysis to exist and be blocker-free.
    cases.append((
        "scenarios.OVERLOAD_SCRIPT",
        scenarios.OVERLOAD_SCRIPT,
        _flat(scenarios.benchmark_cluster(), DistributionPolicy.SHARED),
    ))
    cases.append((
        "OVERLOAD_COLOCATION_SCRIPT[federated]",
        OVERLOAD_COLOCATION_SCRIPT,
        _federated(scenarios.colocation_federation_spec(),
                   DistributionPolicy.SHARED),
    ))

    # Warm-pool family (PR 10): both arms of the cold-start benchmark,
    # verified against the deployment the bench actually drives. The
    # warm-first script additionally regression-guards the validator's
    # placement rules for the strategy (set-level is legal; tag-level
    # would be an error-level finding and fail this gate).
    from benchmarks.coldstart_bench import (
        OBLIVIOUS_SCRIPT,
        WARM_FIRST_COLDSTART_SCRIPT,
    )

    cases.append((
        "coldstart_bench.WARM_FIRST_COLDSTART_SCRIPT",
        WARM_FIRST_COLDSTART_SCRIPT,
        _flat(scenarios.benchmark_cluster(), DistributionPolicy.SHARED),
    ))
    cases.append((
        "coldstart_bench.OBLIVIOUS_SCRIPT",
        OBLIVIOUS_SCRIPT,
        _flat(scenarios.benchmark_cluster(), DistributionPolicy.SHARED),
    ))
    return cases


def verify_case(name: str, script: str, factory, *,
                verbose: bool) -> Optional[str]:
    """Returns None on pass, else a failure description."""
    platform = factory()
    dry = platform.dry_run_policy(script)
    report = dry.analysis
    if report is None:
        return "script did not lower to a compiled plan (no analysis)"
    if "on-overload" in script and dry.degraded_analysis is None:
        # The script opts into brownout degradation, so apply_policy
        # would pre-compile a degraded plan — it must be analyzed too.
        return ("script declares on-overload but the degraded plan was "
                "not analyzed")
    blockers = tuple(dry.errors) + tuple(dry.proofs)
    if verbose:
        print(f"--- {name} ---")
        print(report.verdict())
        if dry.degraded_analysis is not None:
            print("--- degraded (brownout) plan ---")
            print(dry.degraded_analysis.verdict())
    if blockers:
        return "; ".join(str(f) for f in blockers)
    return None


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("-v", "--verbose", action="store_true",
                        help="print each case's full analyzer verdict")
    opts = parser.parse_args(argv)

    cases = build_cases()
    failures: List[Tuple[str, str]] = []
    for name, script, factory in cases:
        problem = verify_case(name, script, factory, verbose=opts.verbose)
        if problem is None:
            print(f"PASS {name}")
        else:
            print(f"FAIL {name}: {problem}")
            failures.append((name, problem))

    print(f"\n{len(cases) - len(failures)}/{len(cases)} policies verified")
    if failures:
        print("error-level findings / unplaceability proofs in shipped "
              "policies:")
        for name, problem in failures:
            print(f"  {name}: {problem}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
