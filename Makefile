PY := python
export PYTHONPATH := src:.

.PHONY: test lint chaos bench bench-sched bench-sched-full bench-check bench-serve

test:
	$(PY) -m pytest -q

# Seeded fault-injection property suite (PR 6): chaos schedules over the
# failure-detection + retry layer, checking ledger conservation, DEAD-
# worker exclusion, partition containment, and chaos-off bit-compat.
# Failing seeds land in chaos_failures/ (uploaded as a CI artifact).
# --timeout guards against a hung fault schedule, but only when the
# pytest-timeout plugin is installed (requirements-dev.txt; optional).
chaos:
	$(PY) -m pytest tests/test_chaos.py tests/test_failure_detection.py -q \
		$$($(PY) -c "import pytest_timeout" 2>/dev/null && echo --timeout=120)

# Correctness lint (ruff.toml: syntax errors, bad comparisons, undefined
# names). `pip install ruff` (requirements-dev.txt) to run locally.
lint:
	ruff check src benchmarks examples tests

bench:
	$(PY) benchmarks/run.py --quick

# CI gate: scheduler microbench in smoke mode; fails on any regression
# gate (compiled vs interpreted, flat scaling 4w→1024w, saturated-cluster
# cost, constraint-cost, façade overhead budget).
bench-sched:
	$(PY) benchmarks/run.py sched --smoke --check

# bench-sched + comparison against the committed artifact's ratio floors
# (>1.5x regression on speedup / scaling / saturation / façade ratios
# fails; absolute µs are never compared across machines). Writes the
# smoke rows to bench_scheduler_smoke.json for the CI artifact upload.
bench-check:
	$(PY) benchmarks/run.py sched --smoke --check \
		--compare BENCH_scheduler.json --out bench_scheduler_smoke.json

# Full sweep (4..1024 workers); regenerates the committed artifact.
bench-sched-full:
	$(PY) benchmarks/run.py sched --check --out BENCH_scheduler.json

# Serving-engine benchmark (tAPP-scheduled continuous batching on small
# CPU replicas); regenerates the committed artifact.
bench-serve:
	$(PY) benchmarks/run.py serve --out BENCH_serving.json
