PY := python
export PYTHONPATH := src:.

.PHONY: test bench bench-sched bench-sched-full bench-serve

test:
	$(PY) -m pytest -q

bench:
	$(PY) benchmarks/run.py --quick

# CI gate: scheduler microbench in smoke mode; fails if the compiled
# fast path is slower than the reference interpreter on any row.
bench-sched:
	$(PY) benchmarks/run.py sched --smoke --check

# Full sweep (4..1024 workers); regenerates the committed artifact.
bench-sched-full:
	$(PY) benchmarks/run.py sched --check --out BENCH_scheduler.json

# Serving-engine benchmark (tAPP-scheduled continuous batching on small
# CPU replicas); regenerates the committed artifact.
bench-serve:
	$(PY) benchmarks/run.py serve --out BENCH_serving.json
