PY := python
export PYTHONPATH := src:.

.PHONY: test lint verify-policies chaos chaos-overload bench bench-sched bench-sched-full bench-check bench-serve bench-throughput bench-throughput-smoke bench-overload bench-overload-smoke bench-coldstart bench-coldstart-smoke

test:
	$(PY) -m pytest -q

# Seeded fault-injection property suite (PR 6): chaos schedules over the
# failure-detection + retry layer, checking ledger conservation, DEAD-
# worker exclusion, partition containment, and chaos-off bit-compat.
# Failing seeds land in chaos_failures/ (uploaded as a CI artifact).
# --timeout guards against a hung fault schedule, but only when the
# pytest-timeout plugin is installed (requirements-dev.txt; optional).
chaos:
	$(PY) -m pytest tests/test_chaos.py tests/test_failure_detection.py -q \
		$$($(PY) -c "import pytest_timeout" 2>/dev/null && echo --timeout=120)

# Overload-burst chaos suite (PR 9): admission queues, priority
# shedding, circuit breakers, and brownout degradation under seeded
# overload_burst fault schedules (plus the armed-idle bit-identity
# properties).
chaos-overload:
	$(PY) -m pytest tests/test_overload.py \
		tests/test_chaos.py -k "Overload or Breaker or Burst" -q \
		$$($(PY) -c "import pytest_timeout" 2>/dev/null && echo --timeout=120)

# Correctness lint (ruff.toml: syntax errors, bad comparisons, undefined
# names). `pip install ruff` (requirements-dev.txt) to run locally.
# Also fails if any Python bytecode is tracked (bytecode is
# machine-specific noise in diffs; .gitignore keeps it out, this keeps
# it honest).
lint:
	@tracked=$$(git ls-files | grep -E '(^|/)__pycache__/|\.py[co]$$' || true); \
	if [ -n "$$tracked" ]; then \
		echo "FAIL: tracked Python bytecode:"; echo "$$tracked"; exit 1; \
	fi
	ruff check src benchmarks examples tests tools

# Static policy verification (PR 8): run the reachability /
# satisfiability / starvation analyzer over every shipped tAPP script
# (examples/ + sim scenario families) against its real deployment.
# Fails on any error-level finding or unplaceability proof.
verify-policies:
	$(PY) tools/verify_policies.py

bench:
	$(PY) benchmarks/run.py --quick

# CI gate: scheduler microbench in smoke mode; fails on any regression
# gate (compiled vs interpreted, flat scaling 4w→1024w, saturated-cluster
# cost, constraint-cost, façade overhead budget).
bench-sched:
	$(PY) benchmarks/run.py sched --smoke --check

# bench-sched + comparison against the committed artifact's ratio floors
# (>1.5x regression on speedup / scaling / saturation / façade ratios
# fails; absolute µs are never compared across machines). Writes the
# smoke rows to bench_scheduler_smoke.json for the CI artifact upload.
bench-check:
	$(PY) benchmarks/run.py sched --smoke --check \
		--compare BENCH_scheduler.json --out bench_scheduler_smoke.json

# Full sweep (4..1024 workers); regenerates the committed artifact.
bench-sched-full:
	$(PY) benchmarks/run.py sched --check --out BENCH_scheduler.json

# Serving-engine benchmark (tAPP-scheduled continuous batching on small
# CPU replicas); regenerates the committed artifact.
bench-serve:
	$(PY) benchmarks/run.py serve --out BENCH_serving.json

# Multi-entry federated throughput (PR 7): sustained invoke→complete
# ops/s with one driver thread per entry zone at a fixed total worker
# count; gated at 2-zone >= 1.5x the 1-zone rate (what the zone-sharded
# ledgers buy under the GIL). Full reps; merges the rows into the
# committed artifact. CI runs the reduced-rep smoke variant below.
bench-throughput:
	$(PY) benchmarks/run.py sched --throughput --check \
		--merge BENCH_scheduler.json

bench-throughput-smoke:
	$(PY) benchmarks/run.py sched --throughput --smoke \
		--out bench_throughput_smoke.json

# Overload-resilience benchmark (PR 9): goodput under a saturating
# open-loop burst, admission-queue arm vs oblivious arm at equal
# offered load; gated at queued goodput >= 2x oblivious. Full size
# merges the rows into the committed serving artifact.
bench-overload:
	$(PY) benchmarks/run.py overload --check --merge BENCH_serving.json

bench-overload-smoke:
	$(PY) benchmarks/run.py overload --smoke --check \
		--out bench_overload_smoke.json

# Cold-start benchmark (PR 10): warm-first routing over an armed
# warm-pool lifecycle vs a warmth-oblivious scatter policy at equal
# open-loop load; gated at oblivious cold-start rate >= 2x the
# warm-aware arm's. Full size merges the rows into the committed
# serving artifact.
bench-coldstart:
	$(PY) benchmarks/run.py coldstart --check --merge BENCH_serving.json

bench-coldstart-smoke:
	$(PY) benchmarks/run.py coldstart --smoke --check \
		--out bench_coldstart_smoke.json
