"""Overload-resilience benchmark: goodput under a saturating burst (PR 9).

An *open-loop* workload (every arrival time fixed up front — one request
per user, staggered across the horizon, so completions never gate
offered load) is amplified by a seeded ``overload_burst`` chaos schedule
far past cluster capacity. Three arms at EQUAL offered load:

- ``oblivious``   — no overload layer: burst-window requests that find a
  saturated cluster exhaust their retries and fail.
- ``queued``      — a deep deadline-aware admission queue parks the
  overflow and drains it on completions after the burst passes.
- ``bounded``     — a small queue with a tight deadline: the shedding /
  deadline-expiry path, reporting a non-zero shed rate.

The gate (``--check``) pins the queued arm's goodput to at least
``GOODPUT_FACTOR``× the oblivious arm's — the acceptance bar for the
admission-queue layer. Entirely simulator-driven (engine ticks, seeded
faults): deterministic, no accelerator, no wall-clock sensitivity in the
gated ratio.

Run ``python benchmarks/run.py overload [--smoke] [--check]`` or
``make bench-overload``; ``--merge BENCH_serving.json`` folds the rows
into the committed serving artifact.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from typing import Dict, List, Optional

from repro.core.platform import (
    OverloadSpec,
    QueueSpec,
    RetryPolicy,
    TappPlatform,
)
from repro.core.platform.faults import ChaosSpec
from repro.core.scheduler.topology import DistributionPolicy
from repro.core.sim.core import Simulation, SimConfig, WorkloadSpec
from repro.core.sim.scenarios import (
    OVERLOAD_SCRIPT,
    ZONE_EAST,
    adhoc_profiles,
    benchmark_cluster,
    benchmark_network,
)

# Queued-arm goodput must be at least this multiple of the oblivious
# arm's at equal offered load (the PR 9 acceptance bar). The committed
# full-size run measures ~2.3x; 2.0 leaves headroom for config drift
# without letting the queue decay into a no-op.
GOODPUT_FACTOR = 2.0

SEED = 2


def _burst_chaos(*, smoke: bool) -> ChaosSpec:
    if smoke:
        return ChaosSpec(
            seed=SEED, horizon=30.0, overload_bursts=1,
            burst_duration=10.0, burst_factor=12.0,
        )
    return ChaosSpec(
        seed=SEED, horizon=60.0, overload_bursts=2,
        burst_duration=8.0, burst_factor=10.0,
    )


def _run_arm(
    overload: Optional[OverloadSpec], *, smoke: bool
):
    chaos = _burst_chaos(smoke=smoke)
    platform = TappPlatform(
        benchmark_cluster(deployment_seed=SEED),
        distribution=DistributionPolicy.SHARED,
        seed=SEED,
        policy=OVERLOAD_SCRIPT,
        retry=RetryPolicy(max_attempts=3),
        overload=overload,
    )
    sim = Simulation(
        platform, benchmark_network(), adhoc_profiles(False),
        SimConfig(seed=SEED, gateway_zone=ZONE_EAST),
        is_tapp=True, chaos=chaos,
    )
    users = 400 if smoke else 1200
    # requests_per_user=1: the whole arrival schedule is computed from
    # the ramp-up stagger before the event loop starts, so every arm
    # sees the identical offered load no matter how it fares.
    result = sim.run([
        WorkloadSpec(
            function="hellojs", users=users, requests_per_user=1,
            ramp_up=chaos.horizon,
        )
    ])
    return result


def _row(name: str, result, baseline_goodput: Optional[float]) -> Dict:
    offered = len(result.records)
    ok = sum(1 for r in result.records if r.ok)
    goodput = ok / max(1, offered)
    waits = result.queue_waits()
    lat = [r.latency for r in result.records if r.ok]
    derived = (
        f"offered={offered};ok={ok};goodput={goodput:.3f};"
        f"shed_rate={result.n_shed / max(1, offered):.3f};"
        f"queued={result.n_queued};"
        f"queue_wait_mean={statistics.fmean(waits) if waits else 0.0:.2f}s"
    )
    row = {
        "name": name,
        # Mean ok-request latency in simulated µs (queue wait included):
        # the price the queued arm pays for its goodput.
        "us_per_call": (statistics.fmean(lat) if lat else 0.0) * 1e6,
        "goodput": goodput,
        "derived": derived,
    }
    if baseline_goodput is not None:
        ratio = goodput / max(1e-9, baseline_goodput)
        row["goodput_ratio"] = ratio
        row["derived"] += f";goodput_ratio={ratio:.2f}x"
    return row


def overload_bench(*, smoke: bool = False) -> List[Dict]:
    oblivious = _run_arm(None, smoke=smoke)
    deep = _run_arm(
        OverloadSpec(queue=QueueSpec(depth=8192, deadline=120.0)),
        smoke=smoke,
    )
    bounded = _run_arm(
        OverloadSpec(
            queue=QueueSpec(depth=64, deadline=6.0, discipline="edf")
        ),
        smoke=smoke,
    )
    base_row = _row("overload_burst_oblivious", oblivious, None)
    rows = [
        base_row,
        _row("overload_burst_queued", deep, base_row["goodput"]),
        _row("overload_burst_bounded", bounded, base_row["goodput"]),
    ]
    # Equal-offered-load sanity: the open-loop schedule plus the seeded
    # burst expansion must offer every arm the same load, or the
    # goodput ratio is comparing different experiments.
    offered = {int(r["derived"].split(";")[0].split("=")[1]) for r in rows}
    if len(offered) != 1:
        raise RuntimeError(f"offered load diverged across arms: {offered}")
    return rows


def check_rows(rows: List[Dict]) -> List[str]:
    failures: List[str] = []
    by_name = {r["name"]: r for r in rows}
    queued = by_name.get("overload_burst_queued")
    if queued is None:
        failures.append("overload_burst_queued row missing")
        return failures
    ratio = queued.get("goodput_ratio")
    if ratio is None or ratio < GOODPUT_FACTOR:
        failures.append(
            f"overload_burst_queued: goodput ratio "
            f"{ratio if ratio is not None else float('nan'):.2f}x vs "
            f"oblivious < {GOODPUT_FACTOR:.1f}x — the admission queue is "
            f"not recovering the burst overflow"
        )
    bounded = by_name.get("overload_burst_bounded")
    if bounded is not None and "shed_rate=0.000" in bounded["derived"]:
        failures.append(
            "overload_burst_bounded: shed rate is zero — the bounded "
            "queue is not exercising the shedding path"
        )
    return failures


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="small horizon / fewer users (CI gate)")
    parser.add_argument("--check", action="store_true",
                        help="exit non-zero if the queued arm's goodput "
                             "is below the gate vs the oblivious arm")
    parser.add_argument("--out", default=None,
                        help="write a standalone JSON artifact here")
    parser.add_argument("--merge", default=None, metavar="BENCH_JSON",
                        help="merge rows into an existing artifact "
                             "(e.g. BENCH_serving.json), replacing "
                             "same-name rows")
    args = parser.parse_args(argv)

    rows = overload_bench(smoke=args.smoke)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f}us,{r['derived']}")
    if args.merge:
        with open(args.merge) as fh:
            payload = json.load(fh)
        merged = {row["name"]: row for row in payload.get("rows", [])}
        for row in rows:
            merged[row["name"]] = row
        payload["rows"] = list(merged.values())
        with open(args.merge, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
        print(f"# merged {len(rows)} rows into {args.merge}")
    if args.out:
        payload = {
            "benchmark": "overload_bench",
            "unit": "us_mean_ok_latency",
            "rows": rows,
        }
        with open(args.out, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
        print(f"# wrote {args.out}")
    if args.check:
        failures = check_rows(rows)
        if failures:
            for f in failures:
                print(f"FAIL: {f}", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
