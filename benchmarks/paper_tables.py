"""Paper-table benchmarks (§5 of the paper), driven by the DES.

One function per table/figure:
  * :func:`overhead_table`      — Fig. 9 (overhead tests, no data locality)
  * :func:`data_locality_table` — Fig. 10 (mongoDB + data-locality)
  * :func:`qualitative_mqtt`    — §5.1 case study (vanilla vs tAPP)

Each returns a list of row dicts and is averaged over N deployments
(the paper's redeploy-every-2-repetitions methodology, seeded).
"""
from __future__ import annotations

import statistics
from typing import Dict, List

from repro.core.sim.scenarios import run_benchmark, run_mqtt_case

OVERHEAD_TESTS = ["hellojs", "sleep", "matrixMult", "cold-start",
                  "slackpost", "pycatj"]
LOCALITY_TESTS = ["mongoDB", "data-locality"]
SCHEDULERS = ["vanilla", "default", "min_memory", "isolated", "shared"]


def _row(test: str, label: str, *, scheduler: str, tagged: bool,
         n_deployments: int) -> Dict:
    means, stds, fails = [], [], []
    for seed in range(n_deployments):
        _, res = run_benchmark(test, scheduler=scheduler, tagged=tagged,
                               seed=seed)
        s = res.summary()
        means.append(s["mean"])
        stds.append(s["std"])
        fails.append(s["failure_rate"])
    return {
        "test": test,
        "scheduler": label,
        "mean_s": statistics.fmean(means),
        "std_s": statistics.fmean(stds),
        "deployment_spread_s": statistics.pstdev(means) if len(means) > 1 else 0.0,
        "failure_rate": statistics.fmean(fails),
    }


def overhead_table(n_deployments: int = 6) -> List[Dict]:
    rows = []
    for test in OVERHEAD_TESTS:
        for sched in SCHEDULERS:
            rows.append(_row(test, sched, scheduler=sched, tagged=False,
                             n_deployments=n_deployments))
    return rows


def data_locality_table(n_deployments: int = 6) -> List[Dict]:
    rows = []
    for test in LOCALITY_TESTS:
        for sched in SCHEDULERS:
            rows.append(_row(test, sched, scheduler=sched, tagged=False,
                             n_deployments=n_deployments))
        rows.append(_row(test, "shared+tapp", scheduler="shared", tagged=True,
                         n_deployments=n_deployments))
    return rows


def qualitative_mqtt() -> List[Dict]:
    rows = []
    for use_tapp in (False, True):
        for cloud_first in (True, False):
            results = run_mqtt_case(use_tapp=use_tapp, minutes=20,
                                    cloud_first=cloud_first)
            for fn, res in results.items():
                rows.append({
                    "system": "tapp" if use_tapp else "vanilla",
                    "deployment": "cloud-primary" if cloud_first else "edge-primary",
                    "function": fn,
                    "failure_rate": res.failure_rate,
                    "mean_s": res.summary()["mean"],
                })
    return rows
