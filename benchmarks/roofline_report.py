"""Aggregate dry-run artifacts into the §Roofline table.

Reads ``artifacts/dryrun/*.json`` (written by ``repro.launch.dryrun``) and
emits a markdown table + CSV rows with the three roofline terms, dominant
bottleneck, FLOPs ratio, and the per-cell one-line recommendation.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List

ARTIFACTS = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

_RECOMMEND = {
    "compute": "raise per-chip math utilisation (larger per-chip tiles, "
               "fewer remat recomputes, bf16 everywhere)",
    "memory": "cut HBM traffic (deeper fusion, bf16/int8 caches, larger "
              "arithmetic intensity per block)",
    "collective": "cut wire bytes (reduce-scatter grads in bf16, EP "
                  "all-to-all instead of expert all-gather, overlap with compute)",
}


def load_records(mesh: str = "single", tag: str = "") -> List[Dict]:
    records = []
    suffix = f"__{tag}.json" if tag else ".json"
    for path in sorted(ARTIFACTS.glob(f"*__{mesh}{suffix}")):
        name = path.name
        if not tag and name.count("__") != 2:
            continue  # skip tagged variants in the baseline table
        records.append(json.loads(path.read_text()))
    return records


def recommendation(rec: Dict) -> str:
    dom = rec["roofline"]["dominant"]
    return _RECOMMEND[dom]


def table_rows(mesh: str = "single", tag: str = "") -> List[Dict]:
    rows = []
    for rec in load_records(mesh, tag):
        if rec["status"] == "skipped":
            rows.append({
                "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
                "status": "skipped", "reason": rec["reason"],
            })
            continue
        if rec["status"] != "ok":
            rows.append({
                "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
                "status": "error", "reason": rec.get("error", "?")[:80],
            })
            continue
        t = rec["roofline"]
        rows.append({
            "arch": rec["arch"],
            "shape": rec["shape"],
            "mesh": rec["mesh"],
            "status": "ok",
            "compute_s": t["compute_s"],
            "memory_s": t["memory_s"],
            "collective_s": t["collective_s"],
            "dominant": t["dominant"],
            "model_flops": t["model_flops_total"],
            "flops_ratio": t["flops_ratio"],
            "roofline_fraction": t["roofline_fraction"],
            "mem_gib": rec["memory"]["per_device_gib_modeled"],
            "fits": rec["memory"]["fits_hbm"],
            "recommendation": _RECOMMEND[t["dominant"]],
        })
    return rows


def markdown_table(mesh: str = "single", tag: str = "") -> str:
    rows = table_rows(mesh, tag)
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | "
        "dominant | MODEL/HLO flops | roofline frac | mem GiB (fits) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | — |"
            )
            continue
        if r["status"] == "error":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | — | — | — |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['dominant']} | "
            f"{r['flops_ratio']:.2f} | {r['roofline_fraction']:.3f} | "
            f"{r['mem_gib']:.2f} ({'Y' if r['fits'] else 'N'}) |"
        )
    return "\n".join(lines)


def csv_rows(mesh: str = "single") -> List[Dict]:
    out = []
    for r in table_rows(mesh):
        if r["status"] != "ok":
            continue
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        out.append({
            "name": f"roofline_{r['arch']}_{r['shape']}_{mesh}",
            "us_per_call": bound * 1e6,
            "derived": f"dom={r['dominant']};frac={r['roofline_fraction']:.3f}",
        })
    return out
