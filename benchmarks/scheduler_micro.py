"""Scheduler microbenchmark: µs per scheduling decision.

The paper's overhead argument (§5.4.1) rests on the policy interpreter
being cheap relative to function execution; this measures it directly:
tAPP policy evaluation (interpreted reference vs compiled fast path vs
batched fast path) against vanilla co-prime, across cluster sizes from
4 to 1024 workers.

Rows carry ``us_interpreted`` (the seed interpreter: fresh distribution
views + eager trace formatting per call), ``us_compiled`` (pre-lowered
script plan, epoch-cached views + candidate indexes, tracing elided),
``us_batch`` (``schedule_batch`` amortizing plan/tag dispatch over 64
invocations), and ``speedup`` = interpreted/compiled.

Index-layer rows: ``tapp_default_{n}w_saturated`` measures decisions
against a fully saturated cluster (every worker at capacity — the
empty-availability-mask O(1) case), and ``tapp_default_{n}w_churn``
measures the full decide→admit→complete cycle through the watcher
ledger (the O(1) incremental index maintenance).

Gates (``--check``): compiled beats interpreted everywhere;
constraint-heavy ≤ ``CONSTRAINED_FACTOR``× plain; flat scaling —
compiled per-decision at 1024w ≤ ``FLAT_FACTOR``× the 4w row for the
tagged/default/constrained scripts; saturated ≤ ``SATURATED_FACTOR``×
the unsaturated row; batched ≥ ``BATCH_SPEEDUP_FLOOR``× the per-call
compiled path at 1024w; churn cycle ≤ ``CHURN_FACTOR``× its paired
steady-state window (× ``CHURN_NOISE`` headroom on fresh runs — both
sides are ~5µs quantities on drifting hosts); platform façade ≤
``PLATFORM_FACTOR``× raw routing; zone-local federation invoke ≤
``FEDERATION_FACTOR``× the flat-platform invoke; lifecycle-armed
warm-first invoke ≤ ``WARM_FIRST_FACTOR``× the plain tagged invoke;
apply-time policy
analysis of the constraint-heavy plan ≤ ``ANALYZER_BUDGET_US``
(host-scaled) at 1024 workers. ``--throughput``
runs the multi-entry federated throughput rows instead (one driver
thread per entry zone, fixed total workers), gated at 2-zone ≥
``THROUGHPUT_SCALING_FLOOR``× the 1-zone rate. ``--compare
BENCH.json`` additionally enforces the committed artifact's *ratio
floors* (speedup, batch speedup, scaling, saturation, churn, façade —
scale-free quantities, so the check is portable across machines;
absolute µs are never compared).

Run ``python benchmarks/run.py sched --out BENCH_scheduler.json`` to
regenerate the committed artifact, ``make bench-sched`` for the smoke
gate, ``make bench-check`` for the smoke gate + committed-floor
comparison, or ``make bench-throughput`` to refresh the throughput
rows (``--merge`` folds them into the existing artifact).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List, Optional

from repro.core.scheduler.watcher import Watcher

from repro.core.platform import (
    BrownoutSpec,
    ClusterSpec,
    ControllerSpec,
    FederationSpec,
    LifecycleSpec,
    OverloadSpec,
    QueueSpec,
    RetryPolicy,
    TappFederation,
    TappPlatform,
    WorkerSpec,
)
from repro.core.scheduler import (
    ClusterState,
    ControllerState,
    Invocation,
    TappEngine,
    VanillaScheduler,
    WorkerState,
)
from repro.core.analysis import analyze_plan
from repro.core.scheduler.topology import DistributionPolicy
from repro.core.tapp import compile_script, parse_tapp

SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- tagged:
  - workers:
    - set: east
    strategy: random
    invalidate: capacity_used 80%
  - workers:
    - set: west
  followup: default
"""

# Warm-first variant of the tagged script (PR 10): identical topology,
# but the east set ranks warm-instance holders first (set-level inner
# strategy — members never inherit the block strategy) instead of the
# platform co-prime order. Used by the warm-pool fast-path gate row.
WARM_FIRST_SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- tagged:
  - workers:
    - set: east
      strategy: warm-first
    invalidate: capacity_used 80%
  - workers:
    - set: west
  followup: default
"""

# Constraint-heavy variant: every worker item stacks invalidate + affinity
# and/or anti-affinity clauses, so each candidate check runs the full
# constraint-layer conjunction against the running-function multiset. The
# gate requires this to stay within CONSTRAINED_FACTOR of the plain tagged
# script — per-decision cost must not grow with constraint count.
CONSTRAINED_SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- constrained:
  - workers:
    - set: east
      affinity: [svc_cache]
    strategy: random
    invalidate: capacity_used 80%
    anti-affinity: [noisy_batch]
  - workers:
    - set: west
      anti-affinity: [noisy_batch, noisy_etl]
    invalidate: max_concurrent_invocations 12
  - workers:
    - set:
  followup: default
"""

SIZES = (4, 16, 64, 256, 1024)
# Smoke keeps the 1024w point so the flat-scaling and saturation gates
# are enforced in CI, not only on full regenerations.
SMOKE_SIZES = (4, 64, 1024)
BATCH = 64
CONSTRAINED_FACTOR = 2.0  # constrained compiled vs plain compiled, same size
FLAT_FACTOR = 2.0         # compiled us/decision at 1024w vs 4w, same script
SATURATED_FACTOR = 1.5    # saturated-cluster decision vs unsaturated
COMPARE_FACTOR = 1.5      # regression headroom vs committed ratio floors
# The façade gate is an *absolute* budget since PR 4: invoke = route +
# admission recording + the Placement handle, and the admission side is a
# fixed ~2-3µs — with indexed routing at ~4-6µs even at 1024 workers, a
# ratio gate would fail precisely because routing got faster. The budget
# pins the façade's fixed cost; the committed facade_overhead ratio is
# still recorded and floor-checked by --compare. Absolute µs are
# host-dependent, so the gate scales the budget by the measured
# machine-speed factor (see _machine_speed_factor) — the same fixed work
# costs proportionally more µs on a slower CI host, and an unscaled
# budget would gate on host speed rather than on regressions.
PLATFORM_OVERHEAD_US = 6.0  # TappPlatform.invoke minus raw Gateway.route
# What the calibration micro-workload measures on the reference host
# (the class of machine that produced the committed artifact's ~4.3µs
# facade_overhead_us). Hosts measuring slower scale the absolute façade
# budget up proportionally; faster hosts keep the reference budget.
CALIBRATION_BASELINE_US = 7.0
PLATFORM_SIZE = 1024      # representative production point for the gate
FLAT_BASE, FLAT_TOP = 4, 1024  # the flat-scaling gate's endpoints
# Zone-local federation invoke vs flat-platform invoke at the same scale:
# the federation adds entry-zone resolution, the per-zone gateway hop, and
# the FederatedPlacement handle — all fixed-cost. The gate pins the whole
# zone-local path (no forwarding) to a small multiple of the flat façade.
FEDERATION_FACTOR = 1.25
# Fault-free fast path with a RetryPolicy armed vs without (PR 6): the
# retry machinery on a successful invoke is one policy-resolution dict
# lookup that never fires, so arming it must be ~free. The gate pins the
# retry-enabled invoke to RETRY_FACTOR x the plain invoke (paired
# alternating-rep floors, same rationale as the federation gate).
RETRY_FACTOR = 1.1
# Enabled-but-idle overload layer (PR 9): an OverloadSpec (admission
# queue + brownout) armed on a healthy, unsaturated cluster must leave
# the invoke fast path untaxed — the queue map stays empty (complete()'s
# drain check is one falsy dict read) and the enqueue branch is only
# reached after routing already failed. Same paired-floor gate shape as
# the retry row.
OVERLOAD_FACTOR = 1.1
# Warm-pool lifecycle armed under a warm-first policy (PR 10): the armed
# invoke adds the clockless-janitor guard, the per-function warm-mask
# read (incrementally maintained alongside the availability index — a
# dict hit plus journal replay of 0↔1 flips), the stable warm/cold bit
# partition, and the pool's spawn-or-reuse admission hook. All of it is
# O(1) per decision by construction; the gate pins the armed warm-first
# invoke to WARM_FIRST_FACTOR x the plain tagged invoke at the
# production point so warm ranking can never reintroduce an O(workers)
# or O(pool) scan on the hot path.
WARM_FIRST_FACTOR = 1.1
# The vectorized batch path (PR 7): ``schedule_batch`` must amortize a
# homogeneous 64-invocation batch to at least this much faster than
# per-call compiled routing at the FLAT_TOP production point. The same
# ratio is floor-checked (capped) against the committed artifact by
# --compare.
BATCH_SPEEDUP_FLOOR = 5.0
BATCH_SPEEDUP_CAP = 10.0  # committed-floor cap (the speedup-cap rationale)
# Decide→admit→complete cycle vs a pure steady-state decision (PR 7):
# the watcher-ledger churn (two load events consumed incrementally by
# the next refresh) must stay within this factor of the decision alone.
# compare_rows anchors to the committed rows (which sit right at ≈2×)
# with CHURN_FACTOR as an absolute floor on what can fail; every run —
# committed regeneration included — gets CHURN_NOISE headroom in
# check_rows, because both sides of the paired ratio are ~5µs
# quantities and single-core hosts drift by ~10-15% between rep
# windows.
CHURN_FACTOR = 2.0
CHURN_NOISE = 1.2
# Multi-entry federated throughput (PR 7): the same total worker count
# split across 2 zones (two concurrent entrypoint threads, each flapping
# a structural field every THROUGHPUT_FLAP_EVERY ops) must sustain at
# least this multiple of the 1-zone configuration's invocations/sec —
# the zone-sharded state gate: epoch invalidations and view rebuilds
# stay zone-local, so per-invoke work shrinks with zone count.
THROUGHPUT_SCALING_FLOOR = 1.5
THROUGHPUT_WORKERS = 512
THROUGHPUT_FLAP_EVERY = 16
# The apply-time policy verifier (PR 8): a full reachability /
# satisfiability / starvation analysis of a freshly-compiled
# constraint-heavy plan against the PLATFORM_SIZE-worker snapshot must
# fit in the apply_policy budget — the analyzer runs synchronously
# between compile and the atomic swap, so this is latency the control
# plane pays on every policy rollout. Absolute µs, host-scaled by the
# same machine-speed factor as the façade gate.
ANALYZER_BUDGET_US = 25_000.0


def _cluster(n_workers: int, *, saturated: bool = False) -> ClusterState:
    c = ClusterState()
    c.add_controller(ControllerState(name="C1", zone="east"))
    c.add_controller(ControllerState(name="C2", zone="west"))
    for i in range(n_workers):
        zone = "east" if i % 2 == 0 else "west"
        # Mixed running-function multisets so the affinity predicates do
        # real accept/reject work instead of short-circuiting uniformly.
        running = {}
        if i % 3 == 0:
            running["svc_cache"] = 1
        if i % 5 == 2:
            running["noisy_batch"] = 2
        if i % 7 == 3:
            running["noisy_etl"] = 1
        worker = WorkerState(
            name=f"w{i}",
            zone=zone,
            sets=frozenset({zone, "any"}),
            running_functions=running,
        )
        if saturated:
            # Every slot consumed: the `overload` invalidate rejects every
            # candidate, i.e. the indexed path's empty-availability case.
            worker.inflight = worker.capacity_slots
            worker.capacity_used_pct = 100.0
        c.add_worker(worker)
    return c


def _machine_speed_factor() -> float:
    """How much slower this host is than the reference, as a budget scale.

    Times a fixed dict/attribute micro-workload shaped like the admission
    path (counter bumps, dict get/set, a float percentage) and divides by
    ``CALIBRATION_BASELINE_US``. The absolute façade budget multiplies by
    the factor, clamped to [1.0, 3.0]: a slower CI host gets
    proportionally more µs for the same fixed work (the overhead it
    measures grows by exactly this factor), a faster host keeps the
    reference budget, and a host >3× slower is too noisy to gate on
    absolute µs at all — better to fail loudly there than stretch the
    budget into meaninglessness.
    """

    class _W:
        __slots__ = ("inflight", "pct")

        def __init__(self) -> None:
            self.inflight = 0
            self.pct = 0.0

    w = _W()
    d: Dict[int, int] = {}

    def unit() -> None:
        for i in range(64):
            k = i & 7
            d[k] = d.get(k, 0) + 1
            w.inflight = w.inflight + 1
            w.pct = 100.0 * w.inflight / 1024

    us = _floor_us(unit, 2000, reps=5)
    return min(3.0, max(1.0, us / CALIBRATION_BASELINE_US))


def _time_us(fn, n: int = 2000) -> float:
    fn()  # warm
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def _floor_us(fn, n: int, reps: int = 5) -> float:
    """Best-of-``reps`` timing with the GC parked (the `timeit` rationale).

    The per-decision gates compare ~µs quantities across rows that run
    *after* the interpreter reference has churned the allocator; GC
    pauses triggered during a timed window are additive noise that can
    double a 5µs measurement. Each rep's mean is taken with collection
    disabled (collecting between reps instead), and the minimum over
    reps is the deterministic-cost estimate a regression actually moves.
    """
    import gc

    times = []
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _ in range(reps):
            gc.collect()
            times.append(_time_us(fn, n))
    finally:
        if was_enabled:
            gc.enable()
    return min(times)


def _paired_ratio_us(fn_a, fn_b, n: int, reps: int = 7):
    """Noise-robust A/B comparison for the ratio gate.

    Times the two callables in alternating reps with the garbage
    collector disabled (the `timeit` rationale: GC pauses and
    machine-state noise are strictly additive, and hit the side that
    allocates more — here the B/invoke side — asymmetrically), then
    compares the per-side floors: each side's minimum over ``reps`` is
    its best estimate of deterministic cost, so one contended rep cannot
    flake the gate. Returns ``(best_us_a, best_us_b, floor_ratio)``.
    """
    import gc

    a_times, b_times = [], []
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _ in range(reps):
            a_times.append(_time_us(fn_a, n))
            b_times.append(_time_us(fn_b, n))
            gc.collect()  # pay collection between reps, not inside them
    finally:
        if gc_was_enabled:
            gc.enable()
    us_a, us_b = min(a_times), min(b_times)
    return us_a, us_b, us_b / max(1e-9, us_a)


def _platform_row(n_workers: int, iters: int) -> Dict:
    """The façade-overhead row: unified invoke vs raw gateway routing.

    ``TappPlatform.invoke`` = ``Gateway.route`` + admission recording +
    the ``Placement`` handle; the gate pins the façade's *absolute*
    per-call cost over raw routing to ``PLATFORM_OVERHEAD_US`` at the
    representative ``PLATFORM_SIZE``-worker deployment (admission
    recording is a fixed ~2-3µs; policy evaluation is what used to scale
    with the cluster, and no longer does). Worker slots are sized so the
    timed admissions never saturate a worker (completion is the retire
    path, not per-decision routing — see ``make bench-serve`` for the
    full lifecycle under load).
    """
    spec = ClusterSpec(
        controllers=(
            ControllerSpec("C1", zone="east"),
            ControllerSpec("C2", zone="west"),
        ),
        workers=tuple(
            WorkerSpec(
                f"w{i}",
                zone="east" if i % 2 == 0 else "west",
                sets=("east" if i % 2 == 0 else "west", "any"),
                capacity_slots=1 << 30,
            )
            for i in range(n_workers)
        ),
    )
    platform = TappPlatform(
        spec, distribution=DistributionPolicy.SHARED, seed=0, policy=SCRIPT
    )
    gateway = platform.gateway
    inv = Invocation("fn", tag="tagged")
    us_route, us_invoke, overhead = _paired_ratio_us(
        lambda: gateway.route(inv),
        lambda: platform.invoke(inv),
        max(iters // 2, 500),
    )
    return {
        "name": f"platform_invoke_{n_workers}w",
        "us_route": us_route,
        "us_invoke": us_invoke,
        "us_per_call": us_invoke,
        "facade_overhead": overhead,
        "facade_overhead_us": us_invoke - us_route,
        "machine_factor": _machine_speed_factor(),
    }


def _federation_row(n_workers: int, iters: int) -> Dict:
    """Zone-local federation invoke vs flat-platform invoke (same scale).

    The same two-zone deployment drives both façades: the flat
    ``TappPlatform`` over the merged cluster, and a two-entry
    ``TappFederation`` invoked at the east gateway with a tag whose first
    block always places zone-locally (huge slots, so no forwarding walk
    ever runs). The gate pins the federation's zone-local invoke to
    ``FEDERATION_FACTOR`` × the flat invoke — the per-zone entrypoints
    must not tax the µs-scale fast path of PR 4.
    """
    def _zone_spec(zone: str) -> ClusterSpec:
        return ClusterSpec(
            workers=tuple(
                WorkerSpec(
                    f"{zone[0]}{i}",
                    sets=(zone, "any"),
                    capacity_slots=1 << 30,
                )
                for i in range(n_workers // 2)
            ),
            controllers=(ControllerSpec(f"{zone.title()}Ctl"),),
        )

    east, west = _zone_spec("east"), _zone_spec("west")
    fed_spec = FederationSpec.of({"east": east, "west": west})
    flat = TappPlatform(
        fed_spec.merged(), distribution=DistributionPolicy.SHARED, seed=0,
        policy=SCRIPT,
    )
    federation = TappFederation(
        fed_spec, distribution=DistributionPolicy.SHARED, seed=0,
        policy=SCRIPT,
    )
    inv = Invocation("fn", tag="tagged")
    us_flat, us_fed, ratio = _paired_ratio_us(
        lambda: flat.invoke(inv),
        lambda: federation.invoke(inv, entry_zone="east"),
        max(iters // 2, 500),
    )
    return {
        "name": f"federation_invoke_{n_workers}w",
        "us_flat": us_flat,
        "us_invoke": us_fed,
        "us_per_call": us_fed,
        "federation_overhead": ratio,
    }


def _retry_platform_spec(n_workers: int) -> ClusterSpec:
    return ClusterSpec(
        controllers=(
            ControllerSpec("C1", zone="east"),
            ControllerSpec("C2", zone="west"),
        ),
        workers=tuple(
            WorkerSpec(
                f"w{i}",
                zone="east" if i % 2 == 0 else "west",
                sets=("east" if i % 2 == 0 else "west", "any"),
                capacity_slots=1 << 30,
            )
            for i in range(n_workers)
        ),
    )


def _retry_row(n_workers: int, iters: int) -> Dict:
    """Fault-free fast path: retry-armed invoke vs plain invoke (PR 6).

    Two identical platforms over the same deployment, one constructed
    with a ``RetryPolicy``, both invoked on a healthy cluster so the
    retry loop never fires. The armed side's only extra work is the
    policy-resolution lookup after a successful placement — the gate
    pins it to ``RETRY_FACTOR`` × the plain invoke so the robustness
    layer cannot tax the µs-scale fast path it protects.
    """
    spec = _retry_platform_spec(n_workers)
    plain = TappPlatform(
        spec, distribution=DistributionPolicy.SHARED, seed=0, policy=SCRIPT
    )
    armed = TappPlatform(
        spec, distribution=DistributionPolicy.SHARED, seed=0, policy=SCRIPT,
        retry=RetryPolicy(max_attempts=3),
    )
    inv = Invocation("fn", tag="tagged")
    us_plain, us_armed, ratio = _paired_ratio_us(
        lambda: plain.invoke(inv),
        lambda: armed.invoke(inv),
        max(iters // 2, 500),
    )
    return {
        "name": f"retry_invoke_{n_workers}w",
        "us_plain": us_plain,
        "us_invoke": us_armed,
        "us_per_call": us_armed,
        "retry_overhead": ratio,
    }


def _overload_row(n_workers: int, iters: int) -> Dict:
    """Unsaturated fast path: overload-armed invoke vs plain invoke (PR 9).

    Two identical platforms over the same deployment, one constructed
    with a full ``OverloadSpec`` (admission queue + brownout), both
    invoked on a cluster with effectively infinite slots so every invoke
    schedules and the queue never holds an entry. The armed side's only
    extra work is an empty-dict drain check in ``complete`` and the
    dead enqueue branch guard — the gate pins it to ``OVERLOAD_FACTOR``
    × the plain invoke so the overload layer is free until it fires.
    """
    spec = _retry_platform_spec(n_workers)
    plain = TappPlatform(
        spec, distribution=DistributionPolicy.SHARED, seed=0, policy=SCRIPT
    )
    armed = TappPlatform(
        spec, distribution=DistributionPolicy.SHARED, seed=0, policy=SCRIPT,
        overload=OverloadSpec(
            queue=QueueSpec(depth=64, deadline=60.0),
            brownout=BrownoutSpec(),
        ),
    )
    inv = Invocation("fn", tag="tagged")
    us_plain, us_armed, ratio = _paired_ratio_us(
        lambda: plain.invoke(inv),
        lambda: armed.invoke(inv),
        max(iters // 2, 500),
    )
    return {
        "name": f"overload_invoke_{n_workers}w",
        "us_plain": us_plain,
        "us_invoke": us_armed,
        "us_per_call": us_armed,
        "overload_overhead": ratio,
    }


def _warm_first_row(n_workers: int, iters: int) -> Dict:
    """Warm-pool fast path: lifecycle-armed warm-first invoke vs plain (PR 10).

    Two platforms over the same deployment: the plain tagged script with
    no lifecycle, and its warm-first variant with a warm-pool lifecycle
    armed. No placement ever completes, so pools stay cold and the warm
    mask is all-zero — the armed side's measured extra work is the pool
    admission hook (spawn a cold instance per invoke), the clockless
    lazy-janitor guard, the warm-mask read, and the empty warm
    partition's fall-through to the best-first bit pick. The gate pins
    it to ``WARM_FIRST_FACTOR`` × the plain invoke so cold-start-aware
    routing stays O(1) per decision.
    """
    spec = _retry_platform_spec(n_workers)
    plain = TappPlatform(
        spec, distribution=DistributionPolicy.SHARED, seed=0, policy=SCRIPT
    )
    armed = TappPlatform(
        spec, distribution=DistributionPolicy.SHARED, seed=0,
        policy=WARM_FIRST_SCRIPT, lifecycle=LifecycleSpec(),
    )
    inv = Invocation("fn", tag="tagged")
    us_plain, us_armed, ratio = _paired_ratio_us(
        lambda: plain.invoke(inv),
        lambda: armed.invoke(inv),
        max(iters // 2, 500),
    )
    return {
        "name": f"warm_first_invoke_{n_workers}w",
        "us_plain": us_plain,
        "us_invoke": us_armed,
        "us_per_call": us_armed,
        "warm_first_overhead": ratio,
    }


def _recovery_row(n_workers: int, iters: int) -> Dict:
    """Worker-failure recovery time: fail → evict → re-route (PR 6).

    Each cycle admits a placement, kills its worker (``fail_worker``
    evicts the ticket and bumps the topology epoch), re-routes the dead
    placement with ``platform.retry`` — which must land on a live worker
    on the first pass — then revives the worker for the next cycle. The
    reported µs is the full detection-to-replacement cost at the
    representative cluster size: ticket eviction, epoch-index
    recompilation, the masked re-route, and the replacement admission.
    Informational (no gate): the committed row documents the recovery
    budget the §5-scale chaos runs amortize.
    """
    platform = TappPlatform(
        _retry_platform_spec(n_workers),
        distribution=DistributionPolicy.SHARED, seed=0, policy=SCRIPT,
        retry=RetryPolicy(max_attempts=3, backoff_base=0.0),
    )
    inv = Invocation("fn", tag="tagged")

    def cycle():
        placement = platform.invoke(inv)
        victim = placement.worker
        platform.fail_worker(victim)
        replacement = platform.retry(placement)
        assert replacement is not None and replacement.scheduled
        replacement.complete()
        platform.restore(victim)

    return {
        "name": f"recovery_{n_workers}w",
        "us_per_call": _floor_us(cycle, max(iters // 4, 250)),
    }


def _analyzer_row(n_workers: int, iters: int) -> Dict:
    """apply_policy-time static analysis cost at the production point.

    Times :func:`analyze_plan` — the PR 8 verifier's reachability /
    satisfiability / starvation pass — on the constraint-heavy script
    against the ``n_workers`` snapshot. The plan is compiled fresh
    outside the timed region (compile cost is already covered by the
    compiled-path rows); what is gated is the *analysis* latency
    ``apply_policy`` adds between compile and the atomic plan swap.
    """
    cluster = _cluster(n_workers)
    plan = compile_script(parse_tapp(CONSTRAINED_SCRIPT))
    us = _floor_us(
        lambda: analyze_plan(plan, cluster, DistributionPolicy.SHARED),
        max(iters // 100, 3),
        reps=3,
    )
    return {
        "name": f"apply_policy_analyzed_{n_workers}w",
        "analyzer_us": us,
        "us_per_call": us,
        "machine_factor": _machine_speed_factor(),
    }


def microbench(*, smoke: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    script = parse_tapp(SCRIPT)
    constrained = parse_tapp(CONSTRAINED_SCRIPT)
    sizes = SMOKE_SIZES if smoke else SIZES
    iters = 300 if smoke else 2000
    # Measured first, before the O(workers) interpreter rows fragment the
    # allocator and pollute caches — the ratio gate compares two ~µs
    # quantities and needs pristine process state on both sides. A
    # borderline measurement is re-taken (floor over up to 3 samples):
    # noise is additive, per-process hash randomization moves the routing
    # cost itself by ~20%, and a real façade regression stays above the
    # gate in every sample anyway.
    platform_row = _platform_row(PLATFORM_SIZE, iters)
    for _ in range(2):
        budget = PLATFORM_OVERHEAD_US * platform_row["machine_factor"]
        if platform_row["facade_overhead_us"] <= 0.8 * budget:
            break
        retry = _platform_row(PLATFORM_SIZE, iters)
        if retry["facade_overhead_us"] < platform_row["facade_overhead_us"]:
            platform_row = retry
    # Same pristine-state + borderline-retry discipline for the paired
    # federation/flat comparison (it is a ratio of two ~µs quantities).
    federation_row = _federation_row(PLATFORM_SIZE, iters)
    for _ in range(2):
        if federation_row["federation_overhead"] <= 0.8 * FEDERATION_FACTOR:
            break
        retry = _federation_row(PLATFORM_SIZE, iters)
        if retry["federation_overhead"] < federation_row["federation_overhead"]:
            federation_row = retry
    # ... and for the retry-armed/plain pair (PR 6's fast-path gate).
    retry_row = _retry_row(PLATFORM_SIZE, iters)
    for _ in range(2):
        if retry_row["retry_overhead"] <= 0.8 * RETRY_FACTOR:
            break
        retake = _retry_row(PLATFORM_SIZE, iters)
        if retake["retry_overhead"] < retry_row["retry_overhead"]:
            retry_row = retake
    # ... and for the overload-armed/plain pair (PR 9's fast-path gate).
    overload_row = _overload_row(PLATFORM_SIZE, iters)
    for _ in range(2):
        if overload_row["overload_overhead"] <= 0.8 * OVERLOAD_FACTOR:
            break
        retake = _overload_row(PLATFORM_SIZE, iters)
        if retake["overload_overhead"] < overload_row["overload_overhead"]:
            overload_row = retake
    # ... and for the lifecycle-armed warm-first/plain pair (PR 10).
    warm_first_row = _warm_first_row(PLATFORM_SIZE, iters)
    for _ in range(2):
        if warm_first_row["warm_first_overhead"] <= 0.8 * WARM_FIRST_FACTOR:
            break
        retake = _warm_first_row(PLATFORM_SIZE, iters)
        if retake["warm_first_overhead"] < warm_first_row["warm_first_overhead"]:
            warm_first_row = retake
    recovery_row = _recovery_row(PLATFORM_SIZE, iters)
    for n_workers in sizes:
        cluster = _cluster(n_workers)
        vanilla = VanillaScheduler()
        for label, scr, inv in (
            ("tagged", script, Invocation("fn", tag="tagged")),
            ("default", script, Invocation("fn")),
            ("constrained", constrained, Invocation("fn", tag="constrained")),
        ):
            # Fresh engines per row: the compiled-plan cache is per script
            # object, so alternating scripts on one engine would recompile.
            interp = TappEngine(
                DistributionPolicy.SHARED, seed=0, compiled=False
            )
            comp = TappEngine(DistributionPolicy.SHARED, seed=0, compiled=True)
            # The seed interpreter always produced a full trace; measure it
            # as such so `speedup` is against the paper-faithful baseline.
            # Same GC-parked floor methodology as the compiled side (fewer
            # reps, it is the slow reference) so the ratio is honest —
            # mixing a GC-exposed mean with a GC-parked floor would bias
            # every speedup upward.
            us_interp = _floor_us(
                lambda: interp.schedule(inv, scr, cluster, trace=True),
                iters,
                reps=3,
            )
            us_comp = _floor_us(
                lambda: comp.schedule(inv, scr, cluster), iters
            )
            batch = [inv] * BATCH
            us_batch = (
                _floor_us(
                    lambda: comp.schedule_batch(batch, scr, cluster),
                    max(1, iters // BATCH),
                )
                / BATCH
            )
            rows.append(
                {
                    "name": f"tapp_{label}_{n_workers}w",
                    "us_interpreted": us_interp,
                    "us_compiled": us_comp,
                    "us_batch": us_batch,
                    "us_per_call": us_comp,
                    "speedup": us_interp / max(1e-9, us_comp),
                    "batch_speedup": us_comp / max(1e-9, us_batch),
                }
            )
        rows.append(_saturated_row(n_workers, script, iters))
        rows.append(_churn_row(n_workers, script, iters))
        rows.append(
            {
                "name": f"vanilla_{n_workers}w",
                "us_per_call": _time_us(
                    lambda: vanilla.schedule(Invocation("fn"), cluster), iters
                ),
            }
        )
    rows.append(platform_row)
    rows.append(federation_row)
    rows.append(retry_row)
    rows.append(overload_row)
    rows.append(warm_first_row)
    rows.append(recovery_row)
    rows.append(_analyzer_row(PLATFORM_SIZE, iters))
    return rows


def _saturated_row(n_workers: int, script, iters: int) -> Dict:
    """Decision cost against a fully saturated cluster (default tag).

    Every worker sits at capacity, so the decision fails by policy.
    On the indexed path this is the empty-availability-mask case: the
    gate pins it to ``SATURATED_FACTOR``× the unsaturated row, i.e.
    saturated workers must cost (almost) nothing to skip. The gated
    ratio is measured *paired* (alternating reps, GC parked, per-side
    floors — the ``_paired_ratio_us`` rationale): both sides are ~µs
    quantities, so comparing a fresh measurement against the main
    loop's earlier row would gate on machine drift, not on regressions.
    A borderline ratio is re-taken (best of 3): noise is additive and
    a real saturation regression survives every sample.
    """
    inv = Invocation("fn")
    best: Dict = {}
    for _ in range(3):
        saturated = _cluster(n_workers, saturated=True)
        baseline = _cluster(n_workers)
        engine_sat = TappEngine(DistributionPolicy.SHARED, seed=0,
                                compiled=True)
        engine_base = TappEngine(DistributionPolicy.SHARED, seed=0,
                                 compiled=True)
        us_base, us_sat, ratio = _paired_ratio_us(
            lambda: engine_base.schedule(inv, script, baseline),
            lambda: engine_sat.schedule(inv, script, saturated),
            iters,
            reps=5,
        )
        if not best or ratio < best["saturated_ratio"]:
            best = {
                "name": f"tapp_default_{n_workers}w_saturated",
                "us_compiled": us_sat,
                "us_per_call": us_sat,
                "us_unsaturated_paired": us_base,
                "saturated_ratio": ratio,
            }
        if best["saturated_ratio"] <= 0.8 * SATURATED_FACTOR:
            break
    return best


def _churn_row(n_workers: int, script, iters: int) -> Dict:
    """Full decide→admit→complete cycle through the watcher ledger.

    Exercises the O(1) incremental index maintenance: every admission
    and completion logs one load event that the next decision's refresh
    consumes — batched bit re-derivation over the compacted log, never a
    candidate rescan. The gated ``churn_ratio`` is measured *paired*
    against a pure steady-state decision (alternating reps, GC parked,
    per-side floors — the ``_paired_ratio_us`` rationale) and pinned to
    ``CHURN_FACTOR``: the two watcher calls plus the incremental refresh
    must stay within one decision's worth of extra work. Borderline
    ratios are re-taken (best of 3, additive-noise rationale).
    """
    inv = Invocation("fn")
    best: Dict = {}
    for _ in range(3):
        watcher = Watcher(_cluster(n_workers))
        cluster = watcher.cluster
        engine = TappEngine(DistributionPolicy.SHARED, seed=0, compiled=True)
        steady_cluster = _cluster(n_workers)
        steady_engine = TappEngine(DistributionPolicy.SHARED, seed=0,
                                   compiled=True)

        def cycle():
            decision = engine.schedule(inv, script, cluster)
            worker = decision.worker
            if worker is not None:
                controller = decision.controller or "?"
                watcher.record_admission(worker, controller, "fn")
                watcher.record_completion(worker, controller, "fn")

        def steady():
            steady_engine.schedule(inv, script, steady_cluster)

        us_steady, us_cycle, ratio = _paired_ratio_us(
            steady, cycle, iters, reps=5
        )
        if not best or ratio < best["churn_ratio"]:
            best = {
                "name": f"tapp_default_{n_workers}w_churn",
                "us_per_call": us_cycle,
                "us_steady_paired": us_steady,
                "churn_ratio": ratio,
            }
        if best["churn_ratio"] <= 0.8 * CHURN_FACTOR:
            break
    return best


def _throughput_row(
    zones: int, total_workers: int, ops_per_zone: int, flap_every: int
) -> Dict:
    """Sustained federated invoke throughput with one thread per zone.

    Every zone entrypoint runs its own driver thread invoking the
    default tag at its own gateway, completing each placement, and —
    every ``flap_every`` ops — flapping a *structural* worker field
    (``capacity_slots``) through the platform heartbeat. Each flap bumps
    the flapped worker's **zone** topology epoch, so the next decision
    in that zone rebuilds its zone-local views and candidate indexes.
    The total worker count is held constant across configurations: the
    1-zone run pays an O(total) rebuild per flap against one shared
    epoch, the 2-zone run two independent O(total/2) rebuilds against
    zone-sharded epochs, caches, and ledger shards — which is exactly
    why aggregate invocations/sec must *rise* with zone count even
    though the interpreter serializes the threads.
    """
    import threading as _threading

    zone_names = tuple(f"z{i}" for i in range(zones))
    per_zone = total_workers // zones
    specs = {
        zone: ClusterSpec(
            workers=tuple(
                WorkerSpec(
                    f"{zone}w{i}", sets=(zone, "any"), capacity_slots=1 << 30
                )
                for i in range(per_zone)
            ),
            controllers=(ControllerSpec(f"{zone}ctl"),),
        )
        for zone in zone_names
    }
    federation = TappFederation(
        FederationSpec.of(specs), distribution=DistributionPolicy.SHARED,
        seed=0, policy=SCRIPT,
    )
    federation.prewarm()
    barrier = _threading.Barrier(zones + 1)

    def drive(zone: str) -> None:
        inv = Invocation("fn")
        flap_worker = f"{zone}w0"
        barrier.wait()
        for n in range(1, ops_per_zone + 1):
            federation.invoke(inv, entry_zone=zone).complete()
            if n % flap_every == 0:
                federation.heartbeat(
                    flap_worker,
                    capacity_slots=(1 << 30) + (n // flap_every) % 2,
                )

    threads = [
        _threading.Thread(target=drive, args=(zone,)) for zone in zone_names
    ]
    for thread in threads:
        thread.start()
    barrier.wait()
    t0 = time.perf_counter()
    for thread in threads:
        thread.join()
    elapsed = time.perf_counter() - t0
    total_ops = ops_per_zone * zones
    return {
        "name": f"federation_throughput_{zones}zone",
        "zones": zones,
        "workers": total_workers,
        "ops": total_ops,
        "flap_every": flap_every,
        "inv_per_sec": total_ops / max(1e-9, elapsed),
    }


def throughput_rows(*, smoke: bool = False) -> List[Dict]:
    """The 1-zone vs 2-zone concurrent-throughput comparison (PR 7).

    Best-of-``reps`` per configuration with the GC parked (the
    ``_floor_us`` rationale: scheduler noise and collection pauses are
    additive, so each config's max inv/sec is its deterministic-cost
    estimate). Smoke runs are single-rep at reduced ops — recorded for
    the CI artifact but not gated there (thread-scheduling noise on
    shared CI hosts would flake an absolute-concurrency gate; the
    committed artifact is regenerated on a quiet host with --check).
    """
    import gc

    ops = 600 if smoke else 4000
    reps = 1 if smoke else 3

    def best(zones: int) -> Dict:
        rows = []
        was_enabled = gc.isenabled()
        gc.disable()
        try:
            for _ in range(reps):
                gc.collect()
                rows.append(
                    _throughput_row(zones, THROUGHPUT_WORKERS, ops,
                                    THROUGHPUT_FLAP_EVERY)
                )
        finally:
            if was_enabled:
                gc.enable()
        return max(rows, key=lambda row: row["inv_per_sec"])

    one = best(1)
    two = best(2)
    two["throughput_scaling"] = (
        two["inv_per_sec"] / max(1e-9, one["inv_per_sec"])
    )
    return [one, two]


def write_bench_json(rows: List[Dict], path: str) -> None:
    payload = {
        "benchmark": "scheduler_micro",
        "unit": "us_per_decision",
        "batch_size": BATCH,
        "rows": rows,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")


def check_rows(rows: List[Dict], *, min_speedup: float = 1.0) -> List[str]:
    """Regression gates.

    1. The compiled path must beat the interpreted reference on every
       tAPP row.
    2. Flat constraint cost: the constraint-heavy compiled script must
       stay within ``CONSTRAINED_FACTOR`` of the plain tagged script's
       us/decision at the same cluster size.
    3. Flat scaling: compiled us/decision at ``FLAT_TOP`` workers must
       stay within ``FLAT_FACTOR`` of the ``FLAT_BASE``-worker row for
       every tAPP script (the O(1)-per-decision index-layer gate).
    4. Saturation is free: the fully-saturated decision must stay within
       ``SATURATED_FACTOR`` of the unsaturated one (empty availability
       mask, no candidate rescans).
    5. Façade overhead is noise: ``TappPlatform.invoke`` (route + admit +
       placement handle) must cost at most ``PLATFORM_OVERHEAD_US`` more
       than raw ``Gateway.route`` at the same cluster size.
    6. Federation is free when local: a zone-local ``TappFederation``
       invoke must stay within ``FEDERATION_FACTOR`` × the flat
       ``TappPlatform`` invoke on the same deployment.
    """
    failures = []
    by_name = {row["name"]: row for row in rows}
    for row in rows:
        overhead_us = row.get("facade_overhead_us")
        if overhead_us is not None:
            budget = PLATFORM_OVERHEAD_US * row.get("machine_factor", 1.0)
            if overhead_us > budget:
                failures.append(
                    f"{row['name']}: platform invoke "
                    f"{row['us_invoke']:.1f}us vs gateway route "
                    f"{row['us_route']:.1f}us (+{overhead_us:.1f}us > "
                    f"{budget:.1f}us host-scaled budget)"
                )
        analyzer_us = row.get("analyzer_us")
        if analyzer_us is not None:
            budget = ANALYZER_BUDGET_US * row.get("machine_factor", 1.0)
            if analyzer_us > budget:
                failures.append(
                    f"{row['name']}: policy analysis {analyzer_us:.0f}us "
                    f"exceeds the {budget:.0f}us host-scaled apply_policy "
                    f"budget"
                )
        fed_overhead = row.get("federation_overhead")
        if fed_overhead is not None and fed_overhead > FEDERATION_FACTOR:
            failures.append(
                f"{row['name']}: federation invoke {row['us_invoke']:.1f}us "
                f"vs flat platform {row['us_flat']:.1f}us "
                f"({fed_overhead:.2f}x > {FEDERATION_FACTOR:.2f}x budget)"
            )
        retry_overhead = row.get("retry_overhead")
        if retry_overhead is not None and retry_overhead > RETRY_FACTOR:
            failures.append(
                f"{row['name']}: retry-armed invoke {row['us_invoke']:.1f}us "
                f"vs plain invoke {row['us_plain']:.1f}us "
                f"({retry_overhead:.2f}x > {RETRY_FACTOR:.2f}x budget)"
            )
        overload_overhead = row.get("overload_overhead")
        if overload_overhead is not None and overload_overhead > OVERLOAD_FACTOR:
            failures.append(
                f"{row['name']}: overload-armed invoke "
                f"{row['us_invoke']:.1f}us "
                f"vs plain invoke {row['us_plain']:.1f}us "
                f"({overload_overhead:.2f}x > {OVERLOAD_FACTOR:.2f}x budget)"
            )
        warm_first_overhead = row.get("warm_first_overhead")
        if (
            warm_first_overhead is not None
            and warm_first_overhead > WARM_FIRST_FACTOR
        ):
            failures.append(
                f"{row['name']}: warm-first lifecycle-armed invoke "
                f"{row['us_invoke']:.1f}us "
                f"vs plain invoke {row['us_plain']:.1f}us "
                f"({warm_first_overhead:.2f}x > {WARM_FIRST_FACTOR:.2f}x "
                f"budget)"
            )
        speedup = row.get("speedup")
        if speedup is not None and speedup < min_speedup:
            failures.append(
                f"{row['name']}: compiled {row['us_compiled']:.1f}us vs "
                f"interpreted {row['us_interpreted']:.1f}us "
                f"(speedup {speedup:.2f}x < {min_speedup:.2f}x)"
            )
        churn_ratio = row.get("churn_ratio")
        if churn_ratio is not None and churn_ratio > CHURN_FACTOR * CHURN_NOISE:
            failures.append(
                f"{row['name']}: decide→admit→complete cycle "
                f"{row['us_per_call']:.1f}us is {churn_ratio:.2f}x the "
                f"paired steady decision "
                f"({row['us_steady_paired']:.1f}us, > "
                f"{CHURN_FACTOR * CHURN_NOISE:.1f}x noise-padded budget)"
            )
        scaling = row.get("throughput_scaling")
        if scaling is not None and scaling < THROUGHPUT_SCALING_FLOOR:
            failures.append(
                f"{row['name']}: {row['zones']}-zone throughput "
                f"{row['inv_per_sec']:.0f} inv/s is only {scaling:.2f}x the "
                f"1-zone configuration (< {THROUGHPUT_SCALING_FLOOR:.1f}x) — "
                f"zone-sharded state is not containing invalidations"
            )
        name = row["name"]
        if name.startswith("tapp_constrained_"):
            plain = by_name.get(
                name.replace("tapp_constrained_", "tapp_tagged_")
            )
            if plain is not None:
                budget = CONSTRAINED_FACTOR * plain["us_compiled"]
                if row["us_compiled"] > budget:
                    failures.append(
                        f"{name}: constraint-heavy compiled "
                        f"{row['us_compiled']:.1f}us exceeds "
                        f"{CONSTRAINED_FACTOR:.1f}x plain tagged "
                        f"({plain['us_compiled']:.1f}us)"
                    )
    # Flat scaling: per-decision cost must not grow with the cluster.
    for label in ("tagged", "default", "constrained"):
        base = by_name.get(f"tapp_{label}_{FLAT_BASE}w")
        top = by_name.get(f"tapp_{label}_{FLAT_TOP}w")
        if base is not None and top is not None:
            budget = FLAT_FACTOR * base["us_compiled"]
            if top["us_compiled"] > budget:
                failures.append(
                    f"tapp_{label}_{FLAT_TOP}w: compiled "
                    f"{top['us_compiled']:.1f}us exceeds {FLAT_FACTOR:.1f}x "
                    f"the {FLAT_BASE}w row ({base['us_compiled']:.1f}us) — "
                    f"per-decision cost is scaling with the cluster"
                )
        # Batch amortization (PR 7): the vectorized batch path must hold
        # its floor at the production point — falling back to per-item
        # dispatch (solver cache misses, scalar fallbacks firing on the
        # homogeneous batch) collapses this to ~1x.
        if top is not None and top.get("batch_speedup") is not None:
            if top["batch_speedup"] < BATCH_SPEEDUP_FLOOR:
                failures.append(
                    f"tapp_{label}_{FLAT_TOP}w: batch "
                    f"{top['us_batch']:.2f}us/item is only "
                    f"{top['batch_speedup']:.2f}x faster than per-call "
                    f"compiled ({top['us_compiled']:.2f}us, "
                    f"< {BATCH_SPEEDUP_FLOOR:.1f}x floor)"
                )
    # Saturation: skipping saturated workers must cost ~nothing. Gated on
    # the row's own paired ratio (same-process alternating floors); the
    # legacy cross-row comparison is kept for artifacts predating it.
    sat = by_name.get(f"tapp_default_{FLAT_TOP}w_saturated")
    base = by_name.get(f"tapp_default_{FLAT_TOP}w")
    if sat is not None:
        ratio = sat.get("saturated_ratio")
        if ratio is None and base is not None:
            ratio = sat["us_compiled"] / max(1e-9, base["us_compiled"])
        if ratio is not None and ratio > SATURATED_FACTOR:
            failures.append(
                f"{sat['name']}: saturated decision "
                f"{sat['us_compiled']:.1f}us is {ratio:.2f}x the "
                f"unsaturated one (> {SATURATED_FACTOR:.1f}x)"
            )
    return failures


def _scaling_ratio(rows_by_name: Dict[str, Dict], label: str) -> Optional[float]:
    base = rows_by_name.get(f"tapp_{label}_{FLAT_BASE}w")
    top = rows_by_name.get(f"tapp_{label}_{FLAT_TOP}w")
    if base is None or top is None:
        return None
    return top["us_compiled"] / max(1e-9, base["us_compiled"])


def compare_rows(
    rows: List[Dict], committed: Dict, *, factor: float = COMPARE_FACTOR
) -> List[str]:
    """Fail on >``factor`` regression vs the committed artifact's floors.

    Only *ratio* quantities are compared — per-row speedup
    (interpreted/compiled), the 4w→1024w scaling ratio, the
    saturated/unsaturated ratio, and the façade overhead — because they
    are scale-free: CI hardware differs from the machine that produced
    the committed artifact, so absolute µs floors would be pure noise,
    while a real regression (an O(workers) rescan sneaking back into the
    fast path) shifts every one of these ratios no matter the host.
    """
    failures: List[str] = []
    current = {row["name"]: row for row in rows}
    floors = {row["name"]: row for row in committed.get("rows", [])}
    for name, row in current.items():
        ref = floors.get(name)
        if ref is None:
            continue
        if "speedup" in row and "speedup" in ref:
            # Speedup floors are capped: the interpreter side of the
            # ratio swings ~1.5-2x across runs (per-process hash
            # randomization, allocator state), so committed values — in
            # the hundreds at 1024w — are gated order-of-magnitude
            # rather than proportionally. A real regression (an
            # O(workers) rescan returning to the fast path) drops every
            # mid/large-size speedup to single digits, far below the
            # cap; the same-run flat-scaling gate in check_rows covers
            # proportional drift.
            floor = min(ref["speedup"] / factor, 20.0)
            if row["speedup"] < floor:
                failures.append(
                    f"{name}: speedup {row['speedup']:.2f}x fell below "
                    f"committed floor {ref['speedup']:.2f}x/{factor:.1f} "
                    f"= {floor:.2f}x"
                )
        if "batch_speedup" in row and "batch_speedup" in ref:
            # Capped like the interpreter speedup floors: both sides of
            # the ratio are GC-parked floors of compiled code, but the
            # per-item replay cost sits under 1us where timer and
            # allocator jitter are proportionally largest. A real batch
            # regression (per-item dispatch returning) lands at ~1x,
            # far below any cap.
            floor = min(ref["batch_speedup"] / factor, BATCH_SPEEDUP_CAP)
            if row["batch_speedup"] < floor:
                failures.append(
                    f"{name}: batch speedup {row['batch_speedup']:.2f}x "
                    f"fell below committed floor "
                    f"{ref['batch_speedup']:.2f}x/{factor:.1f} "
                    f"= {floor:.2f}x"
                )
        if "churn_ratio" in row and "churn_ratio" in ref:
            ceiling = ref["churn_ratio"] * factor
            if row["churn_ratio"] > ceiling and row["churn_ratio"] > CHURN_FACTOR:
                failures.append(
                    f"{name}: churn ratio {row['churn_ratio']:.2f}x exceeds "
                    f"committed {ref['churn_ratio']:.2f}x * {factor:.1f}"
                )
        if "facade_overhead" in row and "facade_overhead" in ref:
            ceiling = ref["facade_overhead"] * factor
            if row["facade_overhead"] > ceiling:
                failures.append(
                    f"{name}: facade overhead {row['facade_overhead']:.2f}x "
                    f"exceeds committed {ref['facade_overhead']:.2f}x "
                    f"* {factor:.1f}"
                )
        if "federation_overhead" in row and "federation_overhead" in ref:
            ceiling = ref["federation_overhead"] * factor
            if row["federation_overhead"] > ceiling:
                failures.append(
                    f"{name}: federation overhead "
                    f"{row['federation_overhead']:.2f}x exceeds committed "
                    f"{ref['federation_overhead']:.2f}x * {factor:.1f}"
                )
        if "retry_overhead" in row and "retry_overhead" in ref:
            ceiling = ref["retry_overhead"] * factor
            if row["retry_overhead"] > ceiling:
                failures.append(
                    f"{name}: retry overhead "
                    f"{row['retry_overhead']:.2f}x exceeds committed "
                    f"{ref['retry_overhead']:.2f}x * {factor:.1f}"
                )
        if "overload_overhead" in row and "overload_overhead" in ref:
            ceiling = ref["overload_overhead"] * factor
            if row["overload_overhead"] > ceiling:
                failures.append(
                    f"{name}: overload overhead "
                    f"{row['overload_overhead']:.2f}x exceeds committed "
                    f"{ref['overload_overhead']:.2f}x * {factor:.1f}"
                )
        if "warm_first_overhead" in row and "warm_first_overhead" in ref:
            ceiling = ref["warm_first_overhead"] * factor
            if row["warm_first_overhead"] > ceiling:
                failures.append(
                    f"{name}: warm-first overhead "
                    f"{row['warm_first_overhead']:.2f}x exceeds committed "
                    f"{ref['warm_first_overhead']:.2f}x * {factor:.1f}"
                )
    for label in ("tagged", "default", "constrained"):
        now = _scaling_ratio(current, label)
        ref = _scaling_ratio(floors, label)
        # The expected scaling ratio is ~1 (flat). A committed value
        # below 1 means the artifact's small-size row happened to be
        # slow that run — luck, not a floor to defend — so the anchor
        # is clamped to 1 before the headroom multiplies it; the
        # same-run FLAT_FACTOR gate in check_rows still bounds the
        # absolute ratio.
        if now is not None and ref is not None:
            anchor = max(ref, 1.0)
            if now > anchor * factor:
                failures.append(
                    f"tapp_{label}: scaling ratio {FLAT_BASE}w→{FLAT_TOP}w "
                    f"{now:.2f}x exceeds committed {anchor:.2f}x "
                    f"* {factor:.1f}"
                )
    def _sat_ratio(rows_by_name: Dict[str, Dict]) -> Optional[float]:
        sat = rows_by_name.get(f"tapp_default_{FLAT_TOP}w_saturated")
        base = rows_by_name.get(f"tapp_default_{FLAT_TOP}w")
        if sat is None:
            return None
        paired = sat.get("saturated_ratio")  # paired rows carry their own
        if paired is not None:
            return paired
        if base is None:
            return None
        return sat["us_compiled"] / max(1e-9, base["us_compiled"])

    now = _sat_ratio(current)
    ref = _sat_ratio(floors)
    if now is not None and ref is not None:
        if now > ref * factor and now > SATURATED_FACTOR:
            failures.append(
                f"saturated/unsaturated ratio {now:.2f}x exceeds committed "
                f"{ref:.2f}x * {factor:.1f}"
            )
    return failures


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="small sizes / few iterations (CI gate)")
    parser.add_argument("--out", default=None,
                        help="write BENCH_scheduler.json to this path")
    parser.add_argument("--check", action="store_true",
                        help="exit non-zero if any regression gate fails "
                             "(speedup, flat scaling, saturation, façade)")
    parser.add_argument("--compare", default=None, metavar="BENCH_JSON",
                        help="also fail on >1.5x regression vs the committed "
                             "artifact's ratio floors")
    parser.add_argument("--throughput", action="store_true",
                        help="run only the multi-entry federated throughput "
                             "rows (1-zone vs 2-zone, one thread per zone)")
    parser.add_argument("--merge", default=None, metavar="BENCH_JSON",
                        help="merge the produced rows into an existing "
                             "artifact (replacing same-name rows) instead of "
                             "writing a fresh one")
    args = parser.parse_args(argv)

    if args.throughput:
        rows = throughput_rows(smoke=args.smoke)
    else:
        rows = microbench(smoke=args.smoke)
    for r in rows:
        if "inv_per_sec" in r:
            scaling = (
                f",scaling={r['throughput_scaling']:.2f}x"
                if "throughput_scaling" in r else ""
            )
            print(
                f"{r['name']},{r['zones']}zx{r['workers'] // r['zones']}w,"
                f"{r['inv_per_sec']:.0f}inv/s{scaling}"
            )
        elif "speedup" in r:
            print(
                f"{r['name']},interp={r['us_interpreted']:.1f}us,"
                f"compiled={r['us_compiled']:.1f}us,"
                f"batch={r['us_batch']:.2f}us,speedup={r['speedup']:.2f}x,"
                f"batchx={r['batch_speedup']:.2f}x"
            )
        elif "churn_ratio" in r:
            print(
                f"{r['name']},cycle={r['us_per_call']:.1f}us,"
                f"steady={r['us_steady_paired']:.1f}us,"
                f"ratio={r['churn_ratio']:.2f}x"
            )
        elif "facade_overhead" in r:
            print(
                f"{r['name']},route={r['us_route']:.1f}us,"
                f"invoke={r['us_invoke']:.1f}us,"
                f"overhead={r['facade_overhead']:.2f}x"
            )
        elif "federation_overhead" in r:
            print(
                f"{r['name']},flat={r['us_flat']:.1f}us,"
                f"invoke={r['us_invoke']:.1f}us,"
                f"overhead={r['federation_overhead']:.2f}x"
            )
        elif "retry_overhead" in r:
            print(
                f"{r['name']},plain={r['us_plain']:.1f}us,"
                f"invoke={r['us_invoke']:.1f}us,"
                f"overhead={r['retry_overhead']:.2f}x"
            )
        elif "overload_overhead" in r:
            print(
                f"{r['name']},plain={r['us_plain']:.1f}us,"
                f"invoke={r['us_invoke']:.1f}us,"
                f"overhead={r['overload_overhead']:.2f}x"
            )
        elif "warm_first_overhead" in r:
            print(
                f"{r['name']},plain={r['us_plain']:.1f}us,"
                f"invoke={r['us_invoke']:.1f}us,"
                f"overhead={r['warm_first_overhead']:.2f}x"
            )
        elif "analyzer_us" in r:
            print(
                f"{r['name']},analyze={r['analyzer_us']:.0f}us,"
                f"budget={ANALYZER_BUDGET_US * r['machine_factor']:.0f}us"
            )
        else:
            print(f"{r['name']},{r['us_per_call']:.1f}us")
    if args.merge:
        with open(args.merge) as fh:
            payload = json.load(fh)
        merged = {row["name"]: row for row in payload.get("rows", [])}
        for row in rows:
            merged[row["name"]] = row
        payload["rows"] = list(merged.values())
        with open(args.merge, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
        print(f"# merged {len(rows)} rows into {args.merge}")
    if args.out:
        write_bench_json(rows, args.out)
        print(f"# wrote {args.out}")
    failures: List[str] = []
    if args.check:
        failures += check_rows(rows)
    if args.compare:
        with open(args.compare) as fh:
            committed = json.load(fh)
        failures += compare_rows(rows, committed)
    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
