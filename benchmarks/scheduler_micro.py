"""Scheduler microbenchmark: µs per scheduling decision.

The paper's overhead argument (§5.4.1) rests on the policy interpreter
being cheap relative to function execution; this measures it directly:
tAPP policy evaluation (interpreted reference vs compiled fast path vs
batched fast path) against vanilla co-prime, across cluster sizes from
4 to 1024 workers.

Rows carry ``us_interpreted`` (the seed interpreter: fresh distribution
views + eager trace formatting per call), ``us_compiled`` (pre-lowered
script plan, epoch-cached views, tracing elided), ``us_batch``
(``schedule_batch`` amortizing plan/tag dispatch over 64 invocations),
and ``speedup`` = interpreted/compiled.

Run ``python benchmarks/run.py sched --out BENCH_scheduler.json`` to
regenerate the committed artifact, or ``make bench-sched`` for the smoke
gate (fails when the compiled path is not faster than the interpreter).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List

from repro.core.platform import (
    ClusterSpec,
    ControllerSpec,
    TappPlatform,
    WorkerSpec,
)
from repro.core.scheduler import (
    ClusterState,
    ControllerState,
    Invocation,
    TappEngine,
    VanillaScheduler,
    WorkerState,
)
from repro.core.scheduler.topology import DistributionPolicy
from repro.core.tapp import parse_tapp

SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- tagged:
  - workers:
    - set: east
    strategy: random
    invalidate: capacity_used 80%
  - workers:
    - set: west
  followup: default
"""

# Constraint-heavy variant: every worker item stacks invalidate + affinity
# and/or anti-affinity clauses, so each candidate check runs the full
# constraint-layer conjunction against the running-function multiset. The
# gate requires this to stay within CONSTRAINED_FACTOR of the plain tagged
# script — per-decision cost must not grow with constraint count.
CONSTRAINED_SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- constrained:
  - workers:
    - set: east
      affinity: [svc_cache]
    strategy: random
    invalidate: capacity_used 80%
    anti-affinity: [noisy_batch]
  - workers:
    - set: west
      anti-affinity: [noisy_batch, noisy_etl]
    invalidate: max_concurrent_invocations 12
  - workers:
    - set:
  followup: default
"""

SIZES = (4, 16, 64, 256, 1024)
SMOKE_SIZES = (4, 64)
BATCH = 64
CONSTRAINED_FACTOR = 2.0  # constrained compiled vs plain compiled, same size
PLATFORM_FACTOR = 1.15    # TappPlatform.invoke vs raw Gateway.route
PLATFORM_SIZE = 1024      # representative production point for the gate


def _cluster(n_workers: int) -> ClusterState:
    c = ClusterState()
    c.add_controller(ControllerState(name="C1", zone="east"))
    c.add_controller(ControllerState(name="C2", zone="west"))
    for i in range(n_workers):
        zone = "east" if i % 2 == 0 else "west"
        # Mixed running-function multisets so the affinity predicates do
        # real accept/reject work instead of short-circuiting uniformly.
        running = {}
        if i % 3 == 0:
            running["svc_cache"] = 1
        if i % 5 == 2:
            running["noisy_batch"] = 2
        if i % 7 == 3:
            running["noisy_etl"] = 1
        c.add_worker(
            WorkerState(
                name=f"w{i}",
                zone=zone,
                sets=frozenset({zone, "any"}),
                running_functions=running,
            )
        )
    return c


def _time_us(fn, n: int = 2000) -> float:
    fn()  # warm
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def _paired_ratio_us(fn_a, fn_b, n: int, reps: int = 7):
    """Noise-robust A/B comparison for the ratio gate.

    Times the two callables in alternating reps with the garbage
    collector disabled (the `timeit` rationale: GC pauses and
    machine-state noise are strictly additive, and hit the side that
    allocates more — here the B/invoke side — asymmetrically), then
    compares the per-side floors: each side's minimum over ``reps`` is
    its best estimate of deterministic cost, so one contended rep cannot
    flake the gate. Returns ``(best_us_a, best_us_b, floor_ratio)``.
    """
    import gc

    a_times, b_times = [], []
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _ in range(reps):
            a_times.append(_time_us(fn_a, n))
            b_times.append(_time_us(fn_b, n))
            gc.collect()  # pay collection between reps, not inside them
    finally:
        if gc_was_enabled:
            gc.enable()
    us_a, us_b = min(a_times), min(b_times)
    return us_a, us_b, us_b / max(1e-9, us_a)


def _platform_row(n_workers: int, iters: int) -> Dict:
    """The façade-overhead row: unified invoke vs raw gateway routing.

    ``TappPlatform.invoke`` = ``Gateway.route`` + admission recording +
    the ``Placement`` handle; the gate pins the whole façade to
    ``PLATFORM_FACTOR``× raw routing at the representative
    ``PLATFORM_SIZE``-worker deployment, so the one-step flow stays
    noise (admission recording is a fixed ~1µs; policy evaluation is
    what scales with the cluster). Worker slots are sized so the timed
    admissions never saturate a worker (completion is the retire path,
    not per-decision routing — see ``make bench-serve`` for the full
    lifecycle under load).
    """
    spec = ClusterSpec(
        controllers=(
            ControllerSpec("C1", zone="east"),
            ControllerSpec("C2", zone="west"),
        ),
        workers=tuple(
            WorkerSpec(
                f"w{i}",
                zone="east" if i % 2 == 0 else "west",
                sets=("east" if i % 2 == 0 else "west", "any"),
                capacity_slots=1 << 30,
            )
            for i in range(n_workers)
        ),
    )
    platform = TappPlatform(
        spec, distribution=DistributionPolicy.SHARED, seed=0, policy=SCRIPT
    )
    gateway = platform.gateway
    inv = Invocation("fn", tag="tagged")
    us_route, us_invoke, overhead = _paired_ratio_us(
        lambda: gateway.route(inv),
        lambda: platform.invoke(inv),
        max(iters // 2, 500),
    )
    return {
        "name": f"platform_invoke_{n_workers}w",
        "us_route": us_route,
        "us_invoke": us_invoke,
        "us_per_call": us_invoke,
        "facade_overhead": overhead,
    }


def microbench(*, smoke: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    script = parse_tapp(SCRIPT)
    constrained = parse_tapp(CONSTRAINED_SCRIPT)
    sizes = SMOKE_SIZES if smoke else SIZES
    iters = 300 if smoke else 2000
    # Measured first, before the O(workers) interpreter rows fragment the
    # allocator and pollute caches — the ratio gate compares two ~µs
    # quantities and needs pristine process state on both sides. A
    # borderline measurement is re-taken (floor over up to 3 samples):
    # noise is additive, per-process hash randomization moves the routing
    # cost itself by ~20%, and a real façade regression stays above the
    # gate in every sample anyway.
    platform_row = _platform_row(PLATFORM_SIZE, iters)
    for _ in range(2):
        if platform_row["facade_overhead"] <= 0.95 * PLATFORM_FACTOR:
            break
        retry = _platform_row(PLATFORM_SIZE, iters)
        if retry["facade_overhead"] < platform_row["facade_overhead"]:
            platform_row = retry
    for n_workers in sizes:
        cluster = _cluster(n_workers)
        vanilla = VanillaScheduler()
        for label, scr, inv in (
            ("tagged", script, Invocation("fn", tag="tagged")),
            ("default", script, Invocation("fn")),
            ("constrained", constrained, Invocation("fn", tag="constrained")),
        ):
            # Fresh engines per row: the compiled-plan cache is per script
            # object, so alternating scripts on one engine would recompile.
            interp = TappEngine(
                DistributionPolicy.SHARED, seed=0, compiled=False
            )
            comp = TappEngine(DistributionPolicy.SHARED, seed=0, compiled=True)
            # The seed interpreter always produced a full trace; measure it
            # as such so `speedup` is against the paper-faithful baseline.
            us_interp = _time_us(
                lambda: interp.schedule(inv, scr, cluster, trace=True),
                iters,
            )
            us_comp = _time_us(
                lambda: comp.schedule(inv, scr, cluster), iters
            )
            batch = [inv] * BATCH
            us_batch = (
                _time_us(
                    lambda: comp.schedule_batch(batch, scr, cluster),
                    max(1, iters // BATCH),
                )
                / BATCH
            )
            rows.append(
                {
                    "name": f"tapp_{label}_{n_workers}w",
                    "us_interpreted": us_interp,
                    "us_compiled": us_comp,
                    "us_batch": us_batch,
                    "us_per_call": us_comp,
                    "speedup": us_interp / max(1e-9, us_comp),
                }
            )
        rows.append(
            {
                "name": f"vanilla_{n_workers}w",
                "us_per_call": _time_us(
                    lambda: vanilla.schedule(Invocation("fn"), cluster), iters
                ),
            }
        )
    rows.append(platform_row)
    return rows


def write_bench_json(rows: List[Dict], path: str) -> None:
    payload = {
        "benchmark": "scheduler_micro",
        "unit": "us_per_decision",
        "batch_size": BATCH,
        "rows": rows,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")


def check_rows(rows: List[Dict], *, min_speedup: float = 1.0) -> List[str]:
    """Regression gates.

    1. The compiled path must beat the interpreted reference on every
       tAPP row.
    2. Flat constraint cost: the constraint-heavy compiled script must
       stay within ``CONSTRAINED_FACTOR`` of the plain tagged script's
       us/decision at the same cluster size.
    3. Façade overhead is noise: ``TappPlatform.invoke`` (route + admit +
       placement handle) must stay within ``PLATFORM_FACTOR`` of raw
       ``Gateway.route`` at the same cluster size.
    """
    failures = []
    by_name = {row["name"]: row for row in rows}
    for row in rows:
        overhead = row.get("facade_overhead")
        if overhead is not None and overhead > PLATFORM_FACTOR:
            failures.append(
                f"{row['name']}: platform invoke {row['us_invoke']:.1f}us vs "
                f"gateway route {row['us_route']:.1f}us "
                f"({overhead:.2f}x > {PLATFORM_FACTOR:.2f}x)"
            )
        speedup = row.get("speedup")
        if speedup is not None and speedup < min_speedup:
            failures.append(
                f"{row['name']}: compiled {row['us_compiled']:.1f}us vs "
                f"interpreted {row['us_interpreted']:.1f}us "
                f"(speedup {speedup:.2f}x < {min_speedup:.2f}x)"
            )
        name = row["name"]
        if name.startswith("tapp_constrained_"):
            plain = by_name.get(
                name.replace("tapp_constrained_", "tapp_tagged_")
            )
            if plain is not None:
                budget = CONSTRAINED_FACTOR * plain["us_compiled"]
                if row["us_compiled"] > budget:
                    failures.append(
                        f"{name}: constraint-heavy compiled "
                        f"{row['us_compiled']:.1f}us exceeds "
                        f"{CONSTRAINED_FACTOR:.1f}x plain tagged "
                        f"({plain['us_compiled']:.1f}us)"
                    )
    return failures


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="small sizes / few iterations (CI gate)")
    parser.add_argument("--out", default=None,
                        help="write BENCH_scheduler.json to this path")
    parser.add_argument("--check", action="store_true",
                        help="exit non-zero if compiled is slower than "
                             "interpreted on any row")
    args = parser.parse_args(argv)

    rows = microbench(smoke=args.smoke)
    for r in rows:
        if "speedup" in r:
            print(
                f"{r['name']},interp={r['us_interpreted']:.1f}us,"
                f"compiled={r['us_compiled']:.1f}us,"
                f"batch={r['us_batch']:.1f}us,speedup={r['speedup']:.2f}x"
            )
        elif "facade_overhead" in r:
            print(
                f"{r['name']},route={r['us_route']:.1f}us,"
                f"invoke={r['us_invoke']:.1f}us,"
                f"overhead={r['facade_overhead']:.2f}x"
            )
        else:
            print(f"{r['name']},{r['us_per_call']:.1f}us")
    if args.out:
        write_bench_json(rows, args.out)
        print(f"# wrote {args.out}")
    if args.check:
        failures = check_rows(rows)
        if failures:
            for f in failures:
                print(f"FAIL: {f}", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
