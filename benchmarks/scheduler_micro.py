"""Scheduler microbenchmark: µs per scheduling decision.

The paper's overhead argument (§5.4.1) rests on the policy interpreter
being cheap relative to function execution; this measures it directly:
tAPP policy evaluation vs vanilla co-prime, across cluster sizes.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.scheduler import (
    ClusterState,
    ControllerState,
    Invocation,
    TappEngine,
    VanillaScheduler,
    WorkerState,
)
from repro.core.scheduler.topology import DistributionPolicy
from repro.core.tapp import parse_tapp

SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- tagged:
  - workers:
    - set: east
    strategy: random
    invalidate: capacity_used 80%
  - workers:
    - set: west
  followup: default
"""


def _cluster(n_workers: int) -> ClusterState:
    c = ClusterState()
    c.add_controller(ControllerState(name="C1", zone="east"))
    c.add_controller(ControllerState(name="C2", zone="west"))
    for i in range(n_workers):
        zone = "east" if i % 2 == 0 else "west"
        c.add_worker(
            WorkerState(name=f"w{i}", zone=zone, sets=frozenset({zone, "any"}))
        )
    return c


def _time_us(fn, n: int = 2000) -> float:
    fn()  # warm
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def microbench() -> List[Dict]:
    rows = []
    script = parse_tapp(SCRIPT)
    for n_workers in (4, 16, 64, 256):
        cluster = _cluster(n_workers)
        engine = TappEngine(DistributionPolicy.SHARED, seed=0)
        vanilla = VanillaScheduler()
        inv_tag = Invocation("fn", tag="tagged")
        inv_plain = Invocation("fn")
        rows.append({
            "name": f"tapp_tagged_{n_workers}w",
            "us_per_call": _time_us(
                lambda: engine.schedule(inv_tag, script, cluster)
            ),
        })
        rows.append({
            "name": f"tapp_default_{n_workers}w",
            "us_per_call": _time_us(
                lambda: engine.schedule(inv_plain, script, cluster)
            ),
        })
        rows.append({
            "name": f"vanilla_{n_workers}w",
            "us_per_call": _time_us(
                lambda: vanilla.schedule(inv_plain, cluster)
            ),
        })
    return rows
