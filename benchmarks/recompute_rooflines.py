"""Re-derive roofline terms for every saved dry-run artifact from its
persisted HLO (no recompilation) — used when the cost model improves."""
import dataclasses
import json
import pathlib
import sys

import zstandard

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config                      # noqa: E402
from repro.models.api import SHAPES                       # noqa: E402
from repro.roofline.analysis import (                     # noqa: E402
    model_bytes_min, model_flops, roofline_terms,
)

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def main() -> None:
    for jpath in sorted(ART.glob("*.json")):
        rec = json.loads(jpath.read_text())
        if rec.get("status") != "ok":
            continue
        hpath = jpath.with_suffix("").with_suffix("")  # strip .json
        hpath = ART / (jpath.stem + ".hlo.zst")
        if not hpath.exists():
            continue
        hlo = zstandard.ZstdDecompressor().decompress(hpath.read_bytes()).decode()
        cfg = get_config(rec["arch"])
        if rec["shape"] != "train_4k":
            cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
        shape = SHAPES[rec["shape"]]
        terms = roofline_terms(
            cost={"flops": 0.0, "bytes accessed": 0.0},
            hlo_text=hlo,
            n_chips=rec["n_chips"],
            model_flops_total=model_flops(cfg, shape),
            model_bytes_min=model_bytes_min(cfg, shape, rec["n_chips"]),
        )
        rec["roofline"] = terms.to_json()
        jpath.write_text(json.dumps(rec, indent=2))
        print(f"recomputed {jpath.name}: dom={terms.dominant} "
              f"frac={terms.roofline_fraction:.3f}")


if __name__ == "__main__":
    main()
