"""Cold-start benchmark: warm-aware vs warm-oblivious routing (PR 10).

An *open-loop* workload (every arrival time fixed up front — one request
per user, staggered across the horizon, so completions never gate
offered load) drives the §5.3 benchmark cluster with the ``cold-start``
function (42.8MB dependency load: 2.8s cold, 30ms warm). Three arms at
EQUAL offered load:

- ``oblivious``  — warm-pool lifecycle armed, but the policy scatters
  requests at random: each worker sees arrivals further apart than the
  keep-alive window, so most placements land on an expired pool and pay
  the cold start.
- ``warm_aware`` — the same lifecycle under a ``warm-first`` policy:
  requests are steered to the worker holding an idle warm instance, so
  only the pool-seeding placements run cold.
- ``legacy_ttl`` — the unarmed platform (informational, no gate): the
  pre-lifecycle ``FunctionProfile.warm_ttl`` model, whose non-consuming
  per-worker warm cache understates cold starts — the reason the knob
  is deprecated in favour of the armed lifecycle.

The gate (``--check``) pins the oblivious arm's cold-start rate to at
least ``COLD_RATE_FACTOR``× the warm-aware arm's — the acceptance bar
for cold-start-aware scheduling. Entirely simulator-driven (engine
ticks, seeded schedules): deterministic, no accelerator, no wall-clock
sensitivity in the gated ratio.

Run ``python benchmarks/run.py coldstart [--smoke] [--check]`` or
``make bench-coldstart``; ``--merge BENCH_serving.json`` folds the rows
into the committed serving artifact.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from typing import Dict, List, Optional

from repro.core.platform import LifecycleSpec, TappPlatform
from repro.core.scheduler.topology import DistributionPolicy
from repro.core.sim.core import Simulation, SimConfig, WorkloadSpec
from repro.core.sim.scenarios import (
    ZONE_EAST,
    adhoc_profiles,
    benchmark_cluster,
    benchmark_network,
)

# Warm-aware routing must cut the cold-start rate by at least this
# factor vs the warm-oblivious arm at equal offered load (the PR 10
# acceptance bar). The committed full-size run measures ~18x — the
# scatter arm keeps expiring pools between visits while warm-first
# re-uses one — so 2.0 leaves wide headroom without letting warm-first
# decay into a no-op.
COLD_RATE_FACTOR = 2.0

SEED = 3

# Keep-alive shorter than the mean per-worker revisit gap of the
# scatter arm (~3s at one arrival/s over 3 workers) but longer than the
# warm-first arm's single-worker gap (~1s): the window where routing,
# not provisioning, decides the cold-start rate.
KEEP_ALIVE = 2.0

# Both gated arms run the same script shape; only the member-selection
# strategy differs. The strategy sits on the *set* (members never
# inherit the block strategy).
OBLIVIOUS_SCRIPT = """
- default:
  - workers:
    - set: any
      strategy: random
    invalidate: overload
"""

WARM_FIRST_COLDSTART_SCRIPT = """
- default:
  - workers:
    - set: any
      strategy: warm-first
    invalidate: overload
"""


def _run_arm(policy: str, lifecycle: Optional[LifecycleSpec], *, smoke: bool):
    platform = TappPlatform(
        benchmark_cluster(deployment_seed=SEED),
        distribution=DistributionPolicy.SHARED,
        seed=SEED,
        policy=policy,
        lifecycle=lifecycle,
    )
    sim = Simulation(
        platform, benchmark_network(), adhoc_profiles(False),
        SimConfig(seed=SEED, gateway_zone=ZONE_EAST),
        is_tapp=True,
    )
    horizon = 60.0 if smoke else 240.0
    users = int(horizon)  # one arrival per second, staggered open-loop
    result = sim.run([
        WorkloadSpec(
            function="cold-start", users=users, requests_per_user=1,
            ramp_up=horizon,
        )
    ])
    return result, platform


def _row(name: str, result, platform, baseline_rate: Optional[float]) -> Dict:
    offered = len(result.records)
    ok = sum(1 for r in result.records if r.ok)
    cold = sum(1 for r in result.records if r.cold)
    cold_rate = cold / max(1, offered)
    lat = [r.latency for r in result.records if r.ok]
    snap = platform.lifecycle_snapshot()
    derived = (
        f"offered={offered};ok={ok};cold={cold};"
        f"cold_rate={cold_rate:.3f};"
        f"warm_hits={snap['warm_hits']};"
        f"expirations={snap['expirations']}"
    )
    row = {
        "name": name,
        # Mean ok-request latency in simulated µs: what the cold starts
        # cost the oblivious arm end-to-end.
        "us_per_call": (statistics.fmean(lat) if lat else 0.0) * 1e6,
        "cold_rate": cold_rate,
        "derived": derived,
    }
    if baseline_rate is not None:
        # How many times fewer cold starts than the oblivious baseline.
        ratio = baseline_rate / max(1e-9, cold_rate)
        row["cold_rate_ratio"] = ratio
        row["derived"] += f";cold_rate_ratio={ratio:.2f}x"
    return row


def coldstart_bench(*, smoke: bool = False) -> List[Dict]:
    lifecycle = LifecycleSpec(keep_alive=KEEP_ALIVE)
    oblivious, p_obl = _run_arm(OBLIVIOUS_SCRIPT, lifecycle, smoke=smoke)
    warm, p_warm = _run_arm(
        WARM_FIRST_COLDSTART_SCRIPT, lifecycle, smoke=smoke
    )
    legacy, p_legacy = _run_arm(OBLIVIOUS_SCRIPT, None, smoke=smoke)
    base_row = _row("coldstart_oblivious", oblivious, p_obl, None)
    rows = [
        base_row,
        _row("coldstart_warm_aware", warm, p_warm, base_row["cold_rate"]),
        _row("coldstart_legacy_ttl", legacy, p_legacy, None),
    ]
    # Equal-offered-load sanity: the open-loop schedule must offer every
    # arm the same load, or the cold-rate ratio is comparing different
    # experiments.
    offered = {int(r["derived"].split(";")[0].split("=")[1]) for r in rows}
    if len(offered) != 1:
        raise RuntimeError(f"offered load diverged across arms: {offered}")
    return rows


def check_rows(rows: List[Dict]) -> List[str]:
    failures: List[str] = []
    by_name = {r["name"]: r for r in rows}
    warm = by_name.get("coldstart_warm_aware")
    if warm is None:
        failures.append("coldstart_warm_aware row missing")
        return failures
    ratio = warm.get("cold_rate_ratio")
    if ratio is None or ratio < COLD_RATE_FACTOR:
        failures.append(
            f"coldstart_warm_aware: cold-start rate is only "
            f"{ratio if ratio is not None else float('nan'):.2f}x better "
            f"than the oblivious arm (< {COLD_RATE_FACTOR:.1f}x) — "
            f"warm-first routing is not steering onto warm instances"
        )
    oblivious = by_name.get("coldstart_oblivious")
    if oblivious is not None and "expirations=0" in oblivious["derived"]:
        failures.append(
            "coldstart_oblivious: zero expirations — the keep-alive "
            "window is not tight enough to make the scatter arm pay "
            "cold starts, so the ratio is not testing routing"
        )
    return failures


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true",
                        help="short horizon / fewer users (CI gate)")
    parser.add_argument("--check", action="store_true",
                        help="exit non-zero if the warm-aware arm's "
                             "cold-start rate is not at least "
                             "COLD_RATE_FACTOR x better than oblivious")
    parser.add_argument("--out", default=None,
                        help="write a standalone JSON artifact here")
    parser.add_argument("--merge", default=None, metavar="BENCH_JSON",
                        help="merge rows into an existing artifact "
                             "(e.g. BENCH_serving.json), replacing "
                             "same-name rows")
    args = parser.parse_args(argv)

    rows = coldstart_bench(smoke=args.smoke)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f}us,{r['derived']}")
    if args.merge:
        with open(args.merge) as fh:
            payload = json.load(fh)
        merged = {row["name"]: row for row in payload.get("rows", [])}
        for row in rows:
            merged[row["name"]] = row
        payload["rows"] = list(merged.values())
        with open(args.merge, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
        print(f"# merged {len(rows)} rows into {args.merge}")
    if args.out:
        payload = {
            "benchmark": "coldstart_bench",
            "unit": "us_mean_ok_latency",
            "rows": rows,
        }
        with open(args.out, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
        print(f"# wrote {args.out}")
    if args.check:
        failures = check_rows(rows)
        if failures:
            for f in failures:
                print(f"FAIL: {f}", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
