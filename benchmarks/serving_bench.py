"""Serving-engine benchmark: throughput/latency of tAPP-scheduled
continuous batching on CPU-hosted small replicas.

Not a paper table per se, but the data-plane companion of the paper's
evaluation: it shows the scheduling layer keeping replicas busy and
routing around load, measured in engine ticks (deterministic).

Run ``python benchmarks/run.py serve --out BENCH_serving.json`` (or
``make bench-serve``) to record the committed artifact.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import statistics
import time
from typing import Dict, List

import jax

from repro.configs import smoke_config
from repro.core.platform import ClusterSpec, ControllerSpec, FederationSpec
from repro.core.scheduler.topology import DistributionPolicy
from repro.core.sim.core import NetworkModel
from repro.models import Model
from repro.runtime.serve_engine import Replica, ServingEngine

SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- interactive:
  - workers:
    - set: edge
    strategy: random
    invalidate: capacity_used 75%
  - workers:
    - set: cloud
  followup: default
"""

# Constraint-layer variant: interactive requests spread via self
# anti-affinity (prefer a replica not already serving the model) before
# falling back to the load-based policy above.
SPREAD_SCRIPT = SCRIPT + """
- spread:
  - workers:
    - set:
    strategy: platform
    invalidate: capacity_used 75%
    anti-affinity: [smollm-135m]
  - workers:
    - set:
  followup: default
"""


def _mk_replica(name, zone, sets, params, cfg, slots=4):
    return Replica(name, cfg, params, zone=zone, sets=sets, slots=slots,
                   max_len=64)


def _federation_spec() -> FederationSpec:
    """Two-entry edge/cloud federation; controllers live in the slices."""
    return FederationSpec.of(
        {
            "edge": ClusterSpec(controllers=(ControllerSpec("EdgeCtl"),)),
            "cloud": ClusterSpec(controllers=(ControllerSpec("CloudCtl"),)),
        },
        network=NetworkModel(rtt={("edge", "cloud"): 0.030}, bandwidth={}),
        default_entry="edge",
    )


def serving_bench() -> List[Dict]:
    cfg = dataclasses.replace(smoke_config("smollm_135m"), n_layers=2)
    params = Model(cfg).init_params(jax.random.PRNGKey(0))

    rows = []
    configs = (
        (f"serving_{DistributionPolicy.SHARED.value}",
         DistributionPolicy.SHARED, SCRIPT, "interactive", 24, 4),
        (f"serving_{DistributionPolicy.ISOLATED.value}",
         DistributionPolicy.ISOLATED, SCRIPT, "interactive", 24, 4),
        # Anti-affinity spread: constraint-layer policy doing data-plane
        # duty (prefer replicas not already serving the model).
        ("serving_shared_antiaffinity",
         DistributionPolicy.SHARED, SPREAD_SCRIPT, "spread", 24, 4),
        # Saturated cluster: far more requests than slots, so most queue
        # admission passes evaluate the policy against fully saturated
        # replicas — the indexed scheduler's empty-availability case; the
        # engine's per-tick cost must not blow up while the queue drains.
        ("serving_shared_saturated",
         DistributionPolicy.SHARED, SCRIPT, "interactive", 64, 2),
        # Cross-zone federation: two per-zone entrypoints, requests
        # entering both zones; small slot counts saturate each zone's
        # replica so the interactive class spills across zones (the
        # forwarding walk + FederatedPlacement path on the hot loop).
        ("serving_federated",
         DistributionPolicy.SHARED, SCRIPT, "interactive", 24, 2),
    )
    for name, policy, script, tag, n_requests, slots in configs:
        federated = name == "serving_federated"
        if federated:
            # Controllers come from the federation spec's zone slices.
            engine = ServingEngine(
                distribution=policy, tapp_script=script,
                federation=_federation_spec(),
            )
        else:
            engine = ServingEngine(distribution=policy, tapp_script=script)
            engine.add_controller("EdgeCtl", zone="edge")
            engine.add_controller("CloudCtl", zone="cloud")
        engine.add_replica(
            _mk_replica("e0", "edge", ["edge"], params, cfg, slots=slots)
        )
        engine.add_replica(
            _mk_replica("c0", "cloud", ["cloud"], params, cfg, slots=slots)
        )

        reqs = [
            engine.submit(
                "smollm-135m", [1 + i % 7, 2, 3],
                tag=tag if i % 2 == 0 else None,
                max_new_tokens=6,
                entry_zone=(
                    ("edge" if i % 3 else "cloud") if federated else None
                ),
            )
            for i in range(n_requests)
        ]
        t0 = time.perf_counter()
        engine.run_until_done(max_ticks=500)
        wall = time.perf_counter() - t0
        done = [r for r in reqs if r.state == "done"]
        latencies = [r.finished_tick - r.submitted_tick for r in done]
        tokens = sum(len(r.output) for r in done)
        derived = (
            f"done={len(done)}/{n_requests};"
            f"mean_ticks={statistics.fmean(latencies):.1f};"
            f"ticks={engine.tick}"
        )
        if federated:
            stats = engine.platform.stats()
            derived += (
                f";forwards={stats.forwards}"
                f";attempts={stats.forward_attempts}"
            )
        rows.append({
            "name": name,
            "us_per_call": wall / max(1, tokens) * 1e6,
            "derived": derived,
        })
    return rows


def write_bench_json(rows: List[Dict], path: str) -> None:
    payload = {
        "benchmark": "serving_bench",
        "unit": "us_per_token",
        "rows": rows,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default=None,
                        help="write BENCH_serving.json to this path")
    args = parser.parse_args(argv)
    rows = serving_bench()
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f}us,{r['derived']}")
    if args.out:
        write_bench_json(rows, args.out)
        print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
