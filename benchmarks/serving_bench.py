"""Serving-engine benchmark: throughput/latency of tAPP-scheduled
continuous batching on CPU-hosted small replicas.

Not a paper table per se, but the data-plane companion of the paper's
evaluation: it shows the scheduling layer keeping replicas busy and
routing around load, measured in engine ticks (deterministic).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Dict, List

import jax

from repro.configs import smoke_config
from repro.core.scheduler.topology import DistributionPolicy
from repro.models import Model
from repro.runtime.serve_engine import Replica, ServingEngine

SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- interactive:
  - workers:
    - set: edge
    strategy: random
    invalidate: capacity_used 75%
  - workers:
    - set: cloud
  followup: default
"""


def _mk_replica(name, zone, sets, params, cfg, slots=4):
    return Replica(name, cfg, params, zone=zone, sets=sets, slots=slots,
                   max_len=64)


def serving_bench() -> List[Dict]:
    cfg = dataclasses.replace(smoke_config("smollm_135m"), n_layers=2)
    params = Model(cfg).init_params(jax.random.PRNGKey(0))

    rows = []
    for policy in (DistributionPolicy.SHARED, DistributionPolicy.ISOLATED):
        engine = ServingEngine(distribution=policy, tapp_script=SCRIPT)
        engine.add_controller("EdgeCtl", zone="edge")
        engine.add_controller("CloudCtl", zone="cloud")
        engine.add_replica(_mk_replica("e0", "edge", ["edge"], params, cfg))
        engine.add_replica(_mk_replica("c0", "cloud", ["cloud"], params, cfg))

        n_requests = 24
        reqs = [
            engine.submit(
                "smollm-135m", [1 + i % 7, 2, 3],
                tag="interactive" if i % 2 == 0 else None,
                max_new_tokens=6,
            )
            for i in range(n_requests)
        ]
        t0 = time.perf_counter()
        engine.run_until_done(max_ticks=500)
        wall = time.perf_counter() - t0
        done = [r for r in reqs if r.state == "done"]
        latencies = [r.finished_tick - r.submitted_tick for r in done]
        tokens = sum(len(r.output) for r in done)
        rows.append({
            "name": f"serving_{policy.value}",
            "us_per_call": wall / max(1, tokens) * 1e6,
            "derived": (
                f"done={len(done)}/{n_requests};"
                f"mean_ticks={statistics.fmean(latencies):.1f};"
                f"ticks={engine.tick}"
            ),
        })
    return rows
