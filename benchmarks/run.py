"""Benchmark entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus section separators).
Roofline rows appear only when dry-run artifacts exist (run
``python -m repro.launch.dryrun --all`` first).
"""
from __future__ import annotations

import sys


def _emit(rows):
    for r in rows:
        name = r.get("name")
        if name is None:
            name = f"{r['test']}_{r['scheduler']}".replace("+", "_")
        us = r.get("us_per_call", r.get("mean_s", 0.0) * 1e6)
        derived = r.get("derived")
        if derived is None:
            derived = (
                f"std_s={r.get('std_s', 0):.3f};"
                f"spread_s={r.get('deployment_spread_s', 0):.3f};"
                f"fail={r.get('failure_rate', 0):.2%}"
            )
        print(f"{name},{us:.1f},{derived}")


def main() -> None:
    if len(sys.argv) > 1 and sys.argv[1] == "sched":
        # Scheduler microbench subcommand (smoke gate / JSON artifact):
        #   python benchmarks/run.py sched [--smoke] [--check] [--out PATH]
        from benchmarks.scheduler_micro import main as sched_main

        raise SystemExit(sched_main(sys.argv[2:]))

    if len(sys.argv) > 1 and sys.argv[1] == "overload":
        # Overload-resilience benchmark subcommand (goodput gate):
        #   python benchmarks/run.py overload [--smoke] [--check]
        #       [--merge BENCH_serving.json]
        from benchmarks.overload_bench import main as overload_main

        raise SystemExit(overload_main(sys.argv[2:]))

    if len(sys.argv) > 1 and sys.argv[1] == "coldstart":
        # Cold-start benchmark subcommand (warm-aware routing gate):
        #   python benchmarks/run.py coldstart [--smoke] [--check]
        #       [--merge BENCH_serving.json]
        from benchmarks.coldstart_bench import main as coldstart_main

        raise SystemExit(coldstart_main(sys.argv[2:]))

    if len(sys.argv) > 1 and sys.argv[1] == "serve":
        # Serving-engine benchmark subcommand (JSON artifact):
        #   python benchmarks/run.py serve [--out PATH]
        from benchmarks.serving_bench import main as serve_main

        raise SystemExit(serve_main(sys.argv[2:]))

    quick = "--quick" in sys.argv
    n_dep = 3 if quick else 6

    print("# === Fig. 9 analogue: overhead tests (no data-locality) ===")
    from benchmarks.paper_tables import overhead_table

    _emit(overhead_table(n_deployments=n_dep))

    print("# === Fig. 10 analogue: data-locality tests ===")
    from benchmarks.paper_tables import data_locality_table

    _emit(data_locality_table(n_deployments=n_dep))

    print("# === §5.1 analogue: qualitative MQTT case ===")
    from benchmarks.paper_tables import qualitative_mqtt

    for r in qualitative_mqtt():
        print(
            f"mqtt_{r['system']}_{r['deployment']}_{r['function']},"
            f"{r['mean_s'] * 1e6:.1f},fail={r['failure_rate']:.0%}"
        )

    print("# === scheduler microbenchmark (policy-evaluation cost) ===")
    from benchmarks.scheduler_micro import microbench

    for r in microbench(smoke=quick):
        derived = "decision-latency"
        if "speedup" in r:
            derived = (
                f"interp={r['us_interpreted']:.1f}us;"
                f"batch={r['us_batch']:.1f}us;"
                f"speedup={r['speedup']:.2f}x"
            )
        print(f"{r['name']},{r['us_per_call']:.1f},{derived}")

    print("# === serving engine (tAPP-scheduled continuous batching) ===")
    from benchmarks.serving_bench import serving_bench

    _emit(serving_bench())

    print("# === roofline (from dry-run artifacts; see EXPERIMENTS.md) ===")
    from benchmarks.roofline_report import csv_rows

    rows = csv_rows("single")
    if rows:
        _emit(rows)
    else:
        print("# (no dry-run artifacts — run python -m repro.launch.dryrun --all)")


if __name__ == "__main__":
    main()
