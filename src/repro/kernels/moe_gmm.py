"""Grouped matmul (MoE expert compute) — Pallas TPU kernel.

Computes ``out[e] = x[e] @ w[e]`` for ``E`` experts with MXU-aligned tiles.
Grid ``(E, C/bc, N/bn, K/bk)`` — the contraction dimension is innermost so
the f32 accumulator lives in VMEM scratch and each output tile is written
once on the final k-step (standard TPU matmul pipelining: next tiles are
DMA'd while the MXU runs).

This is the hot loop of every MoE layer after dispatch packs tokens into
the ``[E, C, d]`` buffer (see ``repro.models.layers.moe``); three calls
(gate/up/down) make one expert FFN. Tile defaults (bc=bn=bk=256 ⇒ three
256×256 f32/bf16 tiles ≈ 0.5 MiB) keep double-buffered working sets well
inside VMEM while saturating the 128×128 MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]   # [bc, bk]
    w = w_ref[0]   # [bk, bn]
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(kk == n_k - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bc", "bn", "bk", "interpret"))
def gmm(
    x: jax.Array,   # [E, C, K]
    w: jax.Array,   # [E, K, N]
    *,
    bc: int = 256,
    bn: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    e, c, k = x.shape
    _, _, n = w.shape
    bc, bn, bk = min(bc, c), min(bn, n), min(bk, k)
    c_pad, k_pad, n_pad = _ru(c, bc), _ru(k, bk), _ru(n, bn)
    if (c_pad, k_pad) != (c, k):
        x = jnp.pad(x, ((0, 0), (0, c_pad - c), (0, k_pad - k)))
    if (k_pad, n_pad) != (k, n):
        w = jnp.pad(w, ((0, 0), (0, k_pad - k), (0, n_pad - n)))

    grid = (e, c_pad // bc, n_pad // bn, k_pad // bk)
    out = pl.pallas_call(
        functools.partial(_gmm_kernel, n_k=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bk), lambda e_, i, j, kk: (e_, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda e_, i, j, kk: (e_, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bn), lambda e_, i, j, kk: (e_, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, c_pad, n_pad), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:, :c, :n]


def _ru(x: int, m: int) -> int:
    return (x + m - 1) // m * m
