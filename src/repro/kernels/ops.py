"""Jit'd public wrappers around the Pallas kernels.

Layout adaptation lives here (the models use ``[B, S, H, D]``; the kernels
use ``[B, H, S, D]``), as does the interpret-mode switch: on a CPU backend
(this container) the kernels execute via ``interpret=True`` — the kernel
body runs in Python/XLA exactly as written — while on TPU they compile to
Mosaic. The pure-jnp oracles live in :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import functools
import os
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.moe_gmm import gmm
from repro.kernels.ssd_scan import ssd_scan_bhsd


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------
# Scheduler batch-routing kernel
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=())
def _select_first_available_jax(words32: jax.Array, orders: jax.Array) -> jax.Array:
    # words32: uint32 [m, 2W] — each uint64 mask word split into
    # (low, high) halves, low half at even indices (jax runs with x64
    # disabled on this container, so uint64 lanes are unavailable;
    # position p lives at word p>>5, bit p&31).
    valid = orders >= 0
    safe = jnp.where(valid, orders, 0)
    gathered = jnp.take_along_axis(
        jnp.broadcast_to(words32, (orders.shape[0], words32.shape[-1])),
        safe >> 5,
        axis=1,
    )
    bits = (gathered >> (safe & 31).astype(jnp.uint32)) & jnp.uint32(1)
    hit = (bits != 0) & valid
    found = hit.any(axis=1)
    first = hit.argmax(axis=1)
    picks = jnp.take_along_axis(orders, first[:, None], axis=1)[:, 0]
    return jnp.where(found, picks, -1).astype(jnp.int32)


def select_first_available(avail_words, orders, *, backend: str = "numpy"):
    """First-set-bit-in-order over availability mask planes (batched).

    The scheduler's mask-plane routing kernel: ``orders`` is an int32
    ``[m, L]`` plane of candidate positions (one row per distinct
    function hash at a routing stage, ``-1``-padded); ``avail_words`` is
    the stage's uint64 availability bitmask (``[W]``, broadcast across
    rows, or per-row ``[m, W]``). Returns int32 ``[m]`` picks, ``-1``
    where no ordered candidate is available.

    ``backend="numpy"`` uses the reference in :mod:`repro.kernels.ref`;
    ``backend="jax"`` runs the identical computation as a jit'd XLA
    program (correctness-equal; useful once mask planes live on an
    accelerator alongside the model kernels).
    """
    from repro.kernels.ref import select_first_available_np

    if backend == "jax":
        import numpy as np

        words = np.ascontiguousarray(avail_words, dtype=np.uint64)
        if words.ndim == 1:
            words = words[None, :]
        # Split each uint64 word into (low, high) uint32 halves by value
        # — not via a .view(), whose half order depends on host byte
        # order — so position p lives at word p>>5, bit p&31 on any
        # endianness (matching _select_first_available_jax's indexing).
        words32 = np.empty(
            (words.shape[0], 2 * words.shape[1]), dtype=np.uint32
        )
        words32[:, 0::2] = (words & np.uint64(0xFFFFFFFF)).astype(np.uint32)
        words32[:, 1::2] = (words >> np.uint64(32)).astype(np.uint32)
        ordered = np.ascontiguousarray(orders, dtype=np.int32)
        if ordered.ndim == 1:
            ordered = ordered[None, :]
        out = _select_first_available_jax(jnp.asarray(words32), jnp.asarray(ordered))
        return np.asarray(out)
    if backend != "numpy":
        raise ValueError(f"unknown select_first_available backend: {backend!r}")
    return select_first_available_np(avail_words, orders)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------


def flash_attention(
    q: jax.Array,    # [B, S, H, D]   (model layout)
    k: jax.Array,    # [B, T, KV, D]
    v: jax.Array,    # [B, T, KV, D]
    *,
    causal: bool = True,
    bq: int = 256,
    bk: int = 256,
) -> jax.Array:
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bhsd(
        qt, kt, vt, causal=causal, bq=bq, bk=bk,
        interpret=_interpret_default(),
    )
    return out.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# MoE grouped matmul FFN
# ---------------------------------------------------------------------------


def moe_ffn_gmm(cfg, params: Dict, buffer: jax.Array) -> jax.Array:
    """Expert FFN over the packed [E, C, d] buffer via grouped matmuls."""
    interp = _interpret_default()
    cdt = buffer.dtype
    if cfg.mlp_kind in ("swiglu", "geglu"):
        gate = gmm(buffer, params["w_gate"].astype(cdt), interpret=interp)
        up = gmm(buffer, params["w_up"].astype(cdt), interpret=interp)
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else jax.nn.gelu
        h = (act(gate.astype(jnp.float32)) * up.astype(jnp.float32)).astype(cdt)
    elif cfg.mlp_kind == "squared_relu":
        h = gmm(buffer, params["w_up"].astype(cdt), interpret=interp)
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(cdt)
    else:
        h = gmm(buffer, params["w_up"].astype(cdt), interpret=interp)
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(cdt)
    return gmm(h, params["w_down"].astype(cdt), interpret=interp)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------


def ssd_scan(
    x: jax.Array,     # [B, S, H, P]  (model layout)
    dt: jax.Array,    # [B, S, H]     (post-softplus)
    a: jax.Array,     # [H]           (negative)
    b_mat: jax.Array, # [B, S, G, N]
    c_mat: jax.Array, # [B, S, G, N]
    *,
    chunk: int = 256,
) -> Tuple[jax.Array, None]:
    f32 = jnp.float32
    dt_f = dt.astype(f32)
    xdt = (x.astype(f32) * dt_f[..., None]).transpose(0, 2, 1, 3)   # [B,H,S,P]
    da = (dt_f * a.astype(f32)[None, None, :]).transpose(0, 2, 1)   # [B,H,S]
    y = ssd_scan_bhsd(
        xdt,
        da[:, :, None, :],
        b_mat.transpose(0, 2, 1, 3),
        c_mat.transpose(0, 2, 1, 3),
        chunk=min(chunk, x.shape[1]) if x.shape[1] % min(chunk, x.shape[1]) == 0
        else chunk,
        interpret=_interpret_default(),
    )
    return y.transpose(0, 2, 1, 3), None
