"""Jit'd public wrappers around the Pallas kernels.

Layout adaptation lives here (the models use ``[B, S, H, D]``; the kernels
use ``[B, H, S, D]``), as does the interpret-mode switch: on a CPU backend
(this container) the kernels execute via ``interpret=True`` — the kernel
body runs in Python/XLA exactly as written — while on TPU they compile to
Mosaic. The pure-jnp oracles live in :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import functools
import os
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.moe_gmm import gmm
from repro.kernels.ssd_scan import ssd_scan_bhsd


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------


def flash_attention(
    q: jax.Array,    # [B, S, H, D]   (model layout)
    k: jax.Array,    # [B, T, KV, D]
    v: jax.Array,    # [B, T, KV, D]
    *,
    causal: bool = True,
    bq: int = 256,
    bk: int = 256,
) -> jax.Array:
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bhsd(
        qt, kt, vt, causal=causal, bq=bq, bk=bk,
        interpret=_interpret_default(),
    )
    return out.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# MoE grouped matmul FFN
# ---------------------------------------------------------------------------


def moe_ffn_gmm(cfg, params: Dict, buffer: jax.Array) -> jax.Array:
    """Expert FFN over the packed [E, C, d] buffer via grouped matmuls."""
    interp = _interpret_default()
    cdt = buffer.dtype
    if cfg.mlp_kind in ("swiglu", "geglu"):
        gate = gmm(buffer, params["w_gate"].astype(cdt), interpret=interp)
        up = gmm(buffer, params["w_up"].astype(cdt), interpret=interp)
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else jax.nn.gelu
        h = (act(gate.astype(jnp.float32)) * up.astype(jnp.float32)).astype(cdt)
    elif cfg.mlp_kind == "squared_relu":
        h = gmm(buffer, params["w_up"].astype(cdt), interpret=interp)
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(cdt)
    else:
        h = gmm(buffer, params["w_up"].astype(cdt), interpret=interp)
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(cdt)
    return gmm(h, params["w_down"].astype(cdt), interpret=interp)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------


def ssd_scan(
    x: jax.Array,     # [B, S, H, P]  (model layout)
    dt: jax.Array,    # [B, S, H]     (post-softplus)
    a: jax.Array,     # [H]           (negative)
    b_mat: jax.Array, # [B, S, G, N]
    c_mat: jax.Array, # [B, S, G, N]
    *,
    chunk: int = 256,
) -> Tuple[jax.Array, None]:
    f32 = jnp.float32
    dt_f = dt.astype(f32)
    xdt = (x.astype(f32) * dt_f[..., None]).transpose(0, 2, 1, 3)   # [B,H,S,P]
    da = (dt_f * a.astype(f32)[None, None, :]).transpose(0, 2, 1)   # [B,H,S]
    y = ssd_scan_bhsd(
        xdt,
        da[:, :, None, :],
        b_mat.transpose(0, 2, 1, 3),
        c_mat.transpose(0, 2, 1, 3),
        chunk=min(chunk, x.shape[1]) if x.shape[1] % min(chunk, x.shape[1]) == 0
        else chunk,
        interpret=_interpret_default(),
    )
    return y.transpose(0, 2, 1, 3), None
