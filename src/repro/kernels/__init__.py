"""Pallas TPU kernels for the data-plane hot spots (+ ops wrappers, ref oracles)."""
