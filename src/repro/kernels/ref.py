"""Pure-jnp oracles for every Pallas kernel (the allclose references).

Deliberately naive — O(S²) attention with materialised scores, einsum
grouped matmul, quadratic SSD — so the tests compare two *independent*
implementations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def select_first_available_np(avail_words, orders):
    """Numpy reference for the scheduler's batch-routing kernel.

    ``avail_words`` — uint64 availability bitmask planes, shape ``[W]``
    (one mask shared by every row) or ``[m, W]`` (per-row masks); bit
    ``p`` of the flattened mask is set iff candidate position ``p`` is
    available. ``orders`` — int32 ``[m, L]`` candidate positions in
    preference order, right-padded with ``-1``.

    Returns int32 ``[m]``: for each row, the first position in its order
    whose availability bit is set, or ``-1`` when none is. Equivalent to
    the scalar ``ItemIndex.pick_*`` scan, resolved for all rows at once
    via a bit-gather and an argmax over the extracted order plane.
    """
    orders = np.ascontiguousarray(orders, dtype=np.int64)
    if orders.ndim == 1:
        orders = orders[None, :]
    m, _l = orders.shape
    words = np.ascontiguousarray(avail_words, dtype=np.uint64)
    if words.ndim == 1:
        words = words[None, :]
    valid = orders >= 0
    safe = np.where(valid, orders, 0)
    gathered = np.take_along_axis(
        np.broadcast_to(words, (m, words.shape[1])), safe >> 6, axis=1
    )
    bits = (gathered >> (safe & 63).astype(np.uint64)) & np.uint64(1)
    hit = (bits != 0) & valid
    found = hit.any(axis=1)
    first = hit.argmax(axis=1)
    picks = np.take_along_axis(orders, first[:, None], axis=1)[:, 0]
    return np.where(found, picks, -1).astype(np.int32)


def ref_attention(
    q: jax.Array,    # [B, H, S, D]
    k: jax.Array,    # [B, KV, T, D]
    v: jax.Array,    # [B, KV, T, D]
    *,
    causal: bool = True,
) -> jax.Array:
    b, h, s, d = q.shape
    kvh, t = k.shape[1], k.shape[2]
    group = h // kvh
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    scores = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32), kf)
    scores = scores / jnp.sqrt(jnp.float32(d))
    if causal:
        mask = jnp.tril(jnp.ones((s, t), dtype=bool), k=t - s)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, vf)
    return out.astype(q.dtype)


def ref_gmm(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: [E, C, K]; w: [E, K, N] → [E, C, N]."""
    return jnp.einsum(
        "eck,ekn->ecn", x.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(x.dtype)


def ref_ssd(
    xdt: jax.Array,   # [B, H, S, P]
    da: jax.Array,    # [B, H, S]
    b_mat: jax.Array, # [B, G, S, N]
    c_mat: jax.Array, # [B, G, S, N]
) -> jax.Array:
    """Quadratic (full-sequence dual form) SSD: O(S²), small shapes only."""
    bsz, h, s, p = xdt.shape
    g = b_mat.shape[1]
    hpg = h // g
    bf = jnp.repeat(b_mat, hpg, axis=1).astype(jnp.float32)  # [B,H,S,N]
    cf = jnp.repeat(c_mat, hpg, axis=1).astype(jnp.float32)
    cum = jnp.cumsum(da.astype(jnp.float32), axis=-1)        # [B,H,S]
    diff = cum[..., :, None] - cum[..., None, :]             # [B,H,S,S]
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    l_mat = jnp.exp(jnp.where(mask[None, None], diff, NEG_INF))
    cb = jnp.einsum("bhln,bhsn->bhls", cf, bf)
    return jnp.einsum("bhls,bhsp->bhlp", cb * l_mat, xdt.astype(jnp.float32))
