"""Pure-jnp oracles for every Pallas kernel (the allclose references).

Deliberately naive — O(S²) attention with materialised scores, einsum
grouped matmul, quadratic SSD — so the tests compare two *independent*
implementations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_attention(
    q: jax.Array,    # [B, H, S, D]
    k: jax.Array,    # [B, KV, T, D]
    v: jax.Array,    # [B, KV, T, D]
    *,
    causal: bool = True,
) -> jax.Array:
    b, h, s, d = q.shape
    kvh, t = k.shape[1], k.shape[2]
    group = h // kvh
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    scores = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32), kf)
    scores = scores / jnp.sqrt(jnp.float32(d))
    if causal:
        mask = jnp.tril(jnp.ones((s, t), dtype=bool), k=t - s)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, vf)
    return out.astype(q.dtype)


def ref_gmm(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: [E, C, K]; w: [E, K, N] → [E, C, N]."""
    return jnp.einsum(
        "eck,ekn->ecn", x.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(x.dtype)


def ref_ssd(
    xdt: jax.Array,   # [B, H, S, P]
    da: jax.Array,    # [B, H, S]
    b_mat: jax.Array, # [B, G, S, N]
    c_mat: jax.Array, # [B, G, S, N]
) -> jax.Array:
    """Quadratic (full-sequence dual form) SSD: O(S²), small shapes only."""
    bsz, h, s, p = xdt.shape
    g = b_mat.shape[1]
    hpg = h // g
    bf = jnp.repeat(b_mat, hpg, axis=1).astype(jnp.float32)  # [B,H,S,N]
    cf = jnp.repeat(c_mat, hpg, axis=1).astype(jnp.float32)
    cum = jnp.cumsum(da.astype(jnp.float32), axis=-1)        # [B,H,S]
    diff = cum[..., :, None] - cum[..., None, :]             # [B,H,S,S]
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    l_mat = jnp.exp(jnp.where(mask[None, None], diff, NEG_INF))
    cb = jnp.einsum("bhln,bhsn->bhls", cf, bf)
    return jnp.einsum("bhls,bhsp->bhlp", cb * l_mat, xdt.astype(jnp.float32))
