"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

One grid step processes one (batch, head, chunk) tile:

  * intra-chunk: the *dual quadratic form* — three MXU matmuls
    ``(C·Bᵀ ⊙ L) · X`` with the decay mask ``L = exp(segsum(Δt·A))``;
  * inter-chunk: the running ``[P, N]`` SSD state is carried in VMEM
    scratch across the (innermost, sequential) chunk grid dimension and
    reset at chunk 0 — no HBM round-trip for the recurrence.

Inputs are pre-scaled in ``ops.py`` (``xdt = x·Δt``, ``da = Δt·A``) so the
kernel sees only matmul-shaped work. Tiles: chunk Q=256 (rows), headdim
P=64 and state N=128 (lanes) — all MXU/VREG aligned for v5e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(
    xdt_ref,    # [1, 1, Q, P]  x * dt        (f32)
    da_ref,     # [1, 1, 1, Q]  dt * A        (f32, negative)
    b_ref,      # [1, 1, Q, N]
    c_ref,      # [1, 1, Q, N]
    y_ref,      # [1, 1, Q, P]  output
    state_ref,  # scratch [P, N] f32 — carried across chunks
    *,
    q_len: int,
):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _reset():
        state_ref[...] = jnp.zeros_like(state_ref)

    xdt = xdt_ref[0, 0]                       # [Q, P]
    da = da_ref[0, 0, 0]                      # [Q]
    b = b_ref[0, 0].astype(jnp.float32)       # [Q, N]
    c = c_ref[0, 0].astype(jnp.float32)       # [Q, N]

    cum = jnp.cumsum(da)                      # [Q]
    # Decay mask L[l, s] = exp(cum[l] - cum[s]) for l >= s.
    diff = cum[:, None] - cum[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 1)
    l_mat = jnp.exp(jnp.where(rows >= cols, diff, NEG_INF))

    # Intra-chunk: (C Bᵀ ⊙ L) X.
    cb = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # [Q, Q]
    y_intra = jax.lax.dot_general(
        cb * l_mat, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                          # [Q, P]

    # Inter-chunk: contribution of the carried state, decayed to each row.
    state = state_ref[...]                     # [P, N]
    c_scaled = c * jnp.exp(cum)[:, None]       # [Q, N]
    y_inter = jax.lax.dot_general(
        c_scaled, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                          # [Q, P]

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # State update: decay to chunk end, add this chunk's contribution.
    decay_to_end = jnp.exp(cum[-1] - cum)      # [Q]
    xd = xdt * decay_to_end[:, None]           # [Q, P]
    s_c = jax.lax.dot_general(
        xd, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # [P, N]
    state_ref[...] = state * jnp.exp(cum[-1]) + s_c


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_bhsd(
    xdt: jax.Array,   # [B, H, S, P]  (x * dt, f32)
    da: jax.Array,    # [B, H, 1, S]  (dt * A, f32)
    b_mat: jax.Array, # [B, G, S, N]
    c_mat: jax.Array, # [B, G, S, N]
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    bsz, h, s, p = xdt.shape
    g, n = b_mat.shape[1], b_mat.shape[3]
    hpg = h // g
    if s % chunk != 0:
        pad = chunk - s % chunk
        xdt = jnp.pad(xdt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, 0), (0, 0), (0, pad)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, 0), (0, pad), (0, 0)))
    s_pad = xdt.shape[2]
    nc = s_pad // chunk

    grid = (bsz, h, nc)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, q_len=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b_, h_, c_: (b_, h_, 0, c_)),
            pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, c_: (b_, h_ // hpg, c_, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, c_: (b_, h_ // hpg, c_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, c_: (b_, h_, c_, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, s_pad, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xdt, da, b_mat, c_mat)
    return out[:, :, :s, :]
