"""Flash attention (forward) — Pallas TPU kernel.

Tiled online-softmax attention targeting TPU v5e: the grid is
``(batch, q_heads, q_blocks, kv_blocks)`` with the kv dimension innermost —
TPU Pallas iterates the grid sequentially, so the output block (indexed by
``(b, h, i)`` only) is revisited across kv steps and the running max / sum /
accumulator live in VMEM scratch. GQA is expressed in the K/V index maps
(``h → h // group``), so kv heads are never materialised per-q-head.

Block shapes are MXU-aligned: ``(bq, d)`` and ``(bk, d)`` tiles with
``d ∈ {64, 128}`` and ``bq = bk = 256`` by default (q/k/v tiles ≈ 256·128·2B
= 64 KiB each; acc + m + l ≈ 160 KiB — comfortably inside the ~16 MiB VMEM).

Fully-masked kv blocks in the causal case are skipped with ``pl.when``
(they cost a grid step but no compute/loads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,          # inputs
    o_ref,                        # output
    acc_ref, m_ref, l_ref,        # scratch
    *,
    bq: int,
    bk: int,
    causal: bool,
    scale: float,
    kv_len: int,
):
    i = pl.program_id(2)   # q block
    j = pl.program_id(3)   # kv block
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = i * bq
    k_start = j * bk

    # Causal: skip blocks fully above the diagonal.
    needed = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)      # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)      # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)      # [bk, d]

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                 # [bq, bk]

        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        if kv_len % bk != 0:
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(cols < kv_len, s, NEG_INF)

        m_prev = m_ref[...]                       # [bq, 1]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                    # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)           # [bq, 1]
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)

        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                          # [bq, d]
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(j == nj - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "bq", "bk", "interpret"),
)
def flash_attention_bhsd(
    q: jax.Array,    # [B, H, S, D]
    k: jax.Array,    # [B, KV, T, D]
    v: jax.Array,    # [B, KV, T, D]
    *,
    causal: bool = True,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, h, s, d = q.shape
    kvh, t = k.shape[1], k.shape[2]
    group = h // kvh
    bq = min(bq, s)
    bk = min(bk, t)
    s_pad = _round_up(s, bq)
    t_pad = _round_up(t, bk)
    if s_pad != s:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    if t_pad != t:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))

    grid = (b, h, s_pad // bq, t_pad // bk)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            bq=bq, bk=bk, causal=causal,
            scale=1.0 / (d ** 0.5), kv_len=t,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :s, :]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
