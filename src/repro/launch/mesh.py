"""Production meshes.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is an outer data-parallel axis whose collectives cross DCN.

Defined as functions (never module-level constants) so importing this
module does not touch JAX device state.
"""
from __future__ import annotations

from typing import Tuple

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` only exists on newer JAX; older versions default to
    Auto axes, so omitting the kwarg is equivalent there."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_debug_mesh(shape: Tuple[int, ...] = (1, 1), axes=("data", "model")):
    """Small mesh for CPU tests (requires matching host device count)."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def data_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """All batch-parallel axes (the 'pod' axis is outer data parallelism)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh: jax.sharding.Mesh) -> str:
    return "model"
