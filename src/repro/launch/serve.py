"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Builds a zoned replica deployment, loads a tAPP script (file or default),
submits a synthetic request mix, and reports placement + latency stats.
"""
from __future__ import annotations

import argparse
import dataclasses
import statistics

import jax

from repro.configs import ARCH_IDS, smoke_config
from repro.core.scheduler.topology import DistributionPolicy
from repro.models import Model
from repro.runtime.serve_engine import Replica, ServingEngine

DEFAULT_SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- interactive:
  - workers:
    - set: edge
    strategy: random
    invalidate: capacity_used 75%
  - workers:
    - set: cloud
  followup: default
- batch:
  - controller: CloudCtl
    workers:
    - set: cloud
    topology_tolerance: same
  followup: default
"""


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm_135m",
                    help=f"one of {ARCH_IDS}")
    ap.add_argument("--script", default=None, help="tAPP script path")
    ap.add_argument("--replicas-per-zone", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--distribution", default="shared",
                    choices=[p.value for p in DistributionPolicy])
    args = ap.parse_args()

    script = DEFAULT_SCRIPT
    if args.script:
        with open(args.script) as fh:
            script = fh.read()

    cfg = dataclasses.replace(smoke_config(args.arch), n_layers=2)
    params = Model(cfg).init_params(jax.random.PRNGKey(0))

    engine = ServingEngine(
        distribution=DistributionPolicy.parse(args.distribution),
        tapp_script=script,
    )
    engine.add_controller("EdgeCtl", zone="edge")
    engine.add_controller("CloudCtl", zone="cloud")
    for zone in ("edge", "cloud"):
        for i in range(args.replicas_per_zone):
            engine.add_replica(
                Replica(f"{zone}-{i}", cfg, params, zone=zone, sets=[zone],
                        slots=args.slots, max_len=64)
            )

    tags = ["interactive", "batch", None]
    reqs = [
        engine.submit(cfg.name, [1 + i % 13, 2, 3], tag=tags[i % 3],
                      max_new_tokens=args.max_new_tokens)
        for i in range(args.requests)
    ]
    engine.run_until_done(max_ticks=2000)

    done = [r for r in reqs if r.state == "done"]
    lat = [r.finished_tick - r.submitted_tick for r in done]
    print(f"arch={cfg.name} requests={len(reqs)} done={len(done)}")
    print(f"latency ticks: mean={statistics.fmean(lat):.1f} "
          f"p50={sorted(lat)[len(lat)//2]} max={max(lat)}")
    by_tag = {}
    for r in done:
        by_tag.setdefault(r.tag or "untagged", []).append(r.replica)
    for tag, replicas in sorted(by_tag.items()):
        zones = {z.split("-")[0] for z in replicas}
        print(f"  {tag:>12}: zones={sorted(zones)} ({len(replicas)} reqs)")
    print(f"gateway: {engine.gateway.stats}; stragglers flagged: "
          f"{engine.stragglers_flagged}")


if __name__ == "__main__":
    main()
