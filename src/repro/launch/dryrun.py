import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# NOTE: the two lines above MUST run before any other import (including
# `from repro...`) — JAX locks the device count on first initialisation.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (lower+compile succeeds — sharding
    mismatches, unsupported collectives, or uneven partitions fail here);
  * the program fits (``memory_analysis()`` per-device bytes vs 16 GiB);
  * and records the roofline inputs (``cost_analysis()`` FLOPs/bytes +
    the collective schedule parsed from the optimized HLO).

Results land in ``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` and are
aggregated by ``benchmarks/roofline_report.py`` into EXPERIMENTS.md.

Usage::

    python -m repro.launch.dryrun --arch smollm-135m --shape train_4k --mesh single
    python -m repro.launch.dryrun --all            # every applicable cell
    python -m repro.launch.dryrun --all --mesh multi
"""

import argparse
import dataclasses
import json
import pathlib
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    abstract_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    train_state_shardings,
)
from repro.models.api import SHAPES, Model, ShapeSpec, shape_applicable
from repro.optim.adamw import AdamWConfig
from repro.roofline.analysis import (
    model_bytes_min,
    model_flops,
    normalize_cost,
    roofline_terms,
)
from repro.sharding.ctx import activation_sharding
from repro.sharding.specs import (
    ShardingPolicy,
    batch_shardings,
    cache_shardings,
)

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def dryrun_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str = "single",
    *,
    policy: Optional[ShardingPolicy] = None,
    save: bool = True,
    verbose: bool = True,
    tag: str = "",
    overrides: Optional[Dict] = None,
) -> Dict:
    """Lower + compile one cell; return the artifact record.

    ``overrides`` patches ModelConfig fields (perf variants: e.g.
    {"kv_cache_dtype": "int8"} or {"param_dtype": "bfloat16"}).
    """
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        record = {
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "status": "skipped",
            "reason": "long_500k requires sub-quadratic attention "
                      "(see DESIGN.md §Arch-applicability)",
        }
        if save:
            _save(record, tag)
        return record

    if shape.kind in ("prefill", "decode"):
        # Serving runs bf16 weights (training keeps fp32 masters).
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    policy = (policy or ShardingPolicy()).for_mesh(mesh)
    model = Model(cfg)
    t0 = time.time()

    try:
        act_tp = None if policy.tp_scope == "vocab" else policy.tp_axis
        with mesh, activation_sharding(mesh, policy.dp_axes, act_tp,
                                       vocab_axis=policy.tp_axis):
            if shape.kind == "train":
                lowered = _lower_train(cfg, model, shape, mesh, policy)
            elif shape.kind == "prefill":
                lowered = _lower_prefill(cfg, model, shape, mesh, policy)
            else:
                lowered = _lower_decode(cfg, model, shape, mesh, policy)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        cost = normalize_cost(compiled.cost_analysis())
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        terms = roofline_terms(
            cost=cost,
            hlo_text=hlo,
            n_chips=mesh.size,
            model_flops_total=model_flops(cfg, shape),
            model_bytes_min=model_bytes_min(cfg, shape, mesh.size),
        )
        mem_bytes = (
            mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes
        )
        # The CPU backend upcasts bf16 dot operands to f32 and its
        # while-loop widening pass then keeps whole bf16 loop carries (KV
        # caches, activations) as f32 temporaries — a 2× inflation that
        # does not exist in the TPU lowering. `modeled` discounts the temp
        # segment accordingly (documented in EXPERIMENTS.md §Dry-run).
        mem_bytes_modeled = (
            mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes // 2
            - mem.alias_size_in_bytes
        )
        record = {
            "arch": arch,
            "shape": shape_name,
            "mesh": mesh_kind,
            "status": "ok",
            "n_chips": mesh.size,
            "param_count": cfg.param_count(),
            "active_param_count": cfg.active_param_count(),
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "per_device_bytes": mem_bytes,
                "per_device_gib": round(mem_bytes / 2**30, 3),
                "per_device_gib_modeled": round(mem_bytes_modeled / 2**30, 3),
                "fits_hbm": bool(mem_bytes_modeled <= 16 * 2**30),
            },
            "roofline": terms.to_json(),
        }
        if save:
            # Persist the optimized HLO (zstd) so rooflines can be
            # re-derived offline without recompiling.
            import zstandard

            ARTIFACTS.mkdir(parents=True, exist_ok=True)
            suffix = f"__{tag}" if tag else ""
            hlo_path = ARTIFACTS / (
                f"{arch}__{shape_name}__{mesh_kind}{suffix}.hlo.zst"
            )
            hlo_path.write_bytes(
                zstandard.ZstdCompressor(level=3).compress(hlo.encode())
            )
    except Exception as e:  # noqa: BLE001 — record the failure, don't crash --all
        record = {
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-2000:],
        }

    if verbose:
        _print_record(record)
    if save:
        _save(record, tag)
    return record


# ---------------------------------------------------------------------------
# Per-kind lowering
# ---------------------------------------------------------------------------


#: CLI-level optimizer overrides for perf variants.
_OPT_OVERRIDES: Dict = {"master_weights": False, "moment_dtype": "f32"}


def _lower_train(cfg, model, shape: ShapeSpec, mesh, policy):
    opt_cfg = AdamWConfig(
        master_weights=_OPT_OVERRIDES.get("master_weights", False),
        moment_dtype=_OPT_OVERRIDES.get("moment_dtype", "f32"),
    )
    step_fn = make_train_step(cfg, opt_cfg)
    state = abstract_train_state(cfg, opt_cfg=opt_cfg)
    state_sh = train_state_shardings(cfg, policy, mesh, state)
    batch = model.input_specs(shape)
    batch_sh = batch_shardings(cfg, policy, mesh, shape, batch)
    return jax.jit(
        step_fn,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    ).lower(state, batch)


def _lower_prefill(cfg, model, shape: ShapeSpec, mesh, policy):
    step_fn = make_prefill_step(cfg)
    params = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    params_sh = _params_shardings(cfg, policy, mesh, params)
    batch = model.input_specs(shape)
    batch_sh = batch_shardings(cfg, policy, mesh, shape, batch)
    cache = model.cache_specs(shape)
    cache_sh = cache_shardings(cfg, policy, mesh, cache)
    return jax.jit(
        step_fn,
        in_shardings=(params_sh, batch_sh, cache_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(2,),
    ).lower(params, batch, cache)


def _lower_decode(cfg, model, shape: ShapeSpec, mesh, policy):
    step_fn = make_decode_step(cfg)
    params = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    params_sh = _params_shardings(cfg, policy, mesh, params)
    inputs = model.input_specs(shape)
    inputs_sh = batch_shardings(cfg, policy, mesh, shape, inputs)
    cache = model.cache_specs(shape)
    cache_sh = cache_shardings(cfg, policy, mesh, cache)
    return jax.jit(
        step_fn,
        in_shardings=(params_sh, cache_sh, inputs_sh["token"], inputs_sh["position"]),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,),
    ).lower(params, cache, inputs["token"], inputs["position"])


def _params_shardings(cfg, policy, mesh, params):
    from repro.sharding.specs import param_shardings

    return param_shardings(cfg, policy, mesh, params)


# ---------------------------------------------------------------------------
# Reporting / CLI
# ---------------------------------------------------------------------------


def _print_record(r: Dict) -> None:
    if r["status"] == "ok":
        m = r["memory"]
        t = r["roofline"]
        print(
            f"[ok] {r['arch']:>22} {r['shape']:<12} {r['mesh']:<6} "
            f"mem/dev={m['per_device_gib']:7.3f}GiB "
            f"(tpu~{m['per_device_gib_modeled']:.2f}) fits={m['fits_hbm']} "
            f"compute={t['compute_s']:.4f}s memory={t['memory_s']:.4f}s "
            f"coll={t['collective_s']:.4f}s dom={t['dominant']:<10} "
            f"frac={t['roofline_fraction']:.3f} "
            f"(lower {r['lower_s']}s compile {r['compile_s']}s)",
            flush=True,
        )
    elif r["status"] == "skipped":
        print(f"[skip] {r['arch']:>22} {r['shape']:<12} {r['mesh']:<6} — {r['reason']}",
              flush=True)
    else:
        print(f"[ERR] {r['arch']:>22} {r['shape']:<12} {r['mesh']:<6} — {r['error']}",
              flush=True)


def _save(record: Dict, tag: str = "") -> None:
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}{suffix}.json"
    (ARTIFACTS / name).write_text(json.dumps(record, indent=2))


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--arch", default=None, help="architecture id")
    parser.add_argument("--shape", default=None, choices=list(SHAPES))
    parser.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    parser.add_argument("--all", action="store_true", help="run every cell")
    parser.add_argument("--tag", default="", help="artifact suffix (perf variants)")
    parser.add_argument("--no-save", action="store_true")
    parser.add_argument("--kv-int8", action="store_true",
                        help="int8-quantised KV cache (perf variant)")
    parser.add_argument("--no-tp", action="store_true",
                        help="pure DP/FSDP policy (model axis joins data)")
    parser.add_argument("--fsdp-all", action="store_true",
                        help="FSDP params regardless of model size")
    parser.add_argument("--tp-vocab", action="store_true",
                        help="TP only for vocab (embed table + CE logits)")
    parser.add_argument("--bf16-params", action="store_true",
                        help="bf16 params + f32 master weights (train)")
    parser.add_argument("--moment-int8", action="store_true",
                        help="int8-quantised AdamW moments")
    args = parser.parse_args()

    overrides: Dict = {}
    if args.kv_int8:
        overrides["kv_cache_dtype"] = "int8"
    if args.bf16_params:
        overrides["param_dtype"] = "bfloat16"
    policy = ShardingPolicy(
        tp_enabled=not args.no_tp,
        fsdp_min_params=0 if args.fsdp_all else 2_000_000_000,
        tp_scope="vocab" if args.tp_vocab else "full",
    )
    _OPT_OVERRIDES["master_weights"] = args.bf16_params
    _OPT_OVERRIDES["moment_dtype"] = "int8" if args.moment_int8 else "f32"

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]

    failures = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                rec = dryrun_cell(
                    arch, shape, mesh_kind, save=not args.no_save,
                    tag=args.tag, policy=policy, overrides=overrides or None,
                )
                if rec["status"] == "error":
                    failures += 1
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
