"""Jit-compilable train / prefill / decode steps with explicit shardings.

These are the programs the dry-run lowers and the runtime executes; the
sharding policy decides in/out shardings, GSPMD the rest.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.api import Model
from repro.models.config import ModelConfig
from repro.optim.adamw import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
)
from repro.sharding.specs import (
    ShardingPolicy,
    param_shardings,
)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def make_train_step(
    cfg: ModelConfig, opt_cfg: AdamWConfig
) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    model = Model(cfg)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        (loss, parts), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True
        )(state.params)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, state.opt, state.params
        )
        metrics = {"loss": loss, **parts, **opt_metrics}
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    model = Model(cfg)

    def prefill_step(params, batch: Dict, cache):
        return model.prefill(params, batch, cache)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    model = Model(cfg)

    def decode_step(params, cache, token, position):
        return model.decode(params, cache, token, position)

    return decode_step


# ---------------------------------------------------------------------------
# Sharded state construction
# ---------------------------------------------------------------------------


def abstract_train_state(
    cfg: ModelConfig, rng=None, opt_cfg: Optional[AdamWConfig] = None
) -> TrainState:
    """Shape-only TrainState (no allocation) for lowering."""
    model = Model(cfg)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    params = jax.eval_shape(model.init_params, rng)
    opt = jax.eval_shape(
        functools.partial(adamw_init, opt_cfg or AdamWConfig()), params
    )
    return TrainState(params=params, opt=opt)


def train_state_shardings(
    cfg: ModelConfig, policy: ShardingPolicy, mesh: Mesh, state: TrainState
) -> TrainState:
    from repro.sharding.specs import param_spec, sanitize_spec

    p_sh = param_shardings(cfg, policy, mesh, state.params)

    def moment_shardings(tree):
        """Moments inherit the mirrored param's spec; int8 moments are
        {"q": param-shaped int8, "scale": param-shape[:-1]+(1,)}."""

        def visit(path, leaf):
            names = tuple(
                str(getattr(k, "key", getattr(k, "idx", k))) for k in path
            )
            if names and names[-1] in ("q", "scale"):
                parent = names[:-1]
                base = param_spec(cfg, policy, mesh, parent, tuple(leaf.shape))
                if names[-1] == "scale":
                    entries = list(base)[: len(leaf.shape) - 1] + [None]
                    base = sanitize_spec(P(*entries), tuple(leaf.shape), mesh)
                return NamedSharding(mesh, base)
            spec = param_spec(cfg, policy, mesh, names, tuple(leaf.shape))
            return NamedSharding(mesh, spec)

        return jax.tree_util.tree_map_with_path(visit, tree)

    m_sh = moment_shardings(state.opt.m)
    v_sh = moment_shardings(state.opt.v)
    master_sh = (
        param_shardings(cfg, policy, mesh, state.opt.master)
        if state.opt.master is not None else None
    )
    step_sh = NamedSharding(mesh, P())
    return TrainState(
        params=p_sh,
        opt=AdamWState(step=step_sh, m=m_sh, v=v_sh, master=master_sh),
    )


def init_sharded_train_state(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    mesh: Mesh,
    policy: ShardingPolicy,
    rng: jax.Array,
) -> TrainState:
    """Materialise a TrainState directly into its shardings (no host copy)."""
    model = Model(cfg)
    abstract = abstract_train_state(cfg, rng)
    shardings = train_state_shardings(cfg, policy, mesh, abstract)

    @functools.partial(jax.jit, out_shardings=shardings)
    def build(rng):
        params = model.init_params(rng)
        return TrainState(params=params, opt=adamw_init(opt_cfg, params))

    with mesh:
        return build(rng)
