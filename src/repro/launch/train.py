"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it runs reduced configs end-to-end; pointed at a
TPU fleet the same entry point builds the production mesh, shards the
state per the policy, and runs the fault-tolerant loop.
"""
from __future__ import annotations

import argparse
import tempfile

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.launch.steps import TrainState, make_train_step
from repro.models import Model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime.train_loop import TrainLoopConfig, run_training


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True,
                    help=f"one of {ARCH_IDS} (aliases accepted)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU scale)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--moment-dtype", choices=["f32", "int8"], default="f32")
    ap.add_argument("--grad-compression", choices=["none", "int8"],
                    default="none")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    opt_cfg = AdamWConfig(
        lr=args.lr, warmup_steps=max(5, args.steps // 20),
        total_steps=args.steps,
        moment_dtype=args.moment_dtype,
        compression=None if args.grad_compression == "none" else "int8",
    )

    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.2f}M params "
          f"({'smoke' if args.smoke else 'full'} config)")

    state = TrainState(params=params, opt=adamw_init(opt_cfg, params))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0,))
    pipeline = SyntheticTokens(
        DataConfig(
            vocab_size=cfg.vocab_size, global_batch=args.batch,
            seq_len=args.seq,
            frames_dim=cfg.d_model if cfg.family == "encdec" else 0,
        )
    )
    ckpt = Checkpointer(
        args.ckpt_dir or tempfile.mkdtemp(prefix=f"{args.arch}_ckpt_")
    )

    report = run_training(
        step_fn=step_fn, state=state, pipeline=pipeline, checkpointer=ckpt,
        config=TrainLoopConfig(
            total_steps=args.steps,
            checkpoint_every=max(10, args.steps // 4),
            log_every=max(1, args.steps // 10),
        ),
        on_metrics=lambda s, m: print(
            f"step {s:>5} loss {float(m['loss']):.4f} "
            f"({m['step_time_s']*1e3:.0f} ms)"
        ),
    )
    print(f"done: loss {report.losses[0]:.4f} → {report.losses[-1]:.4f}; "
          f"restarts={report.restarts}")


if __name__ == "__main__":
    main()
