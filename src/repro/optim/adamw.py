"""AdamW with decoupled weight decay, global-norm clipping, schedules, and
optional gradient compression — pure JAX, optax-style (init/update) but
self-contained.

State is a pytree with the same structure (and sharding) as the params:
`m` and `v` inherit each parameter's NamedSharding, so optimizer state is
automatically FSDP-sharded wherever params are.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array     # int32 scalar
    m: Any              # first moment  (params-like; f32 or int8+scale)
    v: Any              # second moment (params-like; f32 or int8+scale)
    master: Any = None  # f32 master weights (when params are bf16)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"      # cosine | linear | constant
    # Gradient compression (see repro.optim.compression): None | "int8"
    compression: Optional[str] = None
    # Moment storage: "f32" | "int8" (blockwise-quantised, bitsandbytes-style
    # 8-bit Adam — required to fit 300B+ AdamW states on a 256-chip pod:
    # fp32 p+m+v+g = 16 B/param = 25 GB/chip for jamba-398B vs 16 GB HBM;
    # int8 moments bring it to ~8.3 B/param).
    moment_dtype: str = "f32"
    # Keep f32 master weights when the model params are bf16. Gradients are
    # then bf16 end-to-end — the data-parallel all-reduce moves HALF the
    # wire bytes (the §Perf "bf16 grad reduction" lever).
    master_weights: bool = False


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, cfg.warmup_steps))
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip(
            (step - cfg.warmup_steps)
            / max(1, cfg.total_steps - cfg.warmup_steps),
            0.0, 1.0,
        )
        if cfg.schedule == "cosine":
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        else:  # linear
            decay = 1.0 - frac
        decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * decay
    return cfg.lr * warm * decay


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


# --- int8 moment quantisation -------------------------------------------------
# Row-wise (last-axis absmax) and SHAPE-PRESERVING: `q` mirrors the param's
# shape, so moments inherit the param's sharding spec verbatim; `scale`
# drops the last axis. (bitsandbytes uses 256-blocks; row-wise is the
# sharding-friendly equivalent at our row sizes.)


def _q8_zeros(p: jax.Array) -> Dict[str, jax.Array]:
    return {
        "q": jnp.zeros(p.shape, jnp.int8),
        "scale": jnp.zeros(p.shape[:-1] + (1,), jnp.float32),
    }


def _q8_encode(x: jax.Array) -> Dict[str, jax.Array]:
    x = x.astype(jnp.float32)
    scale = jnp.maximum(
        jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0, 1e-20
    )
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale}


def _q8_decode(enc: Dict[str, jax.Array], like: jax.Array) -> jax.Array:
    return enc["q"].astype(jnp.float32) * enc["scale"]


def adamw_init(cfg: AdamWConfig, params: Any) -> AdamWState:
    if cfg.moment_dtype == "int8":
        m = jax.tree.map(_q8_zeros, params)
        v = jax.tree.map(_q8_zeros, params)
    else:
        m = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        v = jax.tree.map(jnp.copy, m)
    master = None
    if cfg.master_weights:
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=m, v=v, master=master)


def adamw_update(
    cfg: AdamWConfig,
    grads: Any,
    state: AdamWState,
    params: Any,
) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    if cfg.compression == "int8":
        from repro.optim.compression import int8_roundtrip

        grads = int8_roundtrip(grads)

    grads, grad_norm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_at(cfg, state.step)
    b1, b2 = cfg.b1, cfg.b2

    int8_moments = cfg.moment_dtype == "int8"
    use_master = cfg.master_weights and state.master is not None

    def upd(p, g, m, v, mw):
        g = g.astype(jnp.float32)
        if int8_moments:
            m = _q8_decode(m, p)
            v = _q8_decode(v, p)
        ref = mw if use_master else p.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        m_hat = m_new / (1 - b1 ** step.astype(jnp.float32))
        v_hat = v_new / (1 - b2 ** step.astype(jnp.float32))
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        delta = delta + cfg.weight_decay * ref
        ref_new = ref - lr * delta
        if int8_moments:
            m_new = _q8_encode(m_new)
            v_new = _q8_encode(v_new)
        return ref_new.astype(p.dtype), m_new, v_new, (
            ref_new if use_master else None
        )

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_mw = (
        treedef.flatten_up_to(state.master) if use_master
        else [None] * len(flat_p)
    )
    out = [upd(*args) for args in zip(flat_p, flat_g, flat_m, flat_v, flat_mw)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_master = (
        treedef.unflatten([o[3] for o in out]) if use_master else state.master
    )
    metrics = {"grad_norm": grad_norm, "lr": lr}
    return new_params, AdamWState(step=step, m=new_m, v=new_v,
                                  master=new_master), metrics
