"""Gradient compression for cross-pod data parallelism.

At 512+ chips the "pod" axis all-reduce crosses DCN (~25 GB/s per pod vs
~100 GB/s/chip aggregate ICI), so gradients are the dominant inter-pod
traffic. Two tools:

* :func:`int8_roundtrip` — blockwise-scaled int8 quantisation applied to
  gradients *before* the (GSPMD-inserted) all-reduce consumes them. In a
  jit'd train step XLA fuses the quantise→dequantise pair around the
  collective's operand, which models transmitting int8 payloads (4× fewer
  DCN bytes). Error feedback is unnecessary at int8 for AdamW in practice,
  but an EF variant is provided for experimentation.

* :class:`ErrorFeedback` — residual accumulation for more aggressive
  (e.g. top-k) schemes: the compression error is added back to the next
  step's gradient, preserving convergence (Stich et al.).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

_BLOCK = 2048


def _quant_leaf(g: jax.Array) -> jax.Array:
    orig_shape = g.shape
    orig_dtype = g.dtype
    flat = g.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    out = deq.reshape(-1)[:n].reshape(orig_shape)
    return out.astype(orig_dtype)


def int8_roundtrip(grads: Any) -> Any:
    """Blockwise int8 quantise→dequantise every gradient leaf."""
    return jax.tree.map(_quant_leaf, grads)


class ErrorFeedback(NamedTuple):
    residual: Any


def ef_init(params: Any) -> ErrorFeedback:
    return ErrorFeedback(
        residual=jax.tree.map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params
        )
    )


def ef_compress(
    grads: Any, state: ErrorFeedback
) -> Tuple[Any, ErrorFeedback]:
    """int8 with error feedback: g' = Q(g + r); r ← (g + r) − g'."""
    corrected = jax.tree.map(
        lambda g, r: g.astype(jnp.float32) + r, grads, state.residual
    )
    compressed = jax.tree.map(_quant_leaf, corrected)
    residual = jax.tree.map(lambda c, q: c - q, corrected, compressed)
    return compressed, ErrorFeedback(residual=residual)
