"""Three-term roofline analysis from compiled dry-run artifacts.

Terms (seconds, per training/serving step, per chip):

  compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device / HBM_bandwidth
  collective = modeled wire-bytes per chip / ICI bandwidth per chip

``cost_analysis()`` of the SPMD-partitioned executable reports *per-device*
FLOPs and bytes. Collective wire bytes are parsed from the optimized HLO
(``compiled.as_text()``): every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction contributes its result-shape
bytes scaled by the standard ring-algorithm factor for its group size
(AG: (n−1)/n, AR: 2(n−1)/n, RS: (n−1)·result≈(n−1)/n·input, A2A: (n−1)/n,
CP: 1).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link
ICI; ring collectives along one mesh axis drive 2 links per chip
⇒ 100 GB/s effective per-chip collective bandwidth (documented with each
table).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

# --- TPU v5e -----------------------------------------------------------------
PEAK_FLOPS = 197e12           # bf16 per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_LINK_BW = 50e9            # bytes/s per link
ICI_BW_PER_CHIP = 2 * ICI_LINK_BW   # ring along one mesh axis: 2 links
DCN_BW_PER_POD = 25e9         # cross-pod (multi-pod dry-run context only)
HBM_BYTES = 16 * 1024**3      # v5e HBM capacity

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

#: Ring-algorithm wire factors applied to the *result* shape bytes.
_WIRE_FACTOR = {
    "all-gather": lambda n: (n - 1) / n,
    "all-reduce": lambda n: 2 * (n - 1) / n,
    "reduce-scatter": lambda n: float(n - 1),
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    wire_bytes: float
    line: str


@dataclasses.dataclass
class CollectiveSummary:
    ops: List[CollectiveOp]

    @property
    def total_result_bytes(self) -> int:
        return sum(o.result_bytes for o in self.ops)

    @property
    def total_wire_bytes(self) -> float:
        return sum(o.wire_bytes for o in self.ops)

    def by_kind(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for o in self.ops:
            d = out.setdefault(o.kind, {"count": 0, "bytes": 0, "wire_bytes": 0.0})
            d["count"] += 1
            d["bytes"] += o.result_bytes
            d["wire_bytes"] += o.wire_bytes
        return out


def _shape_bytes(text: str) -> int:
    """Sum of all TYPE[dims] array sizes appearing in a (tuple) shape str."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        size = _DTYPE_BYTES[dtype]
        if dims:
            for d in dims.split(","):
                size *= int(d)
        total += size
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]<=...
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def parse_collectives(hlo_text: str, default_group: int = 2) -> CollectiveSummary:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(
            r"=\s+((?:\([^)]*\))|(?:\w+\[[0-9,]*\][^ ]*))\s+"
            r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
            r"(-start)?\(",
            stripped,
        )
        if not m:
            continue
        if re.search(r"(all-reduce|all-gather|all-to-all|collective-permute|reduce-scatter)-done\(", stripped):
            continue
        shape_text, kind = m.group(1), m.group(2)
        result_bytes = _shape_bytes(shape_text)
        n = _group_size(stripped, default_group)
        wire = _WIRE_FACTOR[kind](max(2, n)) * result_bytes
        ops.append(
            CollectiveOp(
                kind=kind, result_bytes=result_bytes, group_size=n,
                wire_bytes=wire, line=stripped[:160],
            )
        )
    return CollectiveSummary(ops=ops)


# ---------------------------------------------------------------------------
# Terms
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    model_flops_total: float
    model_bytes_min: float          # unavoidable per-device HBM bytes/step
    n_chips: int
    collective_detail: Dict[str, Dict[str, float]]

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def model_flops_per_device(self) -> float:
        return self.model_flops_total / self.n_chips

    @property
    def useful_compute_s(self) -> float:
        """Time the *model* FLOPs alone would take at peak."""
        return self.model_flops_per_device / PEAK_FLOPS

    @property
    def ideal_s(self) -> float:
        """Best achievable step time: model FLOPs at peak OR the
        unavoidable HBM traffic (params+cache once), whichever binds.
        Decode steps are bytes-bound by nature — without this floor the
        roofline fraction of every decode cell would be ~0 by definition."""
        return max(self.useful_compute_s, self.model_bytes_min / HBM_BW)

    @property
    def flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per device) — compiled-compute usefulness."""
        if self.flops_per_device <= 0:
            return 0.0
        return self.model_flops_per_device / self.flops_per_device

    @property
    def roofline_fraction(self) -> float:
        """ideal-time / bound-time — the §Perf score for this cell."""
        if self.bound_s <= 0:
            return 0.0
        return min(1.0, self.ideal_s / self.bound_s)

    def to_json(self) -> Dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "model_flops_total": self.model_flops_total,
            "model_flops_per_device": self.model_flops_per_device,
            "model_bytes_min": self.model_bytes_min,
            "ideal_s": self.ideal_s,
            "flops_ratio": self.flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "n_chips": self.n_chips,
            "collectives": self.collective_detail,
        }


_INSTR_RE = re.compile(r"%(\S+?) = (\S+?) ")
_DOT_RE = re.compile(
    r"%\S+ = (\w+)\[([0-9,]*)\]\S* dot\(%(\S+?), %(\S+?)\),.*?"
    r"lhs_contracting_dims=\{([0-9,]*)\}"
)


def parse_dot_flops(hlo_text: str) -> float:
    """Per-device matmul FLOPs parsed from the optimized HLO.

    ``cost_analysis()['flops']`` systematically undercounts on the CPU
    pipeline (fusion accounting), so the compute roofline term uses
    ``max(cost_flops, dot_flops)``. For each ``dot``:
    flops = 2 · prod(result dims) · prod(lhs contracting dim sizes).
    """
    # Shape table: instruction name → dims.
    shapes: Dict[str, Tuple[int, ...]] = {}
    for m in re.finditer(r"%(\S+?) = \w+\[([0-9,]*)\]", hlo_text):
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        shapes[m.group(1)] = dims
    total = 0.0
    for m in _DOT_RE.finditer(hlo_text):
        _, result_dims, lhs, _rhs, contracting = m.groups()
        rdims = [int(d) for d in result_dims.split(",") if d]
        lhs_shape = shapes.get(lhs)
        if lhs_shape is None:
            continue
        k = 1
        for c in contracting.split(","):
            if c and int(c) < len(lhs_shape):
                k *= lhs_shape[int(c)]
        out = 1
        for d in rdims:
            out *= d
        total += 2.0 * out * k
    return total


def normalize_cost(cost) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions.

    Older JAX returns a one-element list of per-computation dicts; newer
    JAX returns the dict directly. Empty/None becomes an empty dict.
    """
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return cost


def roofline_terms(
    *,
    cost: Dict[str, float],
    hlo_text: str,
    n_chips: int,
    model_flops_total: float,
    model_bytes_min: float = 0.0,
) -> RooflineTerms:
    from repro.roofline.hlo import analyze_hlo

    cost = normalize_cost(cost)
    hc = analyze_hlo(hlo_text)
    # Trip-count-aware parsed costs vs cost_analysis (which counts loop
    # bodies once): take the max of each.
    flops = max(float(cost.get("flops", 0.0)), hc.dot_flops)
    bytes_accessed = max(
        float(cost.get("bytes accessed", 0.0)), hc.write_bytes
    )
    wire = hc.collective_wire_bytes
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_accessed / HBM_BW,
        collective_s=wire / ICI_BW_PER_CHIP,
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        wire_bytes_per_device=wire,
        model_flops_total=model_flops_total,
        model_bytes_min=model_bytes_min,
        n_chips=n_chips,
        collective_detail=hc.collective_detail,
    )


def model_bytes_min(cfg, shape, n_chips: int) -> float:
    """Unavoidable per-device HBM bytes per step (roofline ideal floor).

    decode: read active params (bf16) + the full KV/SSM cache once;
    prefill: params + write the cache;
    train: read params + opt state, write params + opt state (fp32 AdamW).
    Activation traffic is excluded (it is the optimisable part).
    """
    n_active = cfg.active_param_count()
    cache = _cache_bytes(cfg, shape)
    if shape.kind == "decode":
        total = 2.0 * n_active + cache
    elif shape.kind == "prefill":
        total = 2.0 * n_active + cache
    else:  # train: p,m,v read+write in fp32 + grads
        total = (4.0 * 2 + 4.0 * 2 + 4.0 * 2 + 4.0) * cfg.param_count()
    return total / n_chips


def _cache_bytes(cfg, shape) -> float:
    """Total KV/SSM cache bytes for this shape (bf16 KV, f32 SSM state)."""
    b, t = shape.global_batch, shape.seq_len
    total = 0.0
    pattern = cfg.layer_pattern()
    per_period_attn = sum(1 for m, _ in pattern if m == "attn")
    per_period_mamba = sum(1 for m, _ in pattern if m == "mamba")
    n_attn = cfg.n_periods * per_period_attn
    n_mamba = cfg.n_periods * per_period_mamba
    if cfg.family == "encdec":
        n_attn = cfg.n_layers * 2  # self + cross
    if n_attn:
        total += n_attn * b * t * cfg.n_kv_heads * cfg.head_dim * 2 * 2
    if n_mamba:
        total += n_mamba * b * (
            cfg.ssm_nheads * cfg.ssm_headdim * cfg.ssm_state * 4
            + (cfg.ssm_conv - 1) * (cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state) * 4
        )
    return total


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (serve fwd)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence.
    return 2.0 * n_active * shape.global_batch
