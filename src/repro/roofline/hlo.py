"""Trip-count-aware HLO cost model.

XLA's ``cost_analysis()`` (and any naive text scan) counts a while-loop
body ONCE — but our models scan over layers, so the dominant dots and the
FSDP all-gathers live inside a loop executed ``n_periods`` times. This
module parses the optimized HLO into its computations, builds the
call-graph multipliers (while ``body=%region`` × ``known_trip_count``,
fusion ``calls=`` × 1), and then accounts:

  * matmul FLOPs      — 2 · prod(result) · prod(contracting dims), × trips;
  * HBM bytes         — result bytes of every materialising instruction
                        (entry + loop regions; fusion internals excluded),
                        × trips — a write-once proxy for buffer traffic;
  * collective bytes  — per-op result bytes × ring wire factor, × trips.

All quantities are per-device (the HLO is the SPMD-partitioned module).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COMP_START = re.compile(r"^(?:ENTRY )?%([\w\.\-]+) \(.*\) -> .* \{$")
_WHILE_RE = re.compile(
    r"while\(.*?condition=%([\w\.\-]+), body=%([\w\.\-]+)"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%([\w\.\-]+)")
_DOT_RE = re.compile(
    r"= (\w+)\[([0-9,]*)\]\S* dot\(%([\w\.\-]+), %([\w\.\-]+)\)(.*)$"
)
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_RESULT_RE = re.compile(r"= (?:\()?(\w+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^(?:ROOT )?%([\w\.\-]+) =")
_COLL_RE = re.compile(
    r"= ((?:\([^)]*\))|(?:\w+\[[0-9,]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_GROUPS_PAIR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_WIRE_FACTOR = {
    "all-gather": lambda n: (n - 1) / n,
    "all-reduce": lambda n: 2 * (n - 1) / n,
    "reduce-scatter": lambda n: float(n - 1),
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in re.findall(r"(\w+)\[([0-9,]*)\]", text):
        if dtype not in _DTYPE_BYTES:
            continue
        size = _DTYPE_BYTES[dtype]
        for d in dims.split(","):
            if d:
                size *= int(d)
        total += size
    return total


@dataclasses.dataclass
class Computation:
    name: str
    lines: List[str]
    called_as_fusion: bool = False


@dataclasses.dataclass
class HloCost:
    dot_flops: float
    write_bytes: float
    collective_wire_bytes: float
    collective_detail: Dict[str, Dict[str, float]]


def _split_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        m = _COMP_START.match(line.strip()) if stripped.endswith("{") else None
        if m and not line.startswith(" "):
            current = Computation(name=m.group(1), lines=[])
            comps[current.name] = current
            continue
        if stripped == "}":
            current = None
            continue
        if current is not None and stripped:
            current.lines.append(stripped)
    return comps


def analyze_hlo(text: str) -> HloCost:
    comps = _split_computations(text)

    # Call graph: (caller, callee, multiplier).
    multipliers: Dict[str, float] = {}
    for name, comp in comps.items():
        for line in comp.lines:
            for callee in _CALLS_RE.findall(line):
                if callee in comps:
                    comps[callee].called_as_fusion = True
    # Entry = the computation never referenced as while body/cond or fusion.
    referenced = set()
    edges: List[Tuple[str, str, float]] = []
    for name, comp in comps.items():
        for line in comp.lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                tm = _TRIP_RE.search(line)
                trips = float(tm.group(1)) if tm else 1.0
                for callee in (cond, body):
                    if callee in comps:
                        edges.append((name, callee, trips))
                        referenced.add(callee)
            for callee in _CALLS_RE.findall(line):
                if callee in comps:
                    edges.append((name, callee, 1.0))
                    referenced.add(callee)
    roots = [n for n in comps if n not in referenced]

    # Propagate multipliers from roots (DAG; cycles impossible in HLO).
    mult: Dict[str, float] = {n: 0.0 for n in comps}
    for r in roots:
        mult[r] = max(mult[r], 1.0)
    changed = True
    iters = 0
    while changed and iters < 200:
        changed = False
        iters += 1
        for caller, callee, k in edges:
            new = mult.get(caller, 0.0) * k
            if new > mult.get(callee, 0.0):
                mult[callee] = new
                changed = True

    dot_flops = 0.0
    write_bytes = 0.0
    wire = 0.0
    detail: Dict[str, Dict[str, float]] = {}

    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        materializes = not comp.called_as_fusion
        for line in comp.lines:
            dm = _DOT_RE.search(line)
            if dm:
                _dt, rdims, lhs, _rhs, rest = dm.groups()
                out = 1
                for d in rdims.split(","):
                    if d:
                        out *= int(d)
                k = 1
                cm = _LHS_CONTRACT_RE.search(rest)
                lhs_shape = _find_shape(comp, comps, lhs)
                if cm and lhs_shape is not None:
                    for c in cm.group(1).split(","):
                        if c and int(c) < len(lhs_shape):
                            k *= lhs_shape[int(c)]
                dot_flops += m * 2.0 * out * k

            if materializes:
                rm = _RESULT_RE.search(line)
                if rm:
                    write_bytes += m * _shape_bytes(line.split(" = ", 1)[1].split("(", 1)[0])

            cm2 = _COLL_RE.search(line)
            if cm2 and "-done(" not in line:
                shape_text, kind = cm2.group(1), cm2.group(2)
                rb = _shape_bytes(shape_text)
                n = _group_size(line)
                w = _WIRE_FACTOR[kind](max(2, n)) * rb
                wire += m * w
                d = detail.setdefault(
                    kind, {"count": 0, "bytes": 0.0, "wire_bytes": 0.0}
                )
                d["count"] += m
                d["bytes"] += m * rb
                d["wire_bytes"] += m * w

    return HloCost(
        dot_flops=dot_flops,
        write_bytes=write_bytes,
        collective_wire_bytes=wire,
        collective_detail=detail,
    )


def _find_shape(
    comp: Computation, comps: Dict[str, Computation], name: str
) -> Optional[Tuple[int, ...]]:
    # Look for the defining line in the same computation first.
    for line in comp.lines:
        nm = _NAME_RE.match(line)
        if nm and nm.group(1) == name:
            rm = re.search(r"= (\w+)\[([0-9,]*)\]", line)
            if rm:
                return tuple(int(d) for d in rm.group(2).split(",") if d)
    # Parameters: "%param_0.1 = f32[..] parameter(0)" also matches above.
    return None


def _group_size(line: str, default: int = 2) -> int:
    m = _GROUPS_PAIR_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default
