"""Fault-tolerant training loop.

Responsibilities beyond "call train_step in a loop":
  * checkpoint/restart — resumes from the latest committed checkpoint,
    replaying the step-indexed data pipeline from the same step;
  * periodic + async checkpointing (the step keeps running during I/O);
  * failure handling — a step that dies (device error, preemption
    simulation via `inject_failure_at`) triggers restore-and-continue
    instead of job loss;
  * loss-spike guard — NaN/Inf metrics roll back to the last checkpoint
    and skip the offending data batch (a standard large-run safeguard);
  * straggler observability — per-step wall times feed an EMA; steps
    slower than ``straggler_factor``× the EMA are counted and surfaced
    (on a real fleet this signal feeds the tAPP ``capacity_used``
    invalidation for the affected hosts — the paper's control plane is
    the mitigation mechanism).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import SyntheticTokens, make_global_batch


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_async: bool = True
    log_every: int = 10
    straggler_factor: float = 3.0
    max_restarts: int = 3
    # test hook: raise at this step (once) to exercise restart
    inject_failure_at: Optional[int] = None


@dataclasses.dataclass
class TrainReport:
    steps_run: int
    final_step: int
    restarts: int
    rollbacks: int
    straggler_steps: int
    losses: List[float]
    step_times: List[float]


def run_training(
    *,
    step_fn: Callable,                 # (state, batch) -> (state, metrics)
    state: Any,
    pipeline: SyntheticTokens,
    checkpointer: Checkpointer,
    config: TrainLoopConfig,
    batch_shardings: Optional[Dict] = None,
    state_shardings: Optional[Any] = None,
    on_metrics: Optional[Callable[[int, Dict], None]] = None,
) -> TrainReport:
    restarts = 0
    rollbacks = 0
    straggler_steps = 0
    losses: List[float] = []
    step_times: List[float] = []
    ema_time: Optional[float] = None
    failure_armed = config.inject_failure_at is not None

    # Resume if a committed checkpoint exists.
    start_step = 0
    latest = checkpointer.latest_step()
    if latest is not None:
        state, start_step, _ = checkpointer.restore(
            state, shardings=state_shardings
        )
        start_step += 1

    step = start_step
    while step < config.total_steps:
        try:
            batch = make_global_batch(pipeline, step, shardings=batch_shardings)
            if failure_armed and step == config.inject_failure_at:
                failure_armed = False
                raise RuntimeError(f"injected failure at step {step}")

            t0 = time.time()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0

            # Loss-spike / NaN guard: roll back and skip the batch.
            if not math.isfinite(loss):
                rollbacks += 1
                latest = checkpointer.latest_step()
                if latest is None or rollbacks > config.max_restarts:
                    raise RuntimeError(
                        f"non-finite loss at step {step} and no checkpoint"
                    )
                state, ck_step, _ = checkpointer.restore(
                    state, shardings=state_shardings
                )
                step = ck_step + 1
                continue

            losses.append(loss)
            step_times.append(dt)
            if ema_time is None:
                ema_time = dt
            else:
                if dt > config.straggler_factor * ema_time:
                    straggler_steps += 1
                ema_time = 0.9 * ema_time + 0.1 * dt

            if on_metrics and step % config.log_every == 0:
                on_metrics(step, {**metrics, "step_time_s": dt})

            if step % config.checkpoint_every == 0 and step > 0:
                checkpointer.save(
                    step, state, blocking=not config.checkpoint_async
                )
            step += 1

        except (RuntimeError, jax.errors.JaxRuntimeError) as e:
            restarts += 1
            if restarts > config.max_restarts:
                raise
            latest = checkpointer.latest_step()
            if latest is None:
                # No checkpoint yet: restart from scratch.
                step = 0
                continue
            state, ck_step, _ = checkpointer.restore(
                state, shardings=state_shardings
            )
            step = ck_step + 1

    checkpointer.wait()
    checkpointer.save(config.total_steps - 1, state, blocking=True)
    return TrainReport(
        steps_run=len(losses),
        final_step=step - 1,
        restarts=restarts,
        rollbacks=rollbacks,
        straggler_steps=straggler_steps,
        losses=losses,
        step_times=step_times,
    )
