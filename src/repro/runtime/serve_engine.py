"""tAPP-scheduled serving engine (continuous batching over model replicas).

The data-plane realisation of the paper's control plane:

  * a **replica** = one model hosted on a device group (a mesh slice on a
    TPU fleet; the host CPU in tests), with a fixed number of sequence
    *slots* and a slot-batched KV cache — the tAPP *worker*;
  * the **gateway** routes each request by its policy tag through the
    tAPP engine against live replica state (slots in use → capacity_used,
    health → overload, residency via worker-set labels = data locality);
  * **continuous batching**: prefill admits a sequence into a free slot;
    every engine tick runs ONE batched decode step per replica across all
    active slots (fixed batch shape → no recompilation);
  * **straggler mitigation**: tick-time EMA per replica; slow replicas
    are reported to the watcher with saturated capacity so tAPP policies
    route around them until they recover (the paper's ``invalidate``
    machinery doing data-plane duty);
  * **failure handling**: a dead replica is marked unreachable; its
    queued work is rescheduled by the same policy evaluation.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.platform import (
    FederationSpec,
    TappFederation,
    TappPlatform,
    WorkerSpec,
)
from repro.core.scheduler.controller import ControllerRuntime
from repro.core.scheduler.engine import Invocation
from repro.core.scheduler.gateway import Gateway
from repro.core.scheduler.topology import DistributionPolicy
from repro.core.scheduler.watcher import Watcher
from repro.models.api import Model
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    request_id: int
    model_id: str
    tokens: np.ndarray                  # prompt [S]
    max_new_tokens: int = 8
    tag: Optional[str] = None
    # Federation entry zone (None: the single gateway / default entry).
    entry_zone: Optional[str] = None
    # lifecycle
    state: str = "queued"               # queued | running | done | failed
    output: List[int] = dataclasses.field(default_factory=list)
    replica: Optional[str] = None
    error: Optional[str] = None
    submitted_tick: int = 0
    finished_tick: int = 0


@dataclasses.dataclass
class _SlotState:
    request: Request
    position: int                       # next cache slot to write
    last_token: int
    placement: object                   # the platform Placement ticket


class Replica:
    """One model replica with slot-batched caches."""

    def __init__(
        self,
        name: str,
        cfg: ModelConfig,
        params,
        *,
        zone: str = "default",
        sets: Sequence[str] = (),
        slots: int = 4,
        max_len: int = 128,
    ) -> None:
        self.name = name
        self.cfg = cfg
        self.model = Model(cfg)
        self.params = params
        self.zone = zone
        self.sets = frozenset(set(sets) | {cfg.name, "any"})
        self.slots = slots
        self.max_len = max_len
        self.cache = self.model.init_cache(slots, max_len, enc_len=max_len)
        self.active: Dict[int, _SlotState] = {}   # slot index -> state
        self.alive = True
        self._decode = jax.jit(self.model.decode)
        self._prefill_b1 = jax.jit(
            lambda p, b, c: self.model.prefill(p, b, c)
        )
        self.tick_times: List[float] = []

    # -- slot management -----------------------------------------------------------

    def free_slot(self) -> Optional[int]:
        for i in range(self.slots):
            if i not in self.active:
                return i
        return None

    def admit(self, request: Request, placement) -> bool:
        slot = self.free_slot()
        if slot is None or not self.alive:
            return False
        prompt = jnp.asarray(request.tokens[None, :], jnp.int32)
        small_cache = self.model.init_cache(1, self.max_len, enc_len=self.max_len)
        batch = {"tokens": prompt}
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (1, prompt.shape[1], self.cfg.d_model), jnp.float32
            )
        logits, filled = self._prefill_b1(self.params, batch, small_cache)
        # Merge the single-sequence cache into this replica's slot.
        self.cache = jax.tree.map(
            lambda big, one: big.at[:, slot].set(one[:, 0]), self.cache, filled
        )
        first_token = int(jnp.argmax(logits[0, -1]))
        self.active[slot] = _SlotState(
            request=request,
            position=len(request.tokens),
            last_token=first_token,
            placement=placement,
        )
        request.state = "running"
        request.replica = self.name
        request.output.append(first_token)
        return True

    # -- decode tick --------------------------------------------------------------------

    def step(self) -> List[Tuple[Request, object]]:
        """One batched decode step; returns finished (request, placement)."""
        if not self.active or not self.alive:
            return []
        t0 = time.time()
        tokens = np.zeros((self.slots,), np.int32)
        positions = np.zeros((self.slots,), np.int32)
        for slot, st in self.active.items():
            tokens[slot] = st.last_token
            positions[slot] = st.position
        logits, self.cache = self._decode(
            self.params, self.cache,
            jnp.asarray(tokens), jnp.asarray(positions),
        )
        next_tokens = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        finished: List[Tuple[Request, object]] = []
        for slot in list(self.active):
            st = self.active[slot]
            st.position += 1
            st.last_token = int(next_tokens[slot])
            st.request.output.append(st.last_token)
            done = (
                len(st.request.output) >= st.request.max_new_tokens
                or st.position >= self.max_len - 1
            )
            if done:
                st.request.state = "done"
                finished.append((st.request, st.placement))
                del self.active[slot]
        self.tick_times.append(time.time() - t0)
        return finished

    def fail(self) -> None:
        """Simulate a replica loss (host/ICI failure)."""
        self.alive = False

    @property
    def load_fraction(self) -> float:
        return len(self.active) / max(1, self.slots)


class ServingEngine:
    def __init__(
        self,
        *,
        distribution: DistributionPolicy = DistributionPolicy.SHARED,
        tapp_script: Optional[str] = None,
        straggler_factor: float = 4.0,
        seed: int = 0,
        federation: Optional[FederationSpec] = None,
    ) -> None:
        # A federation spec turns the engine multi-entry: one ZoneGateway
        # per declared zone, requests routed from their submit()-time
        # entry zone and forwarded per the policy's topology_tolerance.
        # Replicas/controllers still register dynamically (the spec's
        # slices may be empty — they declare the zones).
        if federation is not None:
            self.platform: "TappPlatform | TappFederation" = TappFederation(
                federation, distribution=distribution, seed=seed
            )
        else:
            self.platform = TappPlatform(distribution=distribution, seed=seed)
        self.replicas: Dict[str, Replica] = {}
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self._ids = itertools.count()
        self.tick = 0
        self.straggler_factor = straggler_factor
        self._ema: Dict[str, float] = {}
        self.stragglers_flagged = 0
        if tapp_script is not None:
            self.platform.apply_policy(tapp_script)

    # -- platform access (compat: the engine predates the façade) -------------------

    @property
    def watcher(self) -> Watcher:
        return self.platform.watcher

    @property
    def gateway(self) -> Gateway:
        """The single entrypoint — or, on a federation-backed engine, the
        default entry zone's gateway (keeps the compat surface working:
        stats, probes, prewarm all behave per-zone there)."""
        if isinstance(self.platform, TappFederation):
            return self.platform.zone_gateway(self.platform.spec.entry_zone)
        return self.platform.gateway

    @property
    def runtime(self) -> ControllerRuntime:
        return self.platform.runtime

    # -- topology -------------------------------------------------------------------

    def add_controller(self, name: str, zone: str = "default") -> None:
        self.platform.add_controller(name, zone=zone)

    def add_replica(self, replica: Replica) -> None:
        self.replicas[replica.name] = replica
        self.platform.add_worker(
            WorkerSpec(
                name=replica.name,
                zone=replica.zone,
                sets=tuple(replica.sets),
                capacity_slots=replica.slots,
                resident_models=(replica.cfg.name,),
            )
        )

    def remove_replica(self, name: str) -> None:
        """Elastic scale-down / failure eviction."""
        replica = self.replicas.get(name)
        if replica is not None:
            replica.fail()
            for st in list(replica.active.values()):
                # Retire the ticket of the lost placement; the requeued
                # request gets a fresh one when it is re-admitted.
                st.placement.complete()
                st.request.state = "queued"
                st.request.replica = None
                st.request.output.clear()
                self.queue.append(st.request)
            replica.active.clear()
        self.platform.remove_worker(name)

    # -- requests ------------------------------------------------------------------------

    def submit(
        self,
        model_id: str,
        tokens: Sequence[int],
        *,
        tag: Optional[str] = None,
        max_new_tokens: int = 8,
        entry_zone: Optional[str] = None,
    ) -> Request:
        if entry_zone is not None and not isinstance(
            self.platform, TappFederation
        ):
            raise ValueError(
                f"entry_zone={entry_zone!r} requires a federation-backed "
                f"engine (pass federation=FederationSpec.of(...))"
            )
        req = Request(
            request_id=next(self._ids),
            model_id=model_id,
            tokens=np.asarray(tokens, np.int32),
            max_new_tokens=max_new_tokens,
            tag=tag,
            entry_zone=entry_zone,
            submitted_tick=self.tick,
        )
        self.queue.append(req)
        return req

    # -- engine loop ----------------------------------------------------------------------

    def step_once(self) -> None:
        self.tick += 1
        self._heartbeats()
        self._admit_queued()
        for replica in self.replicas.values():
            finished = replica.step()
            for request, placement in finished:
                request.finished_tick = self.tick
                placement.complete()
                self.done.append(request)
        self._flag_stragglers()

    def run_until_done(self, max_ticks: int = 1000) -> None:
        for _ in range(max_ticks):
            if not self.queue and not any(
                r.active for r in self.replicas.values()
            ):
                return
            self.step_once()

    # -- internals ---------------------------------------------------------------------------

    def _heartbeats(self) -> None:
        workers = self.platform.cluster.workers
        for replica in self.replicas.values():
            if replica.name not in workers:
                continue
            self.platform.heartbeat(
                replica.name,
                healthy=replica.alive,
                reachable=replica.alive,
                capacity_used_pct=100.0 * replica.load_fraction,
            )

    def _admit_queued(self) -> None:
        if not self.queue:
            return
        still_queued: List[Request] = []
        requests = list(self.queue)
        invocations = [
            Invocation(
                function=request.model_id,
                tag=request.tag,
                model_id=request.model_id,
                request_id=request.request_id,
            )
            for request in requests
        ]
        pending = iter(requests)

        def _place(placement) -> None:
            request = next(pending)
            placed = False
            if placement.scheduled and placement.worker in self.replicas:
                replica = self.replicas[placement.worker]
                if replica.cfg.name == request.model_id:
                    placed = replica.admit(request, placement)
            if not placed:
                # Retire the unused ticket (no-op when never admitted) so
                # the running-function multiset stays truthful.
                placement.complete()
                request.state = "queued"
                still_queued.append(request)
                # Requests failed by policy (followup: fail) surface as such.
                if placement.failed_by_policy:
                    request.error = "policy-failed"

        # One unified invoke→admit pass per tick: the script version check,
        # plan compilation, and epoch-cached views are shared across the
        # queue, and each placement's admission lands before the next
        # decision is made (so capacity and affinity effects are observed,
        # exactly as the previous request-at-a-time loop did). On a
        # federation, each request enters at its submit()-time zone.
        if isinstance(self.platform, TappFederation):
            self.platform.invoke_batch(
                invocations,
                entry_zones=[request.entry_zone for request in requests],
                on_placement=_place,
            )
        else:
            self.platform.invoke_batch(invocations, on_placement=_place)
        self.queue = still_queued

    def _flag_stragglers(self) -> None:
        for replica in self.replicas.values():
            # Skip the first tick: it includes jit compilation, which would
            # poison the EMA baseline (warmup exclusion).
            if len(replica.tick_times) < 2:
                continue
            dt = replica.tick_times[-1]
            ema = self._ema.get(replica.name)
            if ema is not None and dt > self.straggler_factor * ema:
                self.stragglers_flagged += 1
                # Route-around: report the replica as saturated until the
                # next healthy heartbeat shows recovered load.
                self.platform.heartbeat(
                    replica.name, capacity_used_pct=100.0
                )
            self._ema[replica.name] = (
                dt if ema is None else 0.9 * ema + 0.1 * dt
            )
