"""Activation-sharding context.

Model code is mesh-agnostic; the launcher/dry-run wraps tracing in
:func:`activation_sharding` so that :func:`constrain` can place
``with_sharding_constraint`` on the hot activations (residual-stream scan
carry, logits) with the right axis names for whichever mesh is in use.
Outside the context (CPU unit tests) ``constrain`` is a no-op.

The key constraint is **sequence parallelism on the residual stream**: the
scan carry ``x [B, S, d]`` is sharded over the TP axis along S between
layers, which cuts stored-activation memory (and the remat carry) by the
TP degree; GSPMD inserts the gather where attention needs the full
sequence.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

_STATE = threading.local()

AxisRef = Union[None, str, Tuple[str, ...]]  # "dp" / "tp" resolved below


@contextlib.contextmanager
def activation_sharding(mesh, dp_axes: Tuple[str, ...], tp_axis,
                        vocab_axis=None):
    """``vocab_axis`` defaults to ``tp_axis``; under tp_scope="vocab" the
    layer carries see tp=None while logits still shard over the model axis."""
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = (mesh, tuple(dp_axes), tp_axis,
                  vocab_axis if vocab_axis is not None else tp_axis)
    try:
        yield
    finally:
        _STATE.ctx = prev


def _resolve(entry, dp_axes, tp_axis, vocab_axis):
    if entry == "dp":
        return dp_axes
    if entry == "tp":
        return tp_axis
    if entry == "vocab":
        return vocab_axis
    return entry


def current_dp_size() -> int:
    """Product of the data-parallel axis sizes (1 outside a context)."""
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return 1
    mesh, dp_axes = ctx[0], ctx[1]
    size = 1
    for a in dp_axes:
        size *= mesh.shape[a]
    return size


def constrain(x: jax.Array, spec_kinds: Sequence) -> jax.Array:
    """Apply a sharding constraint if a context is active and divisible.

    ``spec_kinds`` entries: "dp", "tp", None, or explicit axis names.
    Entries that do not evenly divide their dim are dropped.
    """
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return x
    mesh, dp_axes, tp_axis, vocab_axis = ctx
    entries = []
    for dim, kind in zip(x.shape, spec_kinds):
        axes = _resolve(kind, dp_axes, tp_axis, vocab_axis)
        if axes is None:
            entries.append(None)
            continue
        names = (axes,) if isinstance(axes, str) else tuple(axes)
        size = 1
        for a in names:
            size *= mesh.shape[a]
        entries.append(axes if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(*entries))
    )
