"""Sharding policy: PartitionSpecs for params, optimizer state, batches,
and caches — DP / FSDP / TP / EP / SP composed per architecture.

Rules are *name- and shape-based* with divisibility sanitisation: a spec
axis that does not evenly divide the corresponding dimension is dropped
(XLA requires even input sharding; intermediates may still shard unevenly
under GSPMD). This is what lets one policy cover all ten archs — e.g.
mamba2's vocab 50280 is not 16-divisible, so the embed table falls back
to sharding d_model on the TP axis.

Default placement (hillclimbed variants live in perf configs):
  * 2-D weights [d_in, d_out]: column-parallel on the TP axis for
    up-projections, row-parallel for down/out-projections; FSDP shards
    the *other* dim over the data axes for large models.
  * MoE expert stacks [E, ...]: expert-parallel on the TP axis when E
    divides it, otherwise tensor-parallel within experts.
  * Embeddings [V, d]: vocab-parallel (falls back to d).
  * Batches: [B, ...] over (pod, data); KV caches shard T on the TP axis
    for decode (B already covers the data axes), SSM states shard heads.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.api import ShapeSpec
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    tp_axis: Optional[str] = "model"
    dp_axes: Tuple[str, ...] = ("data",)          # + "pod" on the multipod mesh
    fsdp: bool = True                              # shard params over dp axes too
    fsdp_min_params: int = 2_000_000_000           # only FSDP models above this
    expert_parallel: bool = True                   # EP over tp_axis when divisible
    shard_kv_seq: bool = True                      # decode KV cache: T over TP axis
    # tp_enabled=False → pure DP/FSDP: the "model" axis joins the data axes
    # (the right policy for small models whose TP matmuls are sliver-thin).
    tp_enabled: bool = True
    # tp_scope="vocab" keeps the model axis OUT of the layer matmuls (they
    # run data-parallel) but still vocab-shards the embedding table and the
    # CE logits — the largest tensors of a small-model train step. The
    # batch then shards over the data axes only.
    tp_scope: str = "full"            # full | vocab

    def for_mesh(self, mesh: Mesh) -> "ShardingPolicy":
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        if not self.tp_enabled:
            dp = dp + ("model",)
            return dataclasses.replace(self, dp_axes=dp, tp_axis=None)
        return dataclasses.replace(self, dp_axes=dp)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def sanitize_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop spec entries that do not divide their dimension evenly."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axes in zip(shape, entries):
        if axes is None:
            out.append(None)
        elif dim % _axis_size(mesh, axes) == 0:
            out.append(axes)
        else:
            out.append(None)
    return P(*out)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

_COLUMN_PARALLEL = (  # [d_model, X] → shard X on TP
    "wq", "wk", "wv", "bq", "bk", "bv", "w_gate", "w_up",
    "in_proj_z", "in_proj_xbc", "in_proj_dt",
)
_ROW_PARALLEL = ("wo", "w_down", "out_proj")  # [X, d_model] → shard X on TP


def param_spec(
    cfg: ModelConfig,
    policy: ShardingPolicy,
    mesh: Mesh,
    path: Tuple[str, ...],
    shape: Tuple[int, ...],
) -> P:
    names = [p for p in path]
    leaf = names[-1]
    fsdp_on = policy.fsdp and cfg.param_count() >= policy.fsdp_min_params
    fsdp: Optional[Tuple[str, ...]] = policy.dp_axes if fsdp_on else None
    tp = policy.tp_axis
    if policy.tp_scope == "vocab" and leaf not in ("table",):
        # Layer weights run data-parallel; FSDP may use the idle model axis.
        tp = None
        if fsdp is not None:
            fsdp = fsdp + ((policy.tp_axis,) if policy.tp_axis else ())

    # Stacked layer dims (scan over periods / encoder / decoder stacks).
    stacked = any(n in ("blocks", "encoder", "decoder") for n in names[:-1])
    lead: Tuple = (None,) if stacked else ()

    def make(*entries) -> P:
        return sanitize_spec(P(*lead, *entries), shape, mesh)

    ndim = len(shape) - len(lead)

    if leaf == "table":  # embedding / lm_head [V, d]
        return make(tp, fsdp)
    if leaf in ("enc_pos", "dec_pos"):
        return make(None, tp)
    if ndim <= 1:
        # Norm scales, biases (except qkv bias handled below), scalars.
        if leaf in ("bq", "bk", "bv"):
            return make(tp)
        return make(None)
    if leaf == "router":
        return make(fsdp, None)
    if ndim == 3:  # MoE expert stacks [E, in, out]
        # NEVER shard the contracting (middle) dim: doing so turns every
        # expert matmul into activation-sized partial-sum all-reduces
        # ([E,C,f]-shaped) over the fsdp axis — measured ~10× the wire of
        # the weight gathers this layout incurs instead (§Perf, jamba
        # iteration 3). FSDP shards the *output* dim.
        e = shape[len(lead)]
        if policy.expert_parallel and tp is not None and e % _axis_size(mesh, tp) == 0:
            # Megatron pairing within each expert over the fsdp axis:
            # gate/up column-parallel on f, w_down row-parallel on f —
            # the only cross-device sum is the [.., d] output (3× smaller
            # than gathering the f-wide hidden).
            if leaf == "w_down":
                return make(tp, fsdp, None)
            return make(tp, None, fsdp)
        # Non-EP fallback (expert count not TP-divisible, e.g. grok's 8
        # experts on a 16-way axis): Megatron within experts over TP —
        # measured better than output-dim sharding here, since without EP
        # the buffer would otherwise be fully gathered per device
        # (§Perf: grok iteration log, refuted generalisation).
        if leaf in ("w_gate", "w_up"):
            return make(None, fsdp, tp)
        return make(None, tp, fsdp)
    if leaf in _COLUMN_PARALLEL:
        return make(fsdp, tp)
    if leaf in _ROW_PARALLEL:
        return make(tp, fsdp)
    if leaf == "conv_w":  # [W, conv_dim]
        return make(None, tp)
    # Fallback: replicate.
    return make(*([None] * ndim))


def param_shardings(
    cfg: ModelConfig,
    policy: ShardingPolicy,
    mesh: Mesh,
    params_shapes: Any,
) -> Any:
    """Tree of NamedShardings matching a params (shape) tree."""

    def visit(path, leaf):
        names = tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        spec = param_spec(cfg, policy, mesh, names, tuple(leaf.shape))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(visit, params_shapes)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------


def batch_shardings(
    cfg: ModelConfig,
    policy: ShardingPolicy,
    mesh: Mesh,
    shape_spec: ShapeSpec,
    batch_shapes: Dict[str, jax.ShapeDtypeStruct],
) -> Dict[str, NamedSharding]:
    dp = policy.dp_axes
    out: Dict[str, NamedSharding] = {}
    for name, sds in batch_shapes.items():
        if name in ("tokens", "mask"):
            spec = P(dp, None)
        elif name == "frames":       # [B, S, d]
            spec = P(dp, None, policy.tp_axis)
        elif name == "embeds":
            spec = P(dp, None, policy.tp_axis)
        elif name in ("token", "position"):  # decode step [B]
            spec = P(dp)
        else:
            spec = P()
        out[name] = NamedSharding(mesh, sanitize_spec(spec, sds.shape, mesh))
    return out


def cache_shardings(
    cfg: ModelConfig,
    policy: ShardingPolicy,
    mesh: Mesh,
    cache_shapes: Any,
) -> Any:
    """KV caches: [L, B, T, KV, Dh] — B over dp, T over TP (flash-decode
    style sequence sharding; GSPMD handles the softmax reduction). SSM
    states: [L, B, H, P, N] — H over TP. Conv caches: channel over TP."""
    dp = policy.dp_axes
    tp = policy.tp_axis

    def visit(path, leaf):
        names = tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        leafname = names[-1]
        shape = tuple(leaf.shape)
        kv_names = ("k", "v", "self_k", "self_v", "cross_k", "cross_v")
        if leafname in ("q", "scale") and len(names) >= 2 and names[-2] in kv_names:
            # int8 KV cache: q mirrors the KV layout; scale drops head_dim.
            seq = tp if policy.shard_kv_seq else None
            spec = P(None, dp, seq, None, None)
        elif leafname in kv_names:
            seq = tp if policy.shard_kv_seq else None
            spec = P(None, dp, seq, None, None)
        elif leafname == "ssm":      # [L, B, H, P, N]
            spec = P(None, dp, tp, None, None)
        elif leafname == "conv":     # [L, B, W-1, C]
            spec = P(None, dp, None, tp)
        else:
            spec = P(*([None] * len(shape)))
        return NamedSharding(mesh, sanitize_spec(spec, shape, mesh))

    return jax.tree_util.tree_map_with_path(visit, cache_shapes)
