"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json            # tree structure, shapes, dtypes, meta
        arrays/<leaf-id>.npy     # one file per leaf (host-gathered)
    <dir>/step_000123.COMMITTED  # atomicity marker (written last)

Design points for the 1000-node story:
  * **atomic**: a checkpoint is visible only after the COMMITTED marker —
    a process killed mid-write never corrupts the latest checkpoint;
  * **async**: `save(..., blocking=False)` snapshots device arrays to host
    then writes on a background thread — the train loop keeps stepping;
  * **elastic**: `restore(..., shardings=...)` re-places every leaf into
    the *current* mesh's shardings, so a job restarted on a different
    topology (e.g. 512→256 chips after losing a pod) resumes directly;
  * **garbage collection**: `keep_last` bounds disk usage.

On a real multi-host fleet each host would write only its owned shards
(`jax.experimental.multihost_utils` / array_serialization); in-process we
gather, which is exact on a single host and keeps the format trivial.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_names(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        out.append((name, leaf))
    return out


@dataclasses.dataclass
class CheckpointInfo:
    step: int
    path: pathlib.Path


class Checkpointer:
    def __init__(self, directory: str, *, keep_last: int = 3) -> None:
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._pending: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------------

    def save(self, step: int, tree: Any, *, blocking: bool = True,
             extra: Optional[Dict] = None) -> None:
        """Snapshot to host, then write (optionally on a background thread)."""
        self.wait()  # one async save in flight at a time
        host_leaves = [
            (name, np.asarray(jax.device_get(leaf)))
            for name, leaf in _flatten_with_names(tree)
        ]
        treedef = jax.tree_util.tree_structure(tree)

        def write() -> None:
            final = self.dir / f"step_{step:09d}"
            tmp = self.dir / f".tmp_step_{step:09d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            (tmp / "arrays").mkdir(parents=True)
            manifest = {
                "step": step,
                "treedef": str(treedef),
                "leaves": [],
                "extra": extra or {},
            }
            for idx, (name, arr) in enumerate(host_leaves):
                fname = f"{idx:05d}.npy"
                np.save(tmp / "arrays" / fname, arr)
                manifest["leaves"].append(
                    {"name": name, "file": fname,
                     "shape": list(arr.shape), "dtype": str(arr.dtype)}
                )
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            # Commit marker written last → crash-safe visibility.
            (self.dir / f"step_{step:09d}.COMMITTED").touch()
            self._gc()

        if blocking:
            write()
        else:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # -- restore -------------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        steps = []
        for marker in self.dir.glob("step_*.COMMITTED"):
            m = re.match(r"step_(\d+)\.COMMITTED", marker.name)
            if m and (self.dir / f"step_{int(m.group(1)):09d}").exists():
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def restore(
        self,
        like: Any,
        *,
        step: Optional[int] = None,
        shardings: Optional[Any] = None,
    ) -> Tuple[Any, int, Dict]:
        """Restore into the structure of ``like``; re-place onto
        ``shardings`` (elastic restore onto any current mesh)."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = self.dir / f"step_{step:09d}"
        manifest = json.loads((path / "manifest.json").read_text())

        arrays = {}
        for leaf_info in manifest["leaves"]:
            arrays[leaf_info["name"]] = np.load(path / "arrays" / leaf_info["file"])

        names = [name for name, _ in _flatten_with_names(like)]
        missing = [n for n in names if n not in arrays]
        if missing:
            raise ValueError(f"checkpoint missing leaves: {missing[:5]}…")

        sharding_leaves = None
        if shardings is not None:
            sharding_leaves = [s for _, s in _flatten_with_names(shardings)]

        leaves = []
        for i, name in enumerate(names):
            arr = arrays[name]
            if sharding_leaves is not None:
                leaves.append(jax.device_put(arr, sharding_leaves[i]))
            else:
                leaves.append(jax.numpy.asarray(arr))
        treedef = jax.tree_util.tree_structure(like)
        return treedef.unflatten(leaves), step, manifest.get("extra", {})

    # -- gc -------------------------------------------------------------------------

    def _gc(self) -> None:
        steps = sorted(
            int(re.match(r"step_(\d+)\.COMMITTED", m.name).group(1))
            for m in self.dir.glob("step_*.COMMITTED")
        )
        for old in steps[: -self.keep_last]:
            shutil.rmtree(self.dir / f"step_{old:09d}", ignore_errors=True)
            (self.dir / f"step_{old:09d}.COMMITTED").unlink(missing_ok=True)
