"""Deterministic synthetic token pipeline, per-host sharded.

Production shape: each host materialises only its shard of the global
batch (``host_slice``), and batches are addressable by step — so restart
from a checkpoint replays the exact stream (fault tolerance requires
*step-indexed* data, not an iterator with hidden state), and elastic
rescaling re-slices the same stream across a different host count.

The generator is a counter-based hash (threefry via jax.random with a
per-step fold), so batch(step) is O(1) — no fast-forward replay cost.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    # Structured synthetic data: repeated n-gram motifs make the loss
    # learnable (pure uniform noise has constant optimal loss).
    motif_len: int = 16
    n_motifs: int = 64
    frames_dim: int = 0          # >0 → also emit encoder frame embeddings


class SyntheticTokens:
    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self._motifs = rng.integers(
            low=0, high=cfg.vocab_size,
            size=(cfg.n_motifs, cfg.motif_len), dtype=np.int64,
        )

    # -- step-indexed access ----------------------------------------------------

    def batch_at(
        self,
        step: int,
        *,
        host_index: int = 0,
        host_count: int = 1,
    ) -> Dict[str, np.ndarray]:
        """The host's slice of global batch #step (deterministic)."""
        cfg = self.cfg
        if cfg.global_batch % host_count != 0:
            raise ValueError(
                f"global batch {cfg.global_batch} not divisible by "
                f"{host_count} hosts"
            )
        per_host = cfg.global_batch // host_count
        rows = np.arange(per_host) + host_index * per_host

        tokens = np.empty((per_host, cfg.seq_len), dtype=np.int32)
        for i, row in enumerate(rows):
            tokens[i] = self._row(step, int(row))
        out: Dict[str, np.ndarray] = {"tokens": tokens}
        if cfg.frames_dim:
            # Stub modality frontend: deterministic pseudo-embeddings.
            rng = np.random.default_rng(
                (cfg.seed * 1_000_003 + step) % (2**63)
            )
            out["frames"] = rng.standard_normal(
                (per_host, cfg.seq_len, cfg.frames_dim), dtype=np.float32
            )
        return out

    def _row(self, step: int, row: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 2_000_003 + step * 1_009 + row) % (2**63)
        )
        seq = rng.integers(0, cfg.vocab_size, size=cfg.seq_len, dtype=np.int64)
        # Plant motifs: ~50% of positions covered by repeated n-grams.
        n_plants = cfg.seq_len // (2 * cfg.motif_len)
        starts = rng.integers(0, max(1, cfg.seq_len - cfg.motif_len), size=n_plants)
        motif_ids = rng.integers(0, cfg.n_motifs, size=n_plants)
        for s, mid in zip(starts, motif_ids):
            seq[s : s + cfg.motif_len] = self._motifs[mid][: cfg.seq_len - s]
        return seq.astype(np.int32)

    # -- iterator convenience ------------------------------------------------------

    def iterate(
        self, start_step: int = 0, *, host_index: int = 0, host_count: int = 1
    ) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step, host_index=host_index, host_count=host_count)
            step += 1


def make_global_batch(
    pipeline: SyntheticTokens,
    step: int,
    mesh: Optional[jax.sharding.Mesh] = None,
    shardings: Optional[Dict] = None,
) -> Dict[str, jax.Array]:
    """Single-host path: materialise the full global batch (CPU tests)."""
    host_batch = pipeline.batch_at(step)
    out = {}
    for name, arr in host_batch.items():
        if shardings is not None and name in shardings:
            out[name] = jax.device_put(arr, shardings[name])
        else:
            out[name] = jnp.asarray(arr)
    return out
