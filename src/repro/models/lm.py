"""Decoder-only language model covering the lm / hybrid / ssm families.

The layer stack is ``n_periods`` repetitions of the config's period
pattern (see :meth:`ModelConfig.layer_pattern`). Parameters of each
period-position are stacked along a leading ``n_periods`` axis and the
stack is traversed with ``jax.lax.scan`` — one compiled block body
regardless of depth (72-layer Jamba lowers as 9 scan steps of an 8-layer
body). Activation checkpointing wraps the scan body per the config's
remat policy.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import basic
from repro.models.layers.attention import (
    attend_cached,
    attend_full,
    init_attention,
    init_kv_cache,
)
from repro.models.layers.moe import apply_moe, init_moe
from repro.models.layers.ssm import (
    apply_mamba,
    apply_mamba_step,
    init_mamba,
    init_mamba_cache,
)
from repro.sharding.ctx import constrain

AUX_LOSS_WEIGHT = 0.01


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_period(cfg: ModelConfig, rng: jax.Array) -> Dict:
    """Parameters for one period (pattern of layers)."""
    pattern = cfg.layer_pattern()
    params: Dict = {}
    keys = jax.random.split(rng, 2 * len(pattern))
    for i, (mixer, ffn) in enumerate(pattern):
        sub: Dict = {"mixer_norm": basic.init_norm(cfg)}
        if mixer == "attn":
            sub["attn"] = init_attention(cfg, keys[2 * i])
        else:
            sub["mamba"] = init_mamba(cfg, keys[2 * i])
        if ffn == "dense":
            sub["ffn_norm"] = basic.init_norm(cfg)
            sub["ffn"] = basic.init_ffn(cfg, keys[2 * i + 1])
        elif ffn == "moe":
            sub["ffn_norm"] = basic.init_norm(cfg)
            sub["moe"] = init_moe(cfg, keys[2 * i + 1])
        params[f"pos{i}"] = sub
    return params


def init_params(cfg: ModelConfig, rng: jax.Array) -> Dict:
    k_embed, k_blocks, k_head = jax.random.split(rng, 3)
    block_keys = jax.random.split(k_blocks, cfg.n_periods)
    blocks = jax.vmap(lambda k: init_period(cfg, k))(block_keys)
    params: Dict = {
        "embed": basic.init_embedding(cfg, k_embed),
        "blocks": blocks,
        "final_norm": basic.init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = basic.init_embedding(cfg, k_head)
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _apply_period(
    cfg: ModelConfig,
    period_params: Dict,
    x: jax.Array,
    positions: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """One period of layers. Returns (x, aux_loss)."""
    aux_total = jnp.zeros((), jnp.float32)
    for i, (mixer, ffn) in enumerate(cfg.layer_pattern()):
        sub = period_params[f"pos{i}"]
        h = basic.apply_norm(cfg, sub["mixer_norm"], x)
        if mixer == "attn":
            h = attend_full(cfg, sub["attn"], h, positions)
        else:
            h = apply_mamba(cfg, sub["mamba"], h)
        x = x + h
        if ffn != "none":
            h = basic.apply_norm(cfg, sub["ffn_norm"], x)
            if ffn == "moe":
                h, aux = apply_moe(cfg, sub["moe"], h)
                aux_total = aux_total + aux
            else:
                h = basic.apply_ffn(cfg, sub["ffn"], h)
            x = x + h
    return x, aux_total


def _remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    raise ValueError(f"unknown remat policy {cfg.remat!r}")


def forward(
    cfg: ModelConfig,
    params: Dict,
    tokens: jax.Array,
    *,
    embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Full forward pass. Returns (logits [B,S,V] float32, aux loss)."""
    if embeds is None:
        x = basic.embed(cfg, params["embed"], tokens)
    else:
        x = embeds.astype(jnp.dtype(cfg.compute_dtype))
    bsz, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (bsz, s))

    period_fn = _remat_wrap(
        cfg,
        functools.partial(_apply_period, cfg),
    )

    def scan_body(carry, period_params):
        x, aux = carry
        # Sequence parallelism on the residual stream between periods: the
        # stored scan carry shards S over the TP axis (see sharding/ctx.py).
        x = constrain(x, ("dp", "tp", None))
        x, aux_p = period_fn(period_params, x, positions)
        x = constrain(x, ("dp", "tp", None))
        return (x, aux + aux_p), None

    (x, aux), _ = jax.lax.scan(
        scan_body, (x, jnp.zeros((), jnp.float32)), params["blocks"]
    )

    x = basic.apply_norm(cfg, params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = basic.unembed(cfg, head, x)
    logits = constrain(logits, ("dp", None, "vocab"))  # vocab-parallel CE
    return logits, aux


def loss_fn(
    cfg: ModelConfig,
    params: Dict,
    batch: Dict[str, jax.Array],
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy (+ MoE aux). batch: {"tokens": [B,S]}."""
    tokens = batch["tokens"]
    logits, aux = forward(cfg, params, tokens, embeds=batch.get("embeds"))
    targets = tokens[:, 1:]
    logits = logits[:, :-1, :]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is not None:
        mask = mask[:, 1:].astype(jnp.float32)
        ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        ce = jnp.mean(nll)
    total = ce + AUX_LOSS_WEIGHT * aux
    return total, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode with caches
# ---------------------------------------------------------------------------


def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16
) -> Dict:
    """Stacked per-period cache pytree matching params["blocks"]."""

    def one_period() -> Dict:
        cache: Dict = {}
        for i, (mixer, _ffn) in enumerate(cfg.layer_pattern()):
            if mixer == "attn":
                k, v = init_kv_cache(cfg, batch, max_len, dtype)
                cache[f"pos{i}"] = {"k": k, "v": v}
            else:
                cache[f"pos{i}"] = init_mamba_cache(cfg, batch)
        return cache

    single = one_period()
    return jax.tree.map(
        lambda leaf: jnp.broadcast_to(
            leaf[None], (cfg.n_periods,) + leaf.shape
        ).copy(),
        single,
    )


def prefill(
    cfg: ModelConfig,
    params: Dict,
    tokens: jax.Array,
    cache: Dict,
    *,
    embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    """Process a full prompt, filling the cache. Returns (logits, cache)."""
    if embeds is None:
        x = basic.embed(cfg, params["embed"], tokens)
    else:
        x = embeds.astype(jnp.dtype(cfg.compute_dtype))
    bsz, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (bsz, s))

    def scan_body(x, inputs):
        period_params, period_cache = inputs
        new_cache: Dict = {}
        x = constrain(x, ("dp", "tp", None))  # sequence-parallel carry
        for i, (mixer, ffn) in enumerate(cfg.layer_pattern()):
            sub = period_params[f"pos{i}"]
            c = period_cache[f"pos{i}"]
            h = basic.apply_norm(cfg, sub["mixer_norm"], x)
            if mixer == "attn":
                from repro.models.layers.attention import _project_qkv

                q, k, v = _project_qkv(cfg, sub["attn"], h, positions=positions)
                # Write the prompt K/V into the cache prefix.
                from repro.models.layers.attention import write_kv_prefix

                ck = write_kv_prefix(cfg, c["k"], k, s)
                cv = write_kv_prefix(cfg, c["v"], v, s)
                new_cache[f"pos{i}"] = {"k": ck, "v": cv}
                h = attend_full(cfg, sub["attn"], h, positions)
            else:
                h, mamba_state = apply_mamba_with_state(cfg, sub["mamba"], h)
                new_cache[f"pos{i}"] = mamba_state
            x = x + h
            if ffn != "none":
                h = basic.apply_norm(cfg, sub["ffn_norm"], x)
                if ffn == "moe":
                    h, _ = apply_moe(cfg, sub["moe"], h)
                else:
                    h = basic.apply_ffn(cfg, sub["ffn"], h)
                x = x + h
        return x, new_cache

    x, new_cache = jax.lax.scan(scan_body, x, (params["blocks"], cache))
    x = basic.apply_norm(cfg, params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = basic.unembed(cfg, head, x[:, -1:, :])
    return logits, new_cache


def decode_step(
    cfg: ModelConfig,
    params: Dict,
    cache: Dict,
    token: jax.Array,       # [B] int32 — the most recent token
    position: jax.Array,    # [B] int32 — its cache slot
) -> Tuple[jax.Array, Dict]:
    """One incremental decode step. Returns (logits [B,1,V], new cache)."""
    x = basic.embed(cfg, params["embed"], token[:, None])

    def scan_body(x, inputs):
        period_params, period_cache = inputs
        new_cache: Dict = {}
        for i, (mixer, ffn) in enumerate(cfg.layer_pattern()):
            sub = period_params[f"pos{i}"]
            c = period_cache[f"pos{i}"]
            h = basic.apply_norm(cfg, sub["mixer_norm"], x)
            if mixer == "attn":
                h, ck, cv = attend_cached(
                    cfg, sub["attn"], h, c["k"], c["v"], position
                )
                new_cache[f"pos{i}"] = {"k": ck, "v": cv}
            else:
                h, nc = apply_mamba_step(cfg, sub["mamba"], h, c)
                new_cache[f"pos{i}"] = nc
            x = x + h
            if ffn != "none":
                h = basic.apply_norm(cfg, sub["ffn_norm"], x)
                if ffn == "moe":
                    h, _ = apply_moe(cfg, sub["moe"], h)
                else:
                    h = basic.apply_ffn(cfg, sub["ffn"], h)
                x = x + h
        return x, new_cache

    x, new_cache = jax.lax.scan(scan_body, x, (params["blocks"], cache))
    x = basic.apply_norm(cfg, params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = basic.unembed(cfg, head, x)
    return logits, new_cache


def apply_mamba_with_state(cfg, params: Dict, x: jax.Array):
    """Like apply_mamba but also returns the decode cache (for prefill)."""
    # Re-run the input path to extract the final conv window + ssm state.
    from repro.models.layers.ssm import _causal_conv, _in_proj, ssd_chunked

    cdt = jnp.dtype(cfg.compute_dtype)
    bsz, s, _ = x.shape
    di, g, n, h, p = (
        cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim,
    )
    z, xbc_raw, dt_raw = _in_proj(cfg, params, x.astype(cdt), cdt)
    xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(cdt)

    xs = xbc[..., :di].reshape(bsz, s, h, p)
    b_mat = xbc[..., di : di + g * n].reshape(bsz, s, g, n)
    c_mat = xbc[..., di + g * n :].reshape(bsz, s, g, n)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :]
    )
    a = -jnp.exp(params["a_log"])
    y, final_state = ssd_chunked(xs, dt, a, b_mat, c_mat, cfg.ssm_chunk)
    y = y + xs.astype(jnp.float32) * params["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di)

    from repro.models.layers.ssm import _gated_rmsnorm

    y = _gated_rmsnorm(y, z, params["norm_scale"], cfg.norm_eps).astype(cdt)
    out = y @ params["out_proj"].astype(cdt)

    conv_window = xbc_raw[:, -(cfg.ssm_conv - 1):, :].astype(jnp.float32)
    cache = {"conv": conv_window, "ssm": final_state}
    return out, cache


def _cache_len(cfg: ModelConfig, cache: Dict) -> int:
    for i, (mixer, _) in enumerate(cfg.layer_pattern()):
        if mixer == "attn":
            k = cache[f"pos{i}"]["k"]
            ref = k["q"] if isinstance(k, dict) else k
            return ref.shape[2]
    return 0
