"""Unified model API: one facade over the lm/hybrid/ssm/encdec families.

``Model`` exposes exactly the entry points the launcher, serving engine,
and dry-run lower:

  * ``init_params(rng)``
  * ``loss(params, batch)``             — training objective
  * ``prefill(params, batch, cache)``   — prompt processing
  * ``decode(params, cache, token, position)`` — incremental decode
  * ``init_cache(batch, max_len)``
  * ``input_specs(shape)``              — ShapeDtypeStruct stand-ins for the
                                          multi-pod dry-run (no allocation)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec, lm
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                 # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

#: Sub-quadratic-attention families that run the long_500k cell.
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    """Per DESIGN.md §Arch-applicability: long_500k only for ssm/hybrid."""
    if shape.name == "long_500k":
        return cfg.family in LONG_CONTEXT_FAMILIES
    return True


class Model:
    def __init__(self, cfg: ModelConfig) -> None:
        self.cfg = cfg

    # -- parameters ---------------------------------------------------------

    def init_params(self, rng: jax.Array) -> Dict:
        if self.cfg.family == "encdec":
            return encdec.init_params(self.cfg, rng)
        return lm.init_params(self.cfg, rng)

    # -- training --------------------------------------------------------------

    def loss(self, params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
        if self.cfg.family == "encdec":
            return encdec.loss_fn(self.cfg, params, batch)
        return lm.loss_fn(self.cfg, params, batch)

    # -- serving ------------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int, enc_len: int = 0) -> Dict:
        dtype = jnp.dtype(self.cfg.compute_dtype)
        if self.cfg.family == "encdec":
            return encdec.init_cache(
                self.cfg, batch, max_len, enc_len or max_len, dtype=dtype
            )
        return lm.init_cache(self.cfg, batch, max_len, dtype=dtype)

    def prefill(self, params: Dict, batch: Dict, cache: Dict):
        if self.cfg.family == "encdec":
            return encdec.prefill(
                self.cfg, params, batch["frames"], batch["tokens"], cache
            )
        return lm.prefill(
            self.cfg, params, batch["tokens"], cache, embeds=batch.get("embeds")
        )

    def decode(self, params: Dict, cache: Dict, token: jax.Array, position: jax.Array):
        if self.cfg.family == "encdec":
            return encdec.decode_step(self.cfg, params, cache, token, position)
        return lm.decode_step(self.cfg, params, cache, token, position)

    # -- dry-run stand-ins -------------------------------------------------------------

    def input_specs(self, shape: ShapeSpec) -> Dict:
        """ShapeDtypeStruct stand-ins for every model input of this cell.

        For ``train``/``prefill`` this is the token batch (plus the stub
        frontend embeddings for [audio]/[vlm]); for ``decode`` it is the
        one-token step inputs — the KV cache is constructed separately via
        :meth:`cache_specs` so the dry-run can shard it.
        """
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32

        if cfg.family == "encdec":
            if shape.kind == "train" or shape.kind == "prefill":
                return {
                    "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
                    "tokens": jax.ShapeDtypeStruct((b, s), i32),
                }
            return {
                "token": jax.ShapeDtypeStruct((b,), i32),
                "position": jax.ShapeDtypeStruct((b,), i32),
            }

        if shape.kind in ("train", "prefill"):
            return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        return {
            "token": jax.ShapeDtypeStruct((b,), i32),
            "position": jax.ShapeDtypeStruct((b,), i32),
        }

    def cache_specs(self, shape: ShapeSpec, dtype=jnp.bfloat16) -> Dict:
        """ShapeDtypeStructs matching init_cache (for decode dry-runs)."""
        cache = jax.eval_shape(
            lambda: self.init_cache(shape.global_batch, shape.seq_len,
                                    enc_len=min(shape.seq_len, 4096))
        )
        return cache
