"""Encoder-decoder transformer (Whisper-small backbone).

The audio frontend (log-mel + conv downsampling) is a STUB per the
assignment: ``input_specs()`` feeds precomputed frame embeddings
``[B, S_enc, d_model]`` directly to the encoder. Learned positional
embeddings, LayerNorm, GELU — per the Whisper architecture.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import basic
from repro.models.layers.attention import (
    _project_qkv,
    attend_cached,
    attend_cross,
    attend_full,
    init_attention,
    init_kv_cache,
)
from repro.sharding.ctx import constrain


def _init_pos_table(cfg, rng: jax.Array, n: int) -> jax.Array:
    return (
        0.01 * jax.random.normal(rng, (n, cfg.d_model), dtype=jnp.float32)
    ).astype(jnp.dtype(cfg.param_dtype))


def init_params(cfg: ModelConfig, rng: jax.Array) -> Dict:
    keys = jax.random.split(rng, 8)
    max_pos = cfg.max_position or 4096

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "attn_norm": basic.init_norm(cfg),
            "attn": init_attention(cfg, k1),
            "ffn_norm": basic.init_norm(cfg),
            "ffn": basic.init_ffn(cfg, k2),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "self_norm": basic.init_norm(cfg),
            "self_attn": init_attention(cfg, k1),
            "cross_norm": basic.init_norm(cfg),
            "cross_attn": init_attention(cfg, k2, cross=True),
            "ffn_norm": basic.init_norm(cfg),
            "ffn": basic.init_ffn(cfg, k3),
        }

    enc_keys = jax.random.split(keys[0], cfg.encoder_layers)
    dec_keys = jax.random.split(keys[1], cfg.n_layers)
    return {
        "enc_pos": _init_pos_table(cfg, keys[2], max_pos),
        "dec_pos": _init_pos_table(cfg, keys[3], max_pos),
        "embed": basic.init_embedding(cfg, keys[4]),
        "encoder": jax.vmap(enc_layer)(enc_keys),
        "decoder": jax.vmap(dec_layer)(dec_keys),
        "enc_final_norm": basic.init_norm(cfg),
        "final_norm": basic.init_norm(cfg),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------


def encode(cfg: ModelConfig, params: Dict, frames: jax.Array) -> jax.Array:
    """frames: [B, S_enc, d_model] (stub frontend output) → [B, S_enc, d]."""
    cdt = jnp.dtype(cfg.compute_dtype)
    bsz, s, _ = frames.shape
    pos = params["enc_pos"][:s].astype(cdt)
    x = frames.astype(cdt) + pos[None]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (bsz, s))

    def body(x, layer):
        x = constrain(x, ("dp", "tp", None))
        h = basic.apply_norm(cfg, layer["attn_norm"], x)
        h = attend_full(cfg, layer["attn"], h, positions, causal=False)
        x = x + h
        h = basic.apply_norm(cfg, layer["ffn_norm"], x)
        x = x + basic.apply_ffn(cfg, layer["ffn"], h)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return basic.apply_norm(cfg, params["enc_final_norm"], x)


# ---------------------------------------------------------------------------
# Decoder (train / prefill forward)
# ---------------------------------------------------------------------------


def decode_full(
    cfg: ModelConfig, params: Dict, tokens: jax.Array, enc_out: jax.Array
) -> jax.Array:
    cdt = jnp.dtype(cfg.compute_dtype)
    bsz, s = tokens.shape
    x = basic.embed(cfg, params["embed"], tokens)
    x = x + params["dec_pos"][:s].astype(cdt)[None]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (bsz, s))

    def body(x, layer):
        x = constrain(x, ("dp", "tp", None))
        h = basic.apply_norm(cfg, layer["self_norm"], x)
        h = attend_full(cfg, layer["self_attn"], h, positions, causal=True)
        x = x + h
        h = basic.apply_norm(cfg, layer["cross_norm"], x)
        h = attend_cross(cfg, layer["cross_attn"], h, enc_out)
        x = x + h
        h = basic.apply_norm(cfg, layer["ffn_norm"], x)
        x = x + basic.apply_ffn(cfg, layer["ffn"], h)
        return x, None

    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = basic.apply_norm(cfg, params["final_norm"], x)
    logits = basic.unembed(cfg, params["embed"], x)  # tied head (Whisper ties)
    return constrain(logits, ("dp", None, "vocab"))


def loss_fn(
    cfg: ModelConfig, params: Dict, batch: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """batch: {"frames": [B,S_enc,d], "tokens": [B,S_dec]}."""
    enc_out = encode(cfg, params, batch["frames"])
    logits = decode_full(cfg, params, batch["tokens"], enc_out)
    targets = batch["tokens"][:, 1:]
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(nll)
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_cache(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    enc_len: int,
    dtype=jnp.bfloat16,
) -> Dict:
    k, v = init_kv_cache(cfg, batch, max_len, dtype)

    def stack(leaf):
        return jnp.broadcast_to(leaf[None], (cfg.n_layers,) + leaf.shape).copy()

    cross_shape = (batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "self_k": stack(k),
        "self_v": stack(v),
        "cross_k": jnp.zeros((cfg.n_layers,) + cross_shape, dtype),
        "cross_v": jnp.zeros((cfg.n_layers,) + cross_shape, dtype),
    }


def prefill(
    cfg: ModelConfig,
    params: Dict,
    frames: jax.Array,
    tokens: jax.Array,
    cache: Dict,
) -> Tuple[jax.Array, Dict]:
    """Encode + decoder prompt pass, filling self- and cross-KV caches."""
    enc_out = encode(cfg, params, frames)
    cdt = jnp.dtype(cfg.compute_dtype)
    bsz, s = tokens.shape
    x = basic.embed(cfg, params["embed"], tokens)
    x = x + params["dec_pos"][:s].astype(cdt)[None]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (bsz, s))

    def body(x, inputs):
        layer, ck, cv = inputs
        x = constrain(x, ("dp", "tp", None))  # sequence-parallel carry
        h = basic.apply_norm(cfg, layer["self_norm"], x)
        q, k, v = _project_qkv(cfg, layer["self_attn"], h, positions=positions)
        new_sk = ck.at[:, :s].set(k.astype(ck.dtype))
        new_sv = cv.at[:, :s].set(v.astype(cv.dtype))
        h = attend_full(cfg, layer["self_attn"], h, positions, causal=True)
        x = x + h
        h = basic.apply_norm(cfg, layer["cross_norm"], x)
        _, xk, xv = _project_qkv(
            cfg, layer["cross_attn"], h, kv_input=enc_out, use_rope=False
        )
        h = attend_cross(cfg, layer["cross_attn"], h, enc_out)
        x = x + h
        h = basic.apply_norm(cfg, layer["ffn_norm"], x)
        x = x + basic.apply_ffn(cfg, layer["ffn"], h)
        return x, (new_sk, new_sv, xk.astype(ck.dtype), xv.astype(cv.dtype))

    x, (sk, sv, xk, xv) = jax.lax.scan(
        body, x, (params["decoder"], cache["self_k"], cache["self_v"])
    )
    x = basic.apply_norm(cfg, params["final_norm"], x)
    logits = basic.unembed(cfg, params["embed"], x[:, -1:, :])
    return logits, {"self_k": sk, "self_v": sv, "cross_k": xk, "cross_v": xv}


def decode_step(
    cfg: ModelConfig,
    params: Dict,
    cache: Dict,
    token: jax.Array,
    position: jax.Array,
) -> Tuple[jax.Array, Dict]:
    cdt = jnp.dtype(cfg.compute_dtype)
    x = basic.embed(cfg, params["embed"], token[:, None])
    pos_emb = jnp.take(params["dec_pos"], position, axis=0).astype(cdt)
    x = x + pos_emb[:, None, :]

    def body(x, inputs):
        layer, sk, sv, xk, xv = inputs
        h = basic.apply_norm(cfg, layer["self_norm"], x)
        h, nsk, nsv = attend_cached(cfg, layer["self_attn"], h, sk, sv, position)
        x = x + h
        h = basic.apply_norm(cfg, layer["cross_norm"], x)
        # Cross attention against the precomputed encoder K/V.
        from repro.models.layers.attention import _sdpa

        q, _, _ = _project_qkv(cfg, layer["cross_attn"], h, use_rope=False)
        o = _sdpa(q, xk.astype(cdt), xv.astype(cdt), None)
        o = o.reshape(*o.shape[:-2], cfg.n_heads * cfg.head_dim)
        x = x + o @ layer["cross_attn"]["wo"].astype(cdt)
        h = basic.apply_norm(cfg, layer["ffn_norm"], x)
        x = x + basic.apply_ffn(cfg, layer["ffn"], h)
        return x, (nsk, nsv)

    x, (sk, sv) = jax.lax.scan(
        body,
        x,
        (
            params["decoder"],
            cache["self_k"],
            cache["self_v"],
            cache["cross_k"],
            cache["cross_v"],
        ),
    )
    x = basic.apply_norm(cfg, params["final_norm"], x)
    logits = basic.unembed(cfg, params["embed"], x)
    return logits, {
        "self_k": sk,
        "self_v": sv,
        "cross_k": cache["cross_k"],
        "cross_v": cache["cross_v"],
    }
