"""Model substrate: 10 assigned architectures over 4 families."""
from repro.models.api import LONG_CONTEXT_FAMILIES, SHAPES, Model, ShapeSpec, shape_applicable
from repro.models.config import ModelConfig

__all__ = [
    "LONG_CONTEXT_FAMILIES",
    "Model",
    "ModelConfig",
    "SHAPES",
    "ShapeSpec",
    "shape_applicable",
]
