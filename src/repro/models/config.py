"""Model configuration — a single dataclass covering all assigned families.

Families:
  * ``lm``     — decoder-only transformer (dense / MoE / VLM-early-fusion)
  * ``hybrid`` — interleaved Mamba-2 + attention (Jamba-style), optional MoE
  * ``ssm``    — pure Mamba-2 (SSD)
  * ``encdec`` — encoder-decoder transformer (Whisper backbone)

Layer heterogeneity is expressed as a *period pattern*: the layer stack is
``n_layers / period`` repetitions of a fixed pattern of (mixer, ffn) pairs,
which lets every family scan over stacked per-period parameters (small HLO,
per-layer remat policy).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # lm | hybrid | ssm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // n_heads

    # --- attention variants ---------------------------------------------
    qkv_bias: bool = False           # qwen1.5
    qk_norm: bool = False            # qwen3, chameleon
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    norm_kind: str = "rmsnorm"       # rmsnorm | layernorm

    # --- ffn variants ------------------------------------------------------
    mlp_kind: str = "swiglu"         # swiglu | squared_relu | gelu

    # --- MoE ----------------------------------------------------------------
    moe_experts: int = 0             # 0 → dense
    moe_top_k: int = 2
    moe_every: int = 1               # every Nth ffn is MoE (jamba: 2)
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba-2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_groups: int = 1

    # --- hybrid interleave ------------------------------------------------------
    attn_every: int = 0              # period length; one attn layer per period
    attn_index: int = 0              # position of the attention layer in period

    # --- enc-dec ------------------------------------------------------------------
    encoder_layers: int = 0
    pos_embedding: str = "rope"      # rope | learned
    max_position: int = 0            # learned-pos table size (0 = seq dependent)
    frontend: str = "none"           # none | audio_stub | vq_stub (see DESIGN.md)

    # --- embeddings / output ----------------------------------------------------
    tie_embeddings: bool = False
    logit_softcap: float = 0.0       # grok uses 30.0

    # --- numerics / execution -----------------------------------------------------
    kv_cache_dtype: str = "compute"  # compute | int8 (quantised KV cache)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "none"              # none | dots | full
    use_kernels: bool = False        # route hot paths through Pallas kernels

    # -------------------------------------------------------------------------

    def __post_init__(self) -> None:
        if self.family not in ("lm", "hybrid", "ssm", "encdec"):
            raise ValueError(f"unknown family {self.family!r}")
        if self.family != "ssm" and self.n_heads > 0:
            if self.head_dim == 0:
                object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
            if self.n_heads % max(1, self.n_kv_heads) != 0:
                raise ValueError("n_heads must be a multiple of n_kv_heads")
        if self.family == "hybrid" and self.attn_every <= 0:
            raise ValueError("hybrid family requires attn_every > 0")
        if self.family in ("ssm", "hybrid") and self.ssm_state <= 0:
            raise ValueError(f"{self.family} family requires ssm_state > 0")

    # --- derived structure --------------------------------------------------------

    @property
    def period(self) -> int:
        """Length of the repeating layer pattern."""
        if self.family == "hybrid":
            import math

            # Pattern must also align with the MoE interleave.
            return _lcm(self.attn_every, self.moe_every if self.moe_experts else 1)
        if self.family == "lm" and self.moe_experts and self.moe_every > 1:
            return self.moe_every
        return 1

    @property
    def n_periods(self) -> int:
        if self.n_layers % self.period != 0:
            raise ValueError(
                f"n_layers={self.n_layers} not divisible by period={self.period}"
            )
        return self.n_layers // self.period

    def layer_pattern(self) -> List[Tuple[str, str]]:
        """(mixer, ffn) for each layer position within one period.

        mixer ∈ {"attn", "mamba"}; ffn ∈ {"dense", "moe", "none"}.
        Mamba-2 blocks have no separate FFN (the SSD block includes the
        gated expansion) unless the config interleaves MoE (Jamba).
        """
        pattern: List[Tuple[str, str]] = []
        for i in range(self.period):
            if self.family == "ssm":
                mixer = "mamba"
            elif self.family == "hybrid":
                mixer = "attn" if i % self.attn_every == self.attn_index else "mamba"
            else:
                mixer = "attn"
            if self.family == "ssm":
                ffn = "none"
            elif self.moe_experts and i % self.moe_every == self.moe_every - 1:
                ffn = "moe"
            else:
                ffn = "dense"
            pattern.append((mixer, ffn))
        return pattern

    @property
    def d_inner(self) -> int:
        """Mamba-2 expanded inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    # --- parameter counting (for rooflines & reporting) ------------------------------

    def param_count(self) -> int:
        return sum(c for _, c in self.param_breakdown())

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top-k of experts)."""
        total = 0
        for name, count in self.param_breakdown():
            if name.endswith(".moe"):
                total += count * self.moe_top_k // max(1, self.moe_experts)
            else:
                total += count
        return total

    def param_breakdown(self) -> List[Tuple[str, int]]:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        items: List[Tuple[str, int]] = [("embed", v * d)]
        if not self.tie_embeddings:
            items.append(("lm_head", v * d))

        def attn_params() -> int:
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            bias = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
            qknorm = 2 * hd if self.qk_norm else 0
            return q + kv + o + bias + qknorm

        def dense_ffn() -> int:
            mults = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
            return mults * d * f

        def moe_ffn() -> int:
            return self.moe_experts * dense_ffn() + d * self.moe_experts  # + router

        def mamba_params() -> int:
            di, ns, g = self.d_inner, self.ssm_state, self.ssm_groups
            in_proj = d * (2 * di + 2 * g * ns + self.ssm_nheads)
            conv = self.ssm_conv * (di + 2 * g * ns)
            out_proj = di * d
            extras = 3 * self.ssm_nheads  # A_log, D, dt_bias
            norm = di
            return in_proj + conv + out_proj + extras + norm

        pattern = self.layer_pattern()
        for period_idx in range(self.n_periods):
            for li, (mixer, ffn) in enumerate(pattern):
                tagname = f"layer{period_idx * self.period + li}"
                if mixer == "attn":
                    items.append((f"{tagname}.attn", attn_params() + d))
                else:
                    items.append((f"{tagname}.mamba", mamba_params() + d))
                if ffn == "dense":
                    items.append((f"{tagname}.ffn", dense_ffn() + d))
                elif ffn == "moe":
                    items.append((f"{tagname}.moe", moe_ffn() + d))
        if self.family == "encdec":
            # Encoder self-attn + ffn, decoder cross-attn (added to the above
            # decoder stack), learned positions.
            enc = self.encoder_layers * (attn_params() + dense_ffn() + 2 * d)
            cross = self.n_layers * (attn_params() + d)
            pos = (self.max_position or 4096) * d * 2
            items += [("encoder", enc), ("cross_attn", cross), ("pos", pos)]
        items.append(("final_norm", d))
        return items


def _lcm(a: int, b: int) -> int:
    import math

    return a * b // math.gcd(a, b)
