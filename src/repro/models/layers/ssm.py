"""Mamba-2 (SSD — state-space duality) mixer block.

Implements the chunked SSD algorithm (Dao & Gu, arXiv:2405.21060): the
sequence is split into chunks; within a chunk the recurrence is computed
in its *dual* quadratic-attention form (MXU-friendly matmuls), across
chunks a linear recurrence carries the [H, P, N] state. The same block
exposes a single-token :func:`ssd_step` for decode — state size is
constant in sequence length, which is what makes the ``long_500k`` shape
tractable for the ssm/hybrid archs.

Layout notes (TPU adaptation): heads H shard over the ``model`` mesh axis;
chunk size Q is the Pallas kernel's sequence tile; P (headdim) and N
(state) are 64/128 — multiples of the MXU/VREG lane width.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers.basic import _dtype, _init_linear

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_mamba(cfg, rng: jax.Array) -> Dict:
    dtype = _dtype(cfg.param_dtype)
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_nheads
    conv_dim = di + 2 * g * n
    keys = jax.random.split(rng, 4)

    # in_proj is split into [z | xBC | dt] projections so each shards
    # cleanly over the tensor-parallel axis (the packed 2·di+2GN+H width
    # is not TP-divisible for e.g. mamba2-2.7b).
    kz, kx, kdt = jax.random.split(keys[0], 3)
    params = {
        "in_proj_z": _init_linear(kz, d, di, dtype),
        "in_proj_xbc": _init_linear(kx, d, di + 2 * g * n, dtype),
        "in_proj_dt": _init_linear(kdt, d, h, dtype),
        "conv_w": (
            jax.random.normal(keys[1], (cfg.ssm_conv, conv_dim), jnp.float32)
            * (1.0 / jnp.sqrt(jnp.float32(cfg.ssm_conv)))
        ).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)
        ).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(
            jnp.expm1(
                jnp.exp(
                    jax.random.uniform(
                        keys[2], (h,), jnp.float32,
                        minval=jnp.log(0.001), maxval=jnp.log(0.1),
                    )
                )
            )
        ).astype(jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": _init_linear(keys[3], di, d, dtype),
    }
    return params


# ---------------------------------------------------------------------------
# SSD core (chunked scan) — pure jnp; the Pallas kernel mirrors the
# intra-chunk dual form.
# ---------------------------------------------------------------------------


def segsum(a: jax.Array) -> jax.Array:
    """Lower-triangular pairwise segment sums: out[..., i, j] = sum_{j<k<=i} a[k].

    a: [..., Q] → [..., Q, Q] with -inf above the diagonal.
    """
    q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), dtype=bool))
    return jnp.where(mask, diff, NEG_INF)


def ssd_chunked(
    x: jax.Array,        # [B,S,H,P]  (pre-multiplied by nothing; dt applied here)
    dt: jax.Array,       # [B,S,H]    (post-softplus, positive)
    a: jax.Array,        # [H]        (negative; A = -exp(a_log))
    b_mat: jax.Array,    # [B,S,G,N]
    c_mat: jax.Array,    # [B,S,G,N]
    chunk: int,
    initial_state: Optional[jax.Array] = None,  # [B,H,P,N]
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    orig_s = s
    if s % chunk != 0:
        # Pad to a chunk multiple: dt=0 on padded steps makes both the decay
        # (exp(0)=1) and the input contribution (x·dt=0) identity ops, so the
        # final state and the unpadded outputs are unaffected.
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // chunk
    hpg = h // g  # heads per group

    f32 = jnp.float32
    dt = dt.astype(f32)
    da = dt * a.astype(f32)[None, None, :]                     # [B,S,H] (negative)
    xdt = (x.astype(f32) * dt[..., None])                       # [B,S,H,P]

    # Reshape into chunks.
    da_c = da.reshape(bsz, nc, chunk, h).transpose(0, 3, 1, 2)  # [B,H,C,Q]
    x_c = xdt.reshape(bsz, nc, chunk, h, p)                     # [B,C,Q,H,P]
    b_c = b_mat.astype(f32).reshape(bsz, nc, chunk, g, n)       # [B,C,Q,G,N]
    c_c = c_mat.astype(f32).reshape(bsz, nc, chunk, g, n)

    # Broadcast groups to heads.
    def to_heads(t):  # [B,C,Q,G,N] -> [B,C,Q,H,N]
        return jnp.repeat(t, hpg, axis=3)

    b_h = to_heads(b_c)
    c_h = to_heads(c_c)

    cum = jnp.cumsum(da_c, axis=-1)                             # [B,H,C,Q]
    seg = segsum(da_c)                                          # [B,H,C,Q,Q]
    l_mat = jnp.exp(seg)

    # 1) Intra-chunk (dual quadratic form).
    y_intra = jnp.einsum(
        "bclhn,bcshn,bhcls,bcshp->bclhp", c_h, b_h, l_mat, x_c
    )

    # 2) Per-chunk final states: decay each position to the chunk end.
    decay_to_end = jnp.exp(cum[..., -1:] - cum)                 # [B,H,C,Q]
    states = jnp.einsum("bcshn,bhcs,bcshp->bchpn", b_h, decay_to_end, x_c)

    # 3) Inter-chunk recurrence (scan over chunks).
    chunk_decay = jnp.exp(cum[..., -1])                         # [B,H,C]
    init = (
        jnp.zeros((bsz, h, p, n), f32)
        if initial_state is None
        else initial_state.astype(f32)
    )

    def step(carry, inputs):
        s_c, decay_c = inputs                                   # [B,H,P,N], [B,H]
        new = carry * decay_c[..., None, None] + s_c
        return new, carry                                        # emit state at chunk *start*

    xs = (
        states.transpose(1, 0, 2, 3, 4),                        # [C,B,H,P,N]
        chunk_decay.transpose(2, 0, 1),                         # [C,B,H]
    )
    final_state, start_states = jax.lax.scan(step, init, xs)
    start_states = start_states.transpose(1, 0, 2, 3, 4)        # [B,C,H,P,N]

    # 4) Inter-chunk contribution: state at chunk start, decayed to l.
    state_decay = jnp.exp(cum)                                  # [B,H,C,Q]
    y_inter = jnp.einsum(
        "bclhn,bhcl,bchpn->bclhp", c_h, state_decay, start_states
    )

    y = (y_intra + y_inter).reshape(bsz, s, h, p)[:, :orig_s]
    return y, final_state


def ssd_step(
    x: jax.Array,       # [B,H,P]
    dt: jax.Array,      # [B,H]
    a: jax.Array,       # [H]
    b_vec: jax.Array,   # [B,G,N]
    c_vec: jax.Array,   # [B,G,N]
    state: jax.Array,   # [B,H,P,N]
) -> Tuple[jax.Array, jax.Array]:
    """Single decode step of the SSD recurrence."""
    f32 = jnp.float32
    h = x.shape[1]
    g = b_vec.shape[1]
    hpg = h // g
    dt = dt.astype(f32)
    decay = jnp.exp(dt * a.astype(f32)[None, :])                # [B,H]
    b_h = jnp.repeat(b_vec.astype(f32), hpg, axis=1)            # [B,H,N]
    c_h = jnp.repeat(c_vec.astype(f32), hpg, axis=1)
    dbx = jnp.einsum("bh,bhn,bhp->bhpn", dt, b_h, x.astype(f32))
    state = state * decay[..., None, None] + dbx
    y = jnp.einsum("bhpn,bhn->bhp", state, c_h)
    return y, state


# ---------------------------------------------------------------------------
# Full Mamba-2 block
# ---------------------------------------------------------------------------


def _gated_rmsnorm(y: jax.Array, z: jax.Array, scale: jax.Array, eps: float):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32))


def _in_proj(cfg, params: Dict, x: jax.Array, cdt):
    z = x @ params["in_proj_z"].astype(cdt)
    xbc = x @ params["in_proj_xbc"].astype(cdt)
    dt = x @ params["in_proj_dt"].astype(cdt)
    return z, xbc, dt


def apply_mamba(
    cfg, params: Dict, x: jax.Array, *, initial_state=None
) -> jax.Array:
    """Full-sequence Mamba-2 block. x: [B,S,D] → [B,S,D]."""
    cdt = _dtype(cfg.compute_dtype)
    bsz, s, _ = x.shape
    di, g, n, h, p = (
        cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim,
    )

    z, xbc, dt_raw = _in_proj(cfg, params, x.astype(cdt), cdt)

    # Causal depthwise conv over the sequence.
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(cdt)

    xs = xbc[..., :di].reshape(bsz, s, h, p)
    b_mat = xbc[..., di : di + g * n].reshape(bsz, s, g, n)
    c_mat = xbc[..., di + g * n :].reshape(bsz, s, g, n)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :]
    )
    a = -jnp.exp(params["a_log"])

    if cfg.use_kernels:
        from repro.kernels.ops import ssd_scan

        y, _ = ssd_scan(xs, dt, a, b_mat, c_mat, chunk=cfg.ssm_chunk)
    else:
        y, _ = ssd_chunked(
            xs, dt, a, b_mat, c_mat, cfg.ssm_chunk, initial_state
        )
    y = y + xs.astype(jnp.float32) * params["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di)

    y = _gated_rmsnorm(y, z, params["norm_scale"], cfg.norm_eps).astype(cdt)
    return y @ params["out_proj"].astype(cdt)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. x: [B,S,C]; w: [W,C]."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):  # unrolled: width is 4
        out = out + pad[:, i : i + x.shape[1], :].astype(jnp.float32) * w[i].astype(
            jnp.float32
        )
    return (out + b.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------


def init_mamba_cache(cfg, batch: int, dtype=jnp.float32) -> Dict:
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    conv_dim = di + 2 * g * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim, n), dtype),
    }


def apply_mamba_step(
    cfg, params: Dict, x: jax.Array, cache: Dict
) -> Tuple[jax.Array, Dict]:
    """One-token decode. x: [B,1,D] → ([B,1,D], new cache)."""
    cdt = _dtype(cfg.compute_dtype)
    bsz = x.shape[0]
    di, g, n, h, p = (
        cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim,
    )

    z, xbc, dt_raw = _in_proj(cfg, params, x[:, 0, :].astype(cdt), cdt)

    # Rolling conv buffer: window = [cache | current].
    window = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # [B,W,C]
    conv_out = jnp.einsum(
        "bwc,wc->bc", window.astype(jnp.float32), params["conv_w"].astype(jnp.float32)
    ) + params["conv_b"].astype(jnp.float32)
    xbc_t = jax.nn.silu(conv_out).astype(cdt)
    new_conv = window[:, 1:, :]

    xs = xbc_t[..., :di].reshape(bsz, h, p)
    b_vec = xbc_t[..., di : di + g * n].reshape(bsz, g, n)
    c_vec = xbc_t[..., di + g * n :].reshape(bsz, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, :])
    a = -jnp.exp(params["a_log"])

    y, new_ssm = ssd_step(xs, dt, a, b_vec, c_vec, cache["ssm"])
    y = y + xs.astype(jnp.float32) * params["d_skip"][None, :, None]
    y = y.reshape(bsz, di)

    y = _gated_rmsnorm(y, z, params["norm_scale"], cfg.norm_eps).astype(cdt)
    out = (y @ params["out_proj"].astype(cdt))[:, None, :]
    return out, {"conv": new_conv, "ssm": new_ssm}
