"""Basic layers: norms, RoPE, embeddings, dense FFNs.

All layers are (init, apply) function pairs over plain dict pytrees. The
``compute`` dtype is applied by the caller; norms always run in float32
for numerical stability and cast back.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp


def _dtype(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg, dim: Optional[int] = None) -> Dict:
    dim = dim or cfg.d_model
    params = {"scale": jnp.ones((dim,), dtype=_dtype(cfg.param_dtype))}
    if cfg.norm_kind == "layernorm":
        params["bias"] = jnp.zeros((dim,), dtype=_dtype(cfg.param_dtype))
    return params


def apply_norm(cfg, params: Dict, x: jax.Array) -> jax.Array:
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm_kind == "layernorm":
        mean = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        x = (x - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
        x = x * params["scale"].astype(jnp.float32)
        x = x + params["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        x = x * jax.lax.rsqrt(ms + cfg.norm_eps)
        x = x * params["scale"].astype(jnp.float32)
    return x.astype(orig_dtype)


def rms_norm_headwise(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """Per-head RMS norm for qk-norm (normalises the trailing head_dim)."""
    orig = x.dtype
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)
    return out.astype(orig)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # [head_dim/2]


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float
) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)            # [D/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., :, None, :]               # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def init_embedding(cfg, rng: jax.Array) -> Dict:
    scale = 1.0 / jnp.sqrt(jnp.float32(cfg.d_model))
    table = (
        jax.random.normal(rng, (cfg.vocab_size, cfg.d_model), dtype=jnp.float32)
        * scale
    ).astype(_dtype(cfg.param_dtype))
    return {"table": table}


def embed(cfg, params: Dict, tokens: jax.Array) -> jax.Array:
    out = jnp.take(params["table"], tokens, axis=0)
    return out.astype(_dtype(cfg.compute_dtype))


def unembed(cfg, params: Dict, x: jax.Array) -> jax.Array:
    """Project to vocab logits (tied or untied); returns float32 logits."""
    table = params["table"]
    logits = jnp.einsum(
        "...d,vd->...v", x.astype(jnp.float32), table.astype(jnp.float32)
    )
    if cfg.logit_softcap > 0:
        cap = cfg.logit_softcap
        logits = cap * jnp.tanh(logits / cap)
    return logits


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------


def _init_linear(rng, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / jnp.sqrt(jnp.float32(d_in))
    return (jax.random.normal(rng, (d_in, d_out), dtype=jnp.float32) * scale).astype(
        dtype
    )


def init_ffn(cfg, rng: jax.Array) -> Dict:
    dtype = _dtype(cfg.param_dtype)
    keys = jax.random.split(rng, 3)
    params: Dict = {}
    if cfg.mlp_kind in ("swiglu", "geglu"):
        params["w_gate"] = _init_linear(keys[0], cfg.d_model, cfg.d_ff, dtype)
        params["w_up"] = _init_linear(keys[1], cfg.d_model, cfg.d_ff, dtype)
        params["w_down"] = _init_linear(keys[2], cfg.d_ff, cfg.d_model, dtype)
    else:  # squared_relu | gelu
        params["w_up"] = _init_linear(keys[0], cfg.d_model, cfg.d_ff, dtype)
        params["w_down"] = _init_linear(keys[1], cfg.d_ff, cfg.d_model, dtype)
    return params


def apply_ffn(cfg, params: Dict, x: jax.Array) -> jax.Array:
    cdt = _dtype(cfg.compute_dtype)
    x = x.astype(cdt)
    if cfg.mlp_kind in ("swiglu", "geglu"):
        gate = x @ params["w_gate"].astype(cdt)
        up = x @ params["w_up"].astype(cdt)
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else jax.nn.gelu
        h = act(gate) * up
    elif cfg.mlp_kind == "squared_relu":
        h = x @ params["w_up"].astype(cdt)
        h = jnp.square(jax.nn.relu(h))
    elif cfg.mlp_kind == "gelu":
        h = x @ params["w_up"].astype(cdt)
        h = jax.nn.gelu(h)
    else:
        raise ValueError(f"unknown mlp_kind {cfg.mlp_kind!r}")
    return h @ params["w_down"].astype(cdt)
