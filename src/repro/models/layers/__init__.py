from repro.models.layers import attention, basic, moe, ssm  # noqa: F401
