"""Mixture-of-Experts FFN with top-k routing and capacity-bounded dispatch.

TPU-native design: all shapes are static. Tokens are routed by a linear
router, sorted by expert id, and packed into an ``[E, C, d]`` buffer; the
expert computation is then a *grouped matmul* (``ecd,edf->ecf``) that (a)
maps directly onto the MXU, (b) shards cleanly over the ``model`` axis as
expert parallelism (GSPMD inserts the all-to-alls), and (c) is the
contraction the Pallas ``moe_gmm`` kernel accelerates. Tokens over
capacity are dropped (standard Switch-style), with the usual auxiliary
load-balancing loss.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers.basic import _dtype, _init_linear


def init_moe(cfg, rng: jax.Array) -> Dict:
    dtype = _dtype(cfg.param_dtype)
    e, d, f = cfg.moe_experts, cfg.d_model, cfg.d_ff
    keys = jax.random.split(rng, 4)

    def expert_stack(key, d_in, d_out):
        scale = 1.0 / jnp.sqrt(jnp.float32(d_in))
        w = jax.random.normal(key, (e, d_in, d_out), dtype=jnp.float32) * scale
        return w.astype(dtype)

    params: Dict = {"router": _init_linear(keys[0], d, e, dtype)}
    if cfg.mlp_kind in ("swiglu", "geglu"):
        params["w_gate"] = expert_stack(keys[1], d, f)
        params["w_up"] = expert_stack(keys[2], d, f)
        params["w_down"] = expert_stack(keys[3], f, d)
    else:
        params["w_up"] = expert_stack(keys[1], d, f)
        params["w_down"] = expert_stack(keys[2], f, d)
    return params


def moe_capacity(cfg, n_tokens: int) -> int:
    cap = int(cfg.moe_capacity_factor * n_tokens * cfg.moe_top_k / cfg.moe_experts)
    return max(8, _round_up(cap, 8))


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def route(
    cfg, params: Dict, x2d: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Router logits → (top-k expert ids [T,k], gates [T,k], aux loss)."""
    logits = (x2d.astype(jnp.float32)) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # [T,E]
    gates, expert_ids = jax.lax.top_k(probs, cfg.moe_top_k)      # [T,k]
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # Load-balancing auxiliary loss (Switch-style: fraction-of-tokens ×
    # fraction-of-probability per expert).
    e = cfg.moe_experts
    one_hot = jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32)
    density = jnp.mean(one_hot, axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * e
    return expert_ids, gates, aux


def apply_moe(cfg, params: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: [..., d] → (out [..., d], aux loss scalar).

    Dispatch is **group-local**: tokens are split into G groups aligned
    with the data-parallel sharding (G = dp size at trace time, 1 on CPU),
    and the argsort/capacity/scatter machinery runs per group — so the
    sort and the token gather never cross devices. Only the grouped
    matmul's [G,E,...] ⇄ [E,G,...] resharding moves tokens (the EP
    all-to-all), which is the minimal traffic MoE requires. (§Perf: this
    replaced a global dispatch whose cross-device token gather dominated
    the collective roofline term 10:1.)
    """
    from repro.sharding.ctx import constrain, current_dp_size

    cdt = _dtype(cfg.compute_dtype)
    orig_shape = x.shape
    d = orig_shape[-1]
    x_flat = x.reshape(-1, d)
    t_total = x_flat.shape[0]
    g = current_dp_size()
    if t_total % g != 0:
        g = 1
    xg = x_flat.reshape(g, t_total // g, d)

    out_g, aux = jax.vmap(
        lambda xs: _moe_group(cfg, params, xs)
    )(xg)
    out = constrain(out_g, ("dp", None, None)).reshape(orig_shape).astype(cdt)
    return out, jnp.mean(aux).astype(jnp.float32)


def _moe_group(cfg, params: Dict, x2d: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Dispatch + expert FFN + combine for one token group. x2d: [T, d]."""
    cdt = _dtype(cfg.compute_dtype)
    d = x2d.shape[-1]
    t = x2d.shape[0]
    e, k = cfg.moe_experts, cfg.moe_top_k
    c = moe_capacity(cfg, t)

    expert_ids, gates, aux = route(cfg, params, x2d)

    # ---- dispatch: sort (token,k) pairs by expert, take position-in-expert.
    flat_expert = expert_ids.reshape(-1)                     # [T*k]
    flat_token = jnp.repeat(jnp.arange(t), k)                # [T*k]
    flat_gate = gates.reshape(-1)

    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]

    # Position of each routed pair within its expert's capacity buffer.
    ones = jnp.ones_like(sorted_expert)
    pos_in_expert = jnp.cumsum(ones) - 1
    expert_start = jnp.searchsorted(sorted_expert, jnp.arange(e), side="left")
    pos_in_expert = pos_in_expert - expert_start[sorted_expert]
    keep = pos_in_expert < c

    # Scatter tokens into the [E, C, d] buffer (dropped pairs go to a
    # sacrificial slot C which is sliced away).
    slot = jnp.where(keep, sorted_expert * (c + 1) + pos_in_expert,
                     sorted_expert * (c + 1) + c)
    buffer = jnp.zeros((e * (c + 1), d), dtype=cdt)
    buffer = buffer.at[slot].set(x2d[sorted_token].astype(cdt), mode="drop")
    buffer = buffer.reshape(e, c + 1, d)[:, :c, :]           # [E,C,d]

    # ---- expert computation: grouped matmul.
    if cfg.use_kernels:
        from repro.kernels.ops import moe_ffn_gmm

        h = moe_ffn_gmm(cfg, params, buffer)
    else:
        if cfg.mlp_kind in ("swiglu", "geglu"):
            gate_h = jnp.einsum("ecd,edf->ecf", buffer, params["w_gate"].astype(cdt))
            up_h = jnp.einsum("ecd,edf->ecf", buffer, params["w_up"].astype(cdt))
            act = jax.nn.silu if cfg.mlp_kind == "swiglu" else jax.nn.gelu
            h = act(gate_h) * up_h
        elif cfg.mlp_kind == "squared_relu":
            h = jnp.einsum("ecd,edf->ecf", buffer, params["w_up"].astype(cdt))
            h = jnp.square(jax.nn.relu(h))
        else:
            h = jnp.einsum("ecd,edf->ecf", buffer, params["w_up"].astype(cdt))
            h = jax.nn.gelu(h)
        h = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(cdt))

    # ---- combine: gather expert outputs back to (token, k) pairs.
    h_flat = h.reshape(e * c, d)
    gathered = jnp.where(
        keep[:, None],
        h_flat[jnp.clip(sorted_expert * c + pos_in_expert, 0, e * c - 1)],
        jnp.zeros((1, d), dtype=cdt),
    )
    weighted = gathered * sorted_gate[:, None].astype(cdt)
    out = jnp.zeros((t, d), dtype=cdt).at[sorted_token].add(weighted)
    return out, aux.astype(jnp.float32)
