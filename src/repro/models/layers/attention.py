"""Grouped-query attention with RoPE / qk-norm / bias variants + KV cache.

Three entry points:
  * :func:`attend_full`   — full-sequence causal (train / prefill);
  * :func:`attend_cached` — one-step decode against a KV cache;
  * :func:`attend_cross`  — encoder-decoder cross attention.

The full path optionally routes through the Pallas flash-attention kernel
(``cfg.use_kernels``); the jnp path is the XLA/GSPMD roofline baseline and
the oracle the kernel is validated against.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers.basic import (
    _dtype,
    _init_linear,
    apply_rope,
    rms_norm_headwise,
)

NEG_INF = -1e30


def init_attention(cfg, rng: jax.Array, *, cross: bool = False) -> Dict:
    dtype = _dtype(cfg.param_dtype)
    keys = jax.random.split(rng, 5)
    h, kv, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    params: Dict = {
        "wq": _init_linear(keys[0], d, h * hd, dtype),
        "wk": _init_linear(keys[1], d, kv * hd, dtype),
        "wv": _init_linear(keys[2], d, kv * hd, dtype),
        "wo": _init_linear(keys[3], h * hd, d, dtype),
    }
    if cfg.qkv_bias and not cross:
        params["bq"] = jnp.zeros((h * hd,), dtype)
        params["bk"] = jnp.zeros((kv * hd,), dtype)
        params["bv"] = jnp.zeros((kv * hd,), dtype)
    if cfg.qk_norm and not cross:
        params["q_norm"] = jnp.ones((hd,), dtype)
        params["k_norm"] = jnp.ones((hd,), dtype)
    return params


def _project_qkv(
    cfg,
    params: Dict,
    x: jax.Array,
    kv_input: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    *,
    use_rope: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    cdt = _dtype(cfg.compute_dtype)
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    x = x.astype(cdt)
    kv_src = x if kv_input is None else kv_input.astype(cdt)

    q = x @ params["wq"].astype(cdt)
    k = kv_src @ params["wk"].astype(cdt)
    v = kv_src @ params["wv"].astype(cdt)
    if "bq" in params:
        q = q + params["bq"].astype(cdt)
        k = k + params["bk"].astype(cdt)
        v = v + params["bv"].astype(cdt)

    q = q.reshape(*q.shape[:-1], h, hd)
    k = k.reshape(*k.shape[:-1], kv, hd)
    v = v.reshape(*v.shape[:-1], kv, hd)

    if "q_norm" in params:
        q = rms_norm_headwise(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm_headwise(k, params["k_norm"], cfg.norm_eps)

    if use_rope and cfg.pos_embedding == "rope" and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    mask: Optional[jax.Array],
) -> jax.Array:
    """q: [B,S,H,D]; k,v: [B,T,KV,D] — grouped-query dot-product attention."""
    b, s, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    group = h // kvh
    qg = q.reshape(b, s, kvh, group, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d))
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, d)


def attend_full(
    cfg,
    params: Dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
) -> jax.Array:
    """Full-sequence self attention. x: [B,S,D]; positions: [B,S]."""
    cdt = _dtype(cfg.compute_dtype)
    q, k, v = _project_qkv(cfg, params, x, positions=positions)
    s = x.shape[1]
    mask = None
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))[None, None, None, :, :]
    if cfg.use_kernels:
        from repro.kernels.ops import flash_attention

        out = flash_attention(q, k, v, causal=causal)
    else:
        out = _sdpa(q, k, v, mask)
    out = out.reshape(*out.shape[:-2], cfg.n_heads * cfg.head_dim)
    return out @ params["wo"].astype(cdt)


def attend_cached(
    cfg,
    params: Dict,
    x: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    position: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: [B,1,D]; cache_{k,v}: [B,T,KV,Dh]; position: [B].

    Returns (attn output [B,1,D], new cache_k, new cache_v). The new token's
    K/V are written at ``position``; attention masks out cache slots beyond
    ``position``.
    """
    cdt = _dtype(cfg.compute_dtype)
    q, k_new, v_new = _project_qkv(
        cfg, params, x, positions=position[:, None]
    )
    ref = cache_k["q"] if isinstance(cache_k, dict) else cache_k
    b, t = ref.shape[0], ref.shape[1]

    # In-place one-slot write (lowers to scatter; aliases under donation —
    # a full-cache select here would force whole-cache copies per layer).
    rows = jnp.arange(b)
    cache_k = write_kv(cfg, cache_k, k_new[:, 0], rows, position)
    cache_v = write_kv(cfg, cache_v, v_new[:, 0], rows, position)

    # Mask: only slots <= position are attendable.
    valid = (jnp.arange(t)[None, :] <= position[:, None])  # [B,T]
    mask = valid[:, None, None, None, :]  # [B,KV,G,1,T]
    out = _sdpa(q, dequant_kv(cache_k, cdt), dequant_kv(cache_v, cdt), mask)
    out = out.reshape(*out.shape[:-2], cfg.n_heads * cfg.head_dim)
    return out @ params["wo"].astype(cdt), cache_k, cache_v


def attend_cross(
    cfg,
    params: Dict,
    x: jax.Array,
    enc_out: jax.Array,
) -> jax.Array:
    """Cross attention (decoder query, encoder memory); no mask, no rope."""
    cdt = _dtype(cfg.compute_dtype)
    q, k, v = _project_qkv(cfg, params, x, kv_input=enc_out, use_rope=False)
    out = _sdpa(q, k, v, None)
    out = out.reshape(*out.shape[:-2], cfg.n_heads * cfg.head_dim)
    return out @ params["wo"].astype(cdt)


def init_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """KV cache pair. With ``cfg.kv_cache_dtype == "int8"`` each of K/V is
    a dict {"q": int8 [B,T,KV,D], "scale": f32 [B,T,KV,1]} (per-token,
    per-head absmax quantisation) — halving decode's dominant HBM term."""
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    if cfg.kv_cache_dtype == "int8":
        def q8():
            return {
                "q": jnp.zeros(shape, jnp.int8),
                "scale": jnp.zeros(shape[:-1] + (1,), jnp.float32),
            }
        return q8(), q8()
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def quant_kv(x: jax.Array):
    """Per-(token, head) absmax int8 quantisation of K or V rows."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(
        jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0, 1e-20
    )
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale}


def dequant_kv(c, dtype) -> jax.Array:
    if isinstance(c, dict):
        return (c["q"].astype(jnp.float32) * c["scale"]).astype(dtype)
    return c.astype(dtype)


def write_kv(cfg, cache, new: jax.Array, rows, position):
    """Write one token's K or V into the cache at [rows, position]."""
    if isinstance(cache, dict):
        enc = quant_kv(new)
        return {
            "q": cache["q"].at[rows, position].set(enc["q"]),
            "scale": cache["scale"].at[rows, position].set(enc["scale"]),
        }
    return cache.at[rows, position].set(new.astype(cache.dtype))


def write_kv_prefix(cfg, cache, new: jax.Array, length: int):
    """Write the first ``length`` positions (prefill path)."""
    if isinstance(cache, dict):
        enc = quant_kv(new)
        return {
            "q": cache["q"].at[:, :length].set(enc["q"]),
            "scale": cache["scale"].at[:, :length].set(enc["scale"]),
        }
    return cache.at[:, :length].set(new.astype(cache.dtype))
