"""chameleon-34b [vlm] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early-fusion, VQ image tokens. [arXiv:2405.09818; unverified]

Early fusion: images are VQ-tokenised into the shared 65536 vocab, so the
backbone is a plain decoder LM; the VQ tokenizer frontend is a STUB
(input_specs provides token ids that may be text or image codes).
Chameleon uses qk-norm for training stability.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="lm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=10_000.0,
    frontend="vq_stub",
    remat="full",
)
