"""Architecture configs (one module per assigned arch) + registry."""
from repro.configs.registry import ALIASES, ARCH_IDS, all_configs, get_config, smoke_config

__all__ = ["ALIASES", "ARCH_IDS", "all_configs", "get_config", "smoke_config"]
