"""smollm-135m [dense] — 30L d_model=576 9H (GQA kv=3) d_ff=1536
vocab=49152 — llama-arch small. [hf:HuggingFaceTB/SmolLM-135M; hf]

Also the ~100M end-to-end training example (examples/train_smollm.py).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="lm",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
    remat="full",
)
