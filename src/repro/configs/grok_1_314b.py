"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8e top-2. [hf:xai-org/grok-1; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="lm",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    mlp_kind="geglu",
    norm_kind="rmsnorm",
    moe_experts=8,
    moe_top_k=2,
    logit_softcap=30.0,
    remat="full",
)
