"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16e top-2. [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="lm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    mlp_kind="swiglu",
    norm_kind="layernorm",
    moe_experts=16,
    moe_top_k=2,
    remat="full",
)
