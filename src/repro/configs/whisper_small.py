"""whisper-small [audio] — 12L d_model=768 12H (kv=12) d_ff=3072
vocab=51865 — enc-dec, conv frontend (STUB). [arXiv:2212.04356; unverified]

The log-mel + conv2 frontend is a stub: input_specs() provides precomputed
frame embeddings [B, S_enc, d_model]. Learned positions, LayerNorm, GELU.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    qkv_bias=True,
    mlp_kind="gelu",
    norm_kind="layernorm",
    pos_embedding="learned",
    max_position=32768,
    tie_embeddings=True,
    frontend="audio_stub",
    remat="full",
)
