"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE.
[arXiv:2403.19887; hf]

Period-8 pattern: 1 attention layer (index 3) + 7 Mamba-2 layers; every
other layer's FFN is MoE (16 experts, top-2). ssm_state=128.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    moe_experts=16,
    moe_top_k=2,
    moe_every=2,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    attn_every=8,
    attn_index=3,
    remat="full",
)
