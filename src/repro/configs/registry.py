"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs.

Full configs are exercised only through the dry-run (ShapeDtypeStruct, no
allocation); ``smoke_config()`` shrinks a config to CPU scale while keeping
the family/pattern/variants intact, for the per-arch smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "qwen1_5_0_5b",
    "nemotron_4_15b",
    "qwen3_14b",
    "smollm_135m",
    "chameleon_34b",
    "jamba_1_5_large_398b",
    "whisper_small",
    "grok_1_314b",
    "phi3_5_moe_42b",
    "mamba2_2_7b",
]

#: Aliases accepted on the CLI (the assignment's spelling).
ALIASES: Dict[str, str] = {
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen3-14b": "qwen3_14b",
    "smollm-135m": "smollm_135m",
    "chameleon-34b": "chameleon_34b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "whisper-small": "whisper_small",
    "grok-1-314b": "grok_1_314b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "mamba2-2.7b": "mamba2_2_7b",
}


def get_config(arch: str) -> ModelConfig:
    arch_id = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if arch_id not in ARCH_IDS:
        raise KeyError(
            f"unknown architecture {arch!r}; known: {ARCH_IDS} "
            f"(aliases: {sorted(ALIASES)})"
        )
    module = importlib.import_module(f"repro.configs.{arch_id}")
    return module.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {arch_id: get_config(arch_id) for arch_id in ARCH_IDS}


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config: small widths/depths/vocab for CPU."""
    cfg = get_config(arch)
    period = cfg.period
    n_layers = 2 * period
    kv = min(cfg.n_kv_heads, 2)
    heads = max(kv * 2, 2)
    head_dim = 16
    updates = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=head_dim,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        max_position=cfg.max_position and 128,
        encoder_layers=2 if cfg.encoder_layers else 0,
    )
    if cfg.moe_experts:
        updates["moe_experts"] = 4
    if cfg.ssm_state:
        updates.update(ssm_state=16, ssm_headdim=8, ssm_chunk=8)
    return dataclasses.replace(cfg, **updates)
