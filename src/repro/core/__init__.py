"""The paper's primary contribution: the tAPP language (``repro.core.tapp``),
the topology-aware scheduler (``repro.core.scheduler``), and the evaluation
simulator (``repro.core.sim``).

The data plane that these schedule — models, kernels, sharding, serving —
lives in the sibling subpackages of :mod:`repro`.
"""
from repro.core import platform, scheduler, sim, tapp

__all__ = ["platform", "scheduler", "sim", "tapp"]
