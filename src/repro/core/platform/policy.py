"""Policy lifecycle types: dry-run reports, versioned handles, errors.

The platform treats a tAPP script like a deployment artifact: it is
parsed, **dry-run against the live topology** (unknown controllers /
worker labels / set labels, contradictory affinity lists), compiled,
**statically analyzed** (reachability / satisfiability / starvation, the
questions of arXiv:2407.14159 answered at apply time by
:mod:`repro.core.analysis`), and only then atomically swapped in — with a
bounded history so ``rollback`` can restore the previous policy
bit-for-bit. The findings surface *before* the script starts steering
live traffic; strict mode additionally treats analyzer *proofs* (tags no
admission sequence can ever place) as deploy blockers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.analysis import AnalysisReport
from repro.core.tapp.ast import TappScript
from repro.core.tapp.validate import Finding, ValidationReport


class PolicyError(ValueError):
    """A policy could not be applied / rolled back."""

    def __init__(self, message: str, findings: Sequence[Finding] = ()) -> None:
        self.findings = tuple(findings)
        if self.findings:
            detail = "; ".join(str(f) for f in self.findings)
            message = f"{message}: {detail}"
        super().__init__(message)


# Render order: grammar-level first, then live-topology checks, then the
# static-analysis categories (unknown categories sort last, in input order).
_CATEGORY_ORDER = (
    "structure",
    "topology",
    "constraint",
    "reachability",
    "satisfiability",
    "starvation",
)


@dataclasses.dataclass(frozen=True)
class PolicyDryRun:
    """What applying a script *would* do, checked against live topology."""

    report: ValidationReport
    # Topology snapshot the script was checked against (for the record).
    known_zones: Tuple[str, ...]
    known_sets: Tuple[str, ...]
    known_controllers: Tuple[str, ...]
    # Static plan analysis (reachability/satisfiability/starvation); None
    # when the script could not be lowered (the interpreter path accepts
    # scripts the compiler cannot — lowering failures never reject there).
    analysis: Optional[AnalysisReport] = None
    # Analysis of the brownout-degraded plan (PR 9): scripts declaring
    # ``on-overload: relax-affinity|any-zone`` pre-compile a degraded
    # variant that live traffic may be re-routed through under sustained
    # saturation, so it is verified at apply time exactly like the
    # primary plan — a brownout can never swap in a proven-unplaceable
    # policy. None when no tag opts in.
    degraded_analysis: Optional[AnalysisReport] = None

    @property
    def findings(self) -> Tuple[Finding, ...]:
        found = tuple(self.report.findings)
        if self.analysis is not None:
            found += tuple(self.analysis.findings)
        if self.degraded_analysis is not None:
            found += tuple(
                dataclasses.replace(f, where=f"on-overload:{f.where}")
                for f in self.degraded_analysis.findings
            )
        return found

    @property
    def errors(self) -> Tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.level == "error")

    @property
    def warnings(self) -> Tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.level == "warning")

    @property
    def topology_findings(self) -> Tuple[Finding, ...]:
        """References that match nothing in the live deployment."""
        return self._category("topology")

    @property
    def constraint_findings(self) -> Tuple[Finding, ...]:
        """Unsatisfiable constraint combinations (affinity ∩ anti-affinity)."""
        return self._category("constraint")

    @property
    def reachability_findings(self) -> Tuple[Finding, ...]:
        """Dead blocks / unplaceable tags proven by the static analyzer."""
        return self._category("reachability")

    @property
    def satisfiability_findings(self) -> Tuple[Finding, ...]:
        """Per-item contradictions and empty static survivor sets."""
        return self._category("satisfiability")

    @property
    def starvation_findings(self) -> Tuple[Finding, ...]:
        """Tags whose static admission bound undercuts the declared floor."""
        return self._category("starvation")

    @property
    def proofs(self) -> Tuple[Finding, ...]:
        """Analyzer-proved findings (strict-mode deploy blockers)."""
        return tuple(f for f in self.findings if f.proof)

    def _category(self, category: str) -> Tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.category == category)

    @property
    def ok(self) -> bool:
        """No structural errors (lenient mode: warnings are advisory)."""
        return not self.errors

    def ok_strict(self) -> bool:
        """No errors, no topology/constraint findings, no analyzer proofs.

        Strict mode treats a dangling reference — or a *proof* that a tag
        can never be placed — as a deploy blocker rather than a runtime
        no-match: the right default for production rollouts where set
        membership is not expected to be in flux.
        """
        return (
            self.ok
            and not self.topology_findings
            and not self.constraint_findings
            and not self.proofs
        )

    def blocking(self, *, strict: bool) -> Tuple[Finding, ...]:
        """The findings that reject the apply under the given mode."""
        if strict:
            return tuple(
                self.errors
                + self.topology_findings
                + self.constraint_findings
                + self.proofs
            )
        return self.errors

    def raise_for(self, *, strict: bool) -> None:
        blocking = self.blocking(strict=strict)
        if blocking:
            raise PolicyError("policy rejected by dry-run", blocking)

    def render(self) -> str:
        """Findings grouped by category, every line carrying its tag/block.

        Finding ``where`` strings are already structured
        (``tag:<tag>.block[<i>].workers[<j>]``), so grouping by category
        makes the output actionable without reading the script
        side-by-side.
        """
        lines = [
            f"dry-run against zones={list(self.known_zones)} "
            f"sets={list(self.known_sets)} "
            f"controllers={list(self.known_controllers)}"
        ]
        findings = self.findings
        if not findings:
            lines.append("no findings")
        else:
            groups: Dict[str, List[Finding]] = {}
            for f in findings:
                groups.setdefault(f.category, []).append(f)
            ordered = [c for c in _CATEGORY_ORDER if c in groups]
            ordered.extend(c for c in groups if c not in _CATEGORY_ORDER)
            for category in ordered:
                lines.append(f"{category}:")
                lines.extend(f"  {f}" for f in groups[category])
        if self.analysis is not None:
            lines.append(self.analysis.summary())
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class PolicyHandle:
    """One applied policy version (what ``rollback`` restores)."""

    version: int               # the watcher's script version when published
    script: TappScript         # the published (version-stamped) script
    source: Optional[str]      # YAML text when applied from text
    dry_run: PolicyDryRun      # the report the apply was gated on

    @property
    def tag_names(self) -> Tuple[str, ...]:
        return tuple(t.tag for t in self.script.tags)
