"""Policy lifecycle types: dry-run reports, versioned handles, errors.

The platform treats a tAPP script like a deployment artifact: it is
parsed, **dry-run against the live topology** (unknown controllers /
worker labels / set labels, contradictory affinity lists), compiled, and
only then atomically swapped in — with a bounded history so ``rollback``
can restore the previous policy bit-for-bit. This is where the static
checking of the reachability line of work (arXiv:2407.14159) gets an
ergonomic home: the findings surface *before* the script starts steering
live traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.core.tapp.ast import TappScript
from repro.core.tapp.validate import Finding, ValidationReport


class PolicyError(ValueError):
    """A policy could not be applied / rolled back."""

    def __init__(self, message: str, findings: Sequence[Finding] = ()) -> None:
        self.findings = tuple(findings)
        if self.findings:
            detail = "; ".join(str(f) for f in self.findings)
            message = f"{message}: {detail}"
        super().__init__(message)


@dataclasses.dataclass(frozen=True)
class PolicyDryRun:
    """What applying a script *would* do, checked against live topology."""

    report: ValidationReport
    # Topology snapshot the script was checked against (for the record).
    known_zones: Tuple[str, ...]
    known_sets: Tuple[str, ...]
    known_controllers: Tuple[str, ...]

    @property
    def findings(self) -> Tuple[Finding, ...]:
        return tuple(self.report.findings)

    @property
    def errors(self) -> Tuple[Finding, ...]:
        return tuple(self.report.errors)

    @property
    def warnings(self) -> Tuple[Finding, ...]:
        return tuple(self.report.warnings)

    @property
    def topology_findings(self) -> Tuple[Finding, ...]:
        """References that match nothing in the live deployment."""
        return tuple(
            f for f in self.report.findings if f.category == "topology"
        )

    @property
    def constraint_findings(self) -> Tuple[Finding, ...]:
        """Unsatisfiable constraint combinations (affinity ∩ anti-affinity)."""
        return tuple(
            f for f in self.report.findings if f.category == "constraint"
        )

    @property
    def ok(self) -> bool:
        """No structural errors (lenient mode: warnings are advisory)."""
        return self.report.ok

    def ok_strict(self) -> bool:
        """No errors AND no topology/constraint findings.

        Strict mode treats a dangling reference as a deploy blocker rather
        than a runtime no-match — the right default for production rollouts
        where set membership is not expected to be in flux.
        """
        return self.ok and not self.topology_findings and not self.constraint_findings

    def blocking(self, *, strict: bool) -> Tuple[Finding, ...]:
        """The findings that reject the apply under the given mode."""
        if strict:
            return tuple(
                self.errors + self.topology_findings + self.constraint_findings
            )
        return self.errors

    def raise_for(self, *, strict: bool) -> None:
        blocking = self.blocking(strict=strict)
        if blocking:
            raise PolicyError("policy rejected by dry-run", blocking)

    def render(self) -> str:
        lines = [
            f"dry-run against zones={list(self.known_zones)} "
            f"sets={list(self.known_sets)} "
            f"controllers={list(self.known_controllers)}"
        ]
        if not self.findings:
            lines.append("no findings")
        lines.extend(str(f) for f in self.findings)
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class PolicyHandle:
    """One applied policy version (what ``rollback`` restores)."""

    version: int               # the watcher's script version when published
    script: TappScript         # the published (version-stamped) script
    source: Optional[str]      # YAML text when applied from text
    dry_run: PolicyDryRun      # the report the apply was gated on

    @property
    def tag_names(self) -> Tuple[str, ...]:
        return tuple(t.tag for t in self.script.tags)
