"""The Platform API: the paper's tAPP platform behind one typed façade.

>>> from repro.core.platform import ClusterSpec, ControllerSpec, TappPlatform, WorkerSpec
>>> platform = TappPlatform(ClusterSpec(
...     controllers=(ControllerSpec("EdgeCtl", zone="edge"),),
...     workers=(WorkerSpec("w0", zone="edge", sets=("edge", "any")),),
... ))
>>> platform.apply_policy("- default:\\n  - workers:\\n    - set:\\n")
... # doctest: +SKIP
>>> placement = platform.invoke("my_fn")  # doctest: +SKIP
>>> placement.complete()                  # doctest: +SKIP

Multi-zone deployments federate per-zone entrypoints over the same core
(see the README "Federation" section):

>>> from repro.core.platform import FederationSpec, TappFederation
>>> federation = TappFederation(FederationSpec.of({  # doctest: +SKIP
...     "edge": ClusterSpec(...), "cloud": ClusterSpec(...),
... }))
>>> federation.invoke("my_fn", entry_zone="edge")    # doctest: +SKIP
"""
from repro.core.platform.explain import (
    BlockReport,
    CandidateReport,
    ExplainReport,
    FederationExplainReport,
    ZoneHopReport,
    build_explain_report,
)
from repro.core.platform.facade import (
    Placement,
    PlatformCore,
    PlatformStats,
    TappPlatform,
    UnknownWorkerError,
)
from repro.core.platform.faults import (
    ChaosSpec,
    FaultEvent,
    FaultInjector,
)
from repro.core.platform.lifecycle import (
    InstancePool,
    InstanceState,
    LegacyWarmCache,
    LifecycleManager,
    LifecycleSpec,
)
from repro.core.platform.federation import (
    FederatedPlacement,
    FederationStats,
    ForwardHop,
    TappFederation,
    ZoneStats,
)
from repro.core.platform.overload import (
    AdmissionQueue,
    BreakerSpec,
    BrownoutController,
    BrownoutSpec,
    CircuitBreaker,
    OverloadSpec,
    QueueSpec,
    degrade_script,
)
from repro.core.platform.policy import (
    PolicyDryRun,
    PolicyError,
    PolicyHandle,
)
from repro.core.platform.specs import (
    ClusterSpec,
    ControllerSpec,
    FederationSpec,
    RetryPolicy,
    WorkerSpec,
)
from repro.core.scheduler.state import HealthState
from repro.core.scheduler.watcher import HealthTransition, LeaseConfig

__all__ = [
    "AdmissionQueue",
    "BlockReport",
    "BreakerSpec",
    "BrownoutController",
    "BrownoutSpec",
    "CandidateReport",
    "ChaosSpec",
    "CircuitBreaker",
    "ClusterSpec",
    "ControllerSpec",
    "ExplainReport",
    "FaultEvent",
    "FaultInjector",
    "FederatedPlacement",
    "FederationExplainReport",
    "FederationSpec",
    "FederationStats",
    "ForwardHop",
    "HealthState",
    "HealthTransition",
    "InstancePool",
    "InstanceState",
    "LeaseConfig",
    "LegacyWarmCache",
    "LifecycleManager",
    "LifecycleSpec",
    "OverloadSpec",
    "Placement",
    "PlatformCore",
    "PlatformStats",
    "PolicyDryRun",
    "PolicyError",
    "PolicyHandle",
    "QueueSpec",
    "RetryPolicy",
    "TappFederation",
    "TappPlatform",
    "UnknownWorkerError",
    "WorkerSpec",
    "ZoneHopReport",
    "ZoneStats",
    "build_explain_report",
]
