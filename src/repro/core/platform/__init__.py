"""The Platform API: the paper's tAPP platform behind one typed façade.

>>> from repro.core.platform import ClusterSpec, ControllerSpec, TappPlatform, WorkerSpec
>>> platform = TappPlatform(ClusterSpec(
...     controllers=(ControllerSpec("EdgeCtl", zone="edge"),),
...     workers=(WorkerSpec("w0", zone="edge", sets=("edge", "any")),),
... ))
>>> platform.apply_policy("- default:\\n  - workers:\\n    - set:\\n")
... # doctest: +SKIP
>>> placement = platform.invoke("my_fn")  # doctest: +SKIP
>>> placement.complete()                  # doctest: +SKIP
"""
from repro.core.platform.explain import (
    BlockReport,
    CandidateReport,
    ExplainReport,
    build_explain_report,
)
from repro.core.platform.facade import (
    Placement,
    PlatformStats,
    TappPlatform,
)
from repro.core.platform.policy import (
    PolicyDryRun,
    PolicyError,
    PolicyHandle,
)
from repro.core.platform.specs import ClusterSpec, ControllerSpec, WorkerSpec

__all__ = [
    "BlockReport",
    "CandidateReport",
    "ClusterSpec",
    "ControllerSpec",
    "ExplainReport",
    "Placement",
    "PlatformStats",
    "PolicyDryRun",
    "PolicyError",
    "PolicyHandle",
    "TappPlatform",
    "WorkerSpec",
    "build_explain_report",
]
