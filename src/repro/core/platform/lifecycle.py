"""Warm-pool instance lifecycle (PR 10): cold-start-aware scheduling.

Serverless latency is dominated by *cold starts*: provisioning a fresh
function instance costs orders of magnitude more than dispatching onto
one that is already provisioned and idle. The scheduler through PR 9
decides *where* a function runs but models every placement identically —
the simulator kept a private per-worker warm-container cache
(``FunctionProfile.warm_ttl``), invisible to routing, so a policy could
not prefer a worker holding a warm instance over one that would pay the
cold start.

This module supplies the platform-level instance model, **opt-in** and
off by default (the PR 9 discipline): with no :class:`LifecycleSpec`
configured, placements, traces, RNG streams, cursors, and ledger
counters are bit-identical to the pre-lifecycle platform
(property-tested in ``tests/test_lifecycle.py``).

* :class:`LifecycleSpec` — the keep-alive window (how long a completed
  instance stays reusable) plus an optional per-pool idle cap.
* :class:`InstancePool` — the per-(worker, function) pool with the
  COLD → WARM → IDLE → TERM state machine: an instance is born COLD
  (spawned for an admission that found nothing reusable), parks IDLE on
  completion with an expiry deadline, is reused WARM by a later
  admission (most-recently-used first, the OpenWhisk/Knative shape),
  and terminates TERM when the janitor expires it, the idle cap evicts
  it, or its worker leaves.
* :class:`LifecycleManager` — the armed platform's pool table plus the
  deterministic clock-driven expiration janitor. Fed by the admission
  ledger: ``record_admission`` spawns-or-reuses an instance
  (:meth:`~LifecycleManager.on_admit`), ``Placement.complete()`` parks
  it (:meth:`~LifecycleManager.on_complete`). The manager maintains
  each worker's ``warm_idle`` map — the O(1) warm-first signal the
  engine's ``warm-first`` strategy reads — and emits warmth journal
  events (``ClusterState.note_worker_warmth``) so the compiled engine's
  per-function warm bitmask (``ItemIndex.warm_mask``) stays
  incrementally synced without rebuilds. The janitor never reads a wall
  clock: every deadline check takes an explicit ``now`` (the
  ``check_leases`` discipline), so seeded runs reproduce bit-for-bit.
* :class:`LegacyWarmCache` — a bit-for-bit compat shim of the
  simulator's pre-lifecycle warm table (warm iff ``now - last_end <=
  warm_ttl``, non-consuming, forgotten on worker crash), kept so the
  unarmed simulator path reproduces historical scenario results exactly
  while ``FunctionProfile.warm_ttl`` goes through its deprecation
  cycle.

Keep-alive resolution per completed instance: the worker's
``keep_alive`` override, else the routed controller's
(:class:`~repro.core.platform.specs.ControllerSpec` — platform
configuration, adopted like its retry policy), else the spec default.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
import threading
from typing import Dict, List, Optional, Tuple

from repro.core.scheduler.state import ClusterState, WorkerState
from repro.core.scheduler.strategy import stable_hash

__all__ = [
    "InstancePool",
    "InstanceState",
    "LegacyWarmCache",
    "LifecycleManager",
    "LifecycleSpec",
]


class InstanceState(enum.Enum):
    """One function instance's lifecycle state."""

    COLD = "cold"  # spawning: provisioned for an admission that missed the pool
    WARM = "warm"  # provisioned and busy (reused from the idle pool)
    IDLE = "idle"  # provisioned, not running; reusable until its deadline
    TERM = "term"  # expired / evicted; never reused


@dataclasses.dataclass(frozen=True)
class LifecycleSpec:
    """Warm-pool configuration (per platform; workers/controllers override).

    ``keep_alive`` is how long (seconds) a completed instance stays IDLE
    and reusable before the janitor terminates it — the OpenWhisk
    warm-container TTL, but platform-owned and scheduler-visible.
    ``max_idle`` caps the idle instances one (worker, function) pool may
    hold; a completion into a full pool terminates the instance
    immediately (0: never pool — every admission is a cold start).
    """

    keep_alive: float = 600.0
    max_idle: Optional[int] = None

    def __post_init__(self) -> None:
        if self.keep_alive <= 0:
            raise ValueError(
                f"keep_alive must be positive, got {self.keep_alive}"
            )
        if self.max_idle is not None and self.max_idle < 0:
            raise ValueError(
                f"max_idle must be non-negative, got {self.max_idle}"
            )


class InstancePool:
    """The instances of one function on one worker.

    ``busy`` maps instance id → COLD/WARM (provisioned, running a
    request); ``idle`` is a stack of ``(iid, deadline)`` — reuse pops
    the top (most recently parked, the entry most likely still paged
    in), expiry trims from the bottom. The pool pins the live
    :class:`WorkerState` it was built against, so a later worker
    re-using the name can never inherit a dead incarnation's instances.
    """

    __slots__ = ("worker", "function", "fhash", "busy", "idle")

    def __init__(self, worker: WorkerState, function: str) -> None:
        self.worker = worker
        self.function = function
        # Same hash the engine caches on Invocation — the key warm-first
        # reads back out of worker.warm_idle / ItemIndex.warm_mask.
        self.fhash = stable_hash(function)
        self.busy: Dict[int, InstanceState] = {}
        self.idle: List[Tuple[int, Optional[float]]] = []


class LifecycleManager:
    """Pool table + expiration janitor of an armed platform.

    All mutation happens under one manager lock; within it, each
    worker's ``warm_idle`` entry is updated *before* the warmth journal
    event is emitted, so an index replaying the journal always reads
    the post-transition state (the same discipline the load journal
    uses). Counters are monotonic; ``snapshot()`` reads them
    consistently.
    """

    def __init__(self, spec: LifecycleSpec, cluster: ClusterState) -> None:
        self._spec = spec
        self._cluster = cluster
        self._lock = threading.Lock()
        self._pools: Dict[Tuple[str, str], InstancePool] = {}
        # Lazy-deleted expiry heap: entries are (deadline, iid, worker,
        # function); an entry is live iff the iid's *current* idle
        # deadline still equals the entry's (a reused-then-reparked
        # instance leaves its stale entry behind to be skipped).
        self._expiry: List[Tuple[float, int, str, str]] = []
        self._idle_deadline: Dict[int, float] = {}
        self._iid = itertools.count(1)
        self._controller_keep_alive: Dict[str, float] = {}
        self.cold_starts = 0
        self.warm_hits = 0
        self.expirations = 0

    @property
    def spec(self) -> LifecycleSpec:
        return self._spec

    # -- configuration (adopted from controller specs, like retry) ----------

    def set_controller_keep_alive(self, name: str, keep_alive: float) -> None:
        if keep_alive <= 0:
            raise ValueError(
                f"keep_alive must be positive, got {keep_alive}"
            )
        with self._lock:
            self._controller_keep_alive[name] = keep_alive

    def forget_controller(self, name: str) -> None:
        with self._lock:
            self._controller_keep_alive.pop(name, None)

    # -- warmth signal maintenance ------------------------------------------

    def _set_idle_count(self, worker: WorkerState, fhash: int,
                        count: int) -> None:
        """Publish a pool's idle count into the worker's ``warm_idle``
        map, emitting a warmth journal event on 0 ↔ nonzero flips (the
        only transitions that change any warm bitmask). The map write
        lands before the journal note, so replays read the new state."""
        warm_idle = worker.warm_idle
        prev = warm_idle.get(fhash, 0)
        if count > 0:
            warm_idle[fhash] = count
        elif prev:
            del warm_idle[fhash]
        if (prev == 0) != (count == 0):
            self._cluster.note_worker_warmth(worker.name, fhash)

    def _pool(self, worker: WorkerState, function: str) -> InstancePool:
        key = (worker.name, function)
        pool = self._pools.get(key)
        if pool is None or pool.worker is not worker:
            # First admission, or the name was re-used by a fresh
            # incarnation (the old pool died with forget_worker).
            pool = self._pools[key] = InstancePool(worker, function)
        return pool

    # -- admission-ledger hooks ---------------------------------------------

    def on_admit(self, worker: WorkerState, function: str) -> bool:
        """An admission ticket was taken: reuse the most recently parked
        idle instance (→ WARM) or spawn a new one (→ COLD). Returns
        whether the placement hit a warm instance."""
        with self._lock:
            pool = self._pool(worker, function)
            idle = pool.idle
            if idle:
                iid, _deadline = idle.pop()
                self._idle_deadline.pop(iid, None)
                pool.busy[iid] = InstanceState.WARM
                self.warm_hits += 1
                self._set_idle_count(worker, pool.fhash, len(idle))
                return True
            pool.busy[next(self._iid)] = InstanceState.COLD
            self.cold_starts += 1
            return False

    def on_complete(
        self,
        worker: WorkerState,
        function: str,
        controller: Optional[str] = None,
        now: Optional[float] = None,
    ) -> None:
        """A ticket retired: park its instance IDLE with a keep-alive
        deadline (worker override > controller override > spec default).
        Without a clock (``now`` is None) the instance never expires —
        the armed-but-clockless path tests pin against. A full pool
        (``max_idle``) terminates the instance instead of parking it."""
        with self._lock:
            pool = self._pools.get((worker.name, function))
            if pool is None or pool.worker is not worker or not pool.busy:
                # The instance died with its worker (crash/deregister
                # already forgot the pool); the ledger reconciled it.
                return
            iid, _state = pool.busy.popitem()
            max_idle = self._spec.max_idle
            if max_idle is not None and len(pool.idle) >= max_idle:
                self.expirations += 1  # idle-cap eviction is a TERM too
                if not pool.busy and not pool.idle:
                    del self._pools[(worker.name, function)]
                return
            keep = worker.keep_alive
            if keep is None and controller is not None:
                keep = self._controller_keep_alive.get(controller)
            if keep is None:
                keep = self._spec.keep_alive
            deadline = None if now is None else float(now) + keep
            pool.idle.append((iid, deadline))
            if deadline is not None:
                self._idle_deadline[iid] = deadline
                heapq.heappush(
                    self._expiry, (deadline, iid, worker.name, function)
                )
            self._set_idle_count(worker, pool.fhash, len(pool.idle))

    # -- janitor --------------------------------------------------------------

    def expire(self, now: float) -> int:
        """Terminate every idle instance whose deadline is ≤ ``now``.

        Deterministic: instances expire in (deadline, iid) order, and
        only against the explicit clock — the platform runs this lazily
        from ``invoke``/``complete`` when given ``now``, and callers
        may tick it directly (``expire_instances``). Returns the number
        of instances terminated."""
        expired = 0
        with self._lock:
            heap = self._expiry
            deadlines = self._idle_deadline
            while heap and heap[0][0] <= now:
                deadline, iid, wname, function = heapq.heappop(heap)
                if deadlines.get(iid) != deadline:
                    continue  # stale entry: instance was reused meanwhile
                del deadlines[iid]
                pool = self._pools.get((wname, function))
                if pool is None:
                    continue  # pool already forgotten with its worker
                for index, (pid, _dl) in enumerate(pool.idle):
                    if pid == iid:
                        del pool.idle[index]
                        break
                else:
                    continue
                self.expirations += 1
                expired += 1
                self._set_idle_count(pool.worker, pool.fhash, len(pool.idle))
                if not pool.idle and not pool.busy:
                    del self._pools[(wname, function)]
        return expired

    def next_deadline(self) -> Optional[float]:
        """The earliest live expiry deadline (None: nothing expires) —
        the simulator uses it to schedule janitor ticks exactly."""
        with self._lock:
            heap = self._expiry
            deadlines = self._idle_deadline
            while heap and deadlines.get(heap[0][1]) != heap[0][0]:
                heapq.heappop(heap)  # shed stale entries on the way
            return heap[0][0] if heap else None

    # -- topology churn -------------------------------------------------------

    def forget_worker(self, name: str) -> None:
        """A worker left (deregistration or DEAD transition): its
        instances die with it. Pools are dropped, the worker's warmth
        signal is cleared (journal events emitted for the flips), and
        the heap's stale entries are left for lazy deletion."""
        with self._lock:
            for key in [k for k in self._pools if k[0] == name]:
                pool = self._pools.pop(key)
                for iid, _deadline in pool.idle:
                    self._idle_deadline.pop(iid, None)
                if pool.idle:
                    self._set_idle_count(pool.worker, pool.fhash, 0)

    # -- observability --------------------------------------------------------

    def snapshot(self) -> Dict[str, int]:
        """Monotonic counters + current pool occupancy, consistently."""
        with self._lock:
            idle = busy = 0
            for pool in self._pools.values():
                idle += len(pool.idle)
                busy += len(pool.busy)
            return {
                "cold_starts": self.cold_starts,
                "warm_hits": self.warm_hits,
                "expirations": self.expirations,
                "idle_instances": idle,
                "busy_instances": busy,
                "pools": len(self._pools),
            }

    def pool_sizes(self) -> Dict[Tuple[str, str], Tuple[int, int]]:
        """(worker, function) → (idle, busy) instance counts."""
        with self._lock:
            return {
                key: (len(pool.idle), len(pool.busy))
                for key, pool in sorted(self._pools.items())
            }


class LegacyWarmCache:
    """Bit-for-bit shim of the simulator's pre-lifecycle warm table.

    The historical model (``FunctionProfile.warm_ttl``): a worker is
    warm for a function iff some earlier execution *ended* within the
    TTL. Non-consuming (one warm entry serves any number of concurrent
    reuses), touched with the execution's end time, and forgotten when
    the worker crashes. The unarmed simulator path keeps using exactly
    this model — pinned by regression tests — while ``warm_ttl`` is
    deprecated in favour of :class:`LifecycleSpec`.
    """

    __slots__ = ("_last",)

    def __init__(self) -> None:
        self._last: Dict[Tuple[str, str], float] = {}

    def is_warm(self, worker: str, function: str, now: float,
                ttl: float) -> bool:
        last = self._last.get((worker, function))
        return last is not None and (now - last) <= ttl

    def touch(self, worker: str, function: str, end_time: float) -> None:
        self._last[(worker, function)] = end_time

    def forget_worker(self, worker: str) -> None:
        for key in [k for k in self._last if k[0] == worker]:
            del self._last[key]
