"""``TappFederation`` — multi-zone deployment API v2 (PR 5).

The paper's setting is cloud–edge, multi-region serverless: requests
enter at *different* zones, each zone runs its own controller, and
``topology_tolerance`` bounds how far from its designated home a
function may run. This module makes that scenario class expressible
end-to-end: a :class:`~repro.core.platform.specs.FederationSpec`
declares the zones (each a ``ClusterSpec`` slice) and the inter-zone
network model, and ``TappFederation`` stands up one
:class:`~repro.core.scheduler.gateway.ZoneGateway` per zone — the
Archipelago shape (arXiv:1911.09849): semi-autonomous per-entrypoint
schedulers over a shared authoritative state.

All zone gateways share **one** watcher (cluster state, script store,
admission ledger) and therefore one epoch-cached view/index store; each
owns its zone-local compiled candidate indexes (the
``zone_restriction``-keyed entries of that store), its own RNG stream,
and its own round-robin cursors. ``invoke(fn, entry_zone=...)`` routes
zone-locally first; on failure the request is **forwarded** across
zones per the policy's ``topology_tolerance`` (see
:func:`~repro.core.scheduler.gateway.forward_targets`), nearest zone
first, with the network model charging each hop's RTT into the
returned :class:`FederatedPlacement`, the :class:`FederationStats`
counters, and the :meth:`TappFederation.explain` hop report.

``TappPlatform`` remains the degenerate single-entrypoint case — both
façades share :class:`~repro.core.platform.facade.PlatformCore`, so a
single-zone federation makes bit-identical decisions to the flat
platform on the same spec, policy, and seed (property-tested in
``tests/test_federation.py``).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import (
    Callable,
    Dict,
    FrozenSet,
    Iterable,
    List,
    Optional,
    Sequence,
    Set,
    Tuple,
    Union,
)

from repro.core.analysis import FederationView
from repro.core.platform.explain import (
    FederationExplainReport,
    ZoneHopReport,
    build_explain_report,
)
from repro.core.platform.facade import (
    Placement,
    PlatformCore,
    PlatformStats,
    PolicyInput,
)
from repro.core.platform.lifecycle import LifecycleSpec
from repro.core.platform.overload import OverloadSpec
from repro.core.platform.specs import FederationSpec, RetryPolicy
from repro.core.tapp.ast import TappScript
from repro.core.scheduler.engine import (
    Invocation,
    Outcome,
    ScheduleDecision,
    TraceEvent,
)
from repro.core.scheduler.gateway import ZoneGateway, forward_targets
from repro.core.scheduler.topology import DistributionPolicy
from repro.core.scheduler.watcher import LeaseConfig


@dataclasses.dataclass(frozen=True)
class ForwardHop:
    """One cross-zone hop of a federated request (attempted or taken)."""

    from_zone: str
    to_zone: str
    rtt: float
    scheduled: bool  # did this hop's zone place the invocation?


class FederatedPlacement(Placement):
    """A :class:`Placement` plus its entry zone and forwarding record.

    ``hops`` lists every cross-zone hop in trial order — failed forward
    attempts included, because the entry gateway paid their RTT to ask.
    ``forward_rtt`` is the total the network model charged; zero for a
    zone-local placement.
    """

    __slots__ = ("entry_zone", "hops")

    def __init__(
        self,
        invocation: Invocation,
        decision: ScheduleDecision,
        admitted: bool,
        watcher,
        ledger,
        entry_zone: str,
        hops: Tuple[ForwardHop, ...],
        worker_ref=None,
    ) -> None:
        super().__init__(invocation, decision, admitted, watcher, ledger,
                         worker_ref)
        self.entry_zone = entry_zone
        self.hops = hops

    def _rebind(self, decision, admitted, ledger, worker_ref) -> None:
        """Re-point at a drain/brownout re-route decision; the drain
        pass's hop record replaces the original attempt's (whose hops
        were already charged to the federation counters)."""
        super()._rebind(decision, admitted, ledger, worker_ref)
        core = self._core
        if core is not None:
            hops = getattr(core._drain_hops, "value", None)
            if hops is not None:
                self.hops = hops
                core._drain_hops.value = None

    @property
    def forwarded(self) -> bool:
        """Did the placement land outside the entry zone?"""
        return any(h.scheduled for h in self.hops)

    @property
    def forward_rtt(self) -> float:
        """Total cross-zone RTT charged (attempts included)."""
        return sum(h.rtt for h in self.hops)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"FederatedPlacement(function={self.invocation.function!r}, "
            f"entry={self.entry_zone!r}, worker={self.worker!r}, "
            f"forwarded={self.forwarded}, hops={len(self.hops)})"
        )


@dataclasses.dataclass(frozen=True)
class ZoneStats:
    """One zone's routing + load snapshot inside a federation."""

    zone: str
    routed: int
    tapp_routed: int
    vanilla_routed: int
    failed: int
    script_reloads: int
    entered: int         # invocations whose entry zone this was
    forwarded_in: int    # placements this zone accepted from elsewhere
    forwarded_out: int   # entries this zone handed to another zone
    workers: int
    inflight: int
    # This zone's admission-ledger shard (PR 7): tickets taken on / retired
    # from / evicted with this zone's workers, regardless of entry zone.
    admitted: int = 0
    completed: int = 0
    evicted: int = 0
    # This zone's admission-queue shard (PR 9): overflow entries parked
    # by requests *entering* here, keyed by entry zone. All zero with no
    # OverloadSpec queue armed.
    queued: int = 0
    shed: int = 0
    deadline_exceeded: int = 0
    queue_depth: int = 0


@dataclasses.dataclass(frozen=True)
class FederationStats:
    """Federation snapshot: per-zone breakdown + forwarding economics.

    ``aggregate`` sums the per-zone gateway counters into the familiar
    :class:`PlatformStats` shape; note its ``routed``/``failed`` count
    *evaluations* (a forwarded request is evaluated once per zone
    tried), while ``unplaced`` counts *requests* no zone could take.
    """

    aggregate: PlatformStats
    zones: Tuple[ZoneStats, ...]
    forwards: int          # cross-zone hops that placed the request
    forward_attempts: int  # all cross-zone hops tried (incl. failed)
    unplaced: int          # routing passes that exhausted every allowed
                           # zone (a retried request counts once per pass)
    cross_zone_rtt: float  # total RTT charged to hops (seconds)
    # (source, target) zone links whose circuit breaker is currently open
    # (PR 9) — forwards across them are suppressed to the probe rate.
    open_circuits: Tuple[Tuple[str, str], ...] = ()

    def zone(self, name: str) -> ZoneStats:
        for z in self.zones:
            if z.zone == name:
                return z
        raise KeyError(name)


class TappFederation(PlatformCore):
    """A set of per-zone entrypoints over one shared platform core."""

    def __init__(
        self,
        spec: FederationSpec,
        *,
        distribution: DistributionPolicy = DistributionPolicy.DEFAULT,
        seed: Optional[int] = None,
        compiled: bool = True,
        policy: Optional[PolicyInput] = None,
        strict_policies: bool = False,
        max_policy_history: int = 8,
        retry: Optional[RetryPolicy] = None,
        lease: Optional[LeaseConfig] = None,
        overload: Optional[OverloadSpec] = None,
        lifecycle: Optional[LifecycleSpec] = None,
    ) -> None:
        if not isinstance(spec, FederationSpec):
            raise TypeError(
                "TappFederation takes a FederationSpec (zone → ClusterSpec "
                "slices); wrap a flat ClusterSpec in a single zone, or use "
                "TappPlatform for the single-entrypoint case"
            )
        if not spec.zones:
            raise ValueError("federation spec declares no zones")
        super().__init__(
            spec.build(),
            compiled=compiled,
            strict_policies=strict_policies,
            max_policy_history=max_policy_history,
            retry=retry,
            lease=lease,
            overload=overload,
            lifecycle=lifecycle,
        )
        self._adopt_controller_policies(spec.merged().controllers)
        self._spec = spec
        self._distribution = distribution
        # Every zone gateway gets the same seed: streams are independent
        # per zone (each gateway owns its engine/RNG), and the single-zone
        # federation consumes exactly the flat platform's stream.
        self._zone_gateways: Dict[str, ZoneGateway] = {
            zone: ZoneGateway(
                self._watcher,
                zone=zone,
                distribution=distribution,
                seed=seed,
                compiled=compiled,
            )
            for zone in spec.zone_names
        }
        self._zone_order: Dict[str, Tuple[str, ...]] = {
            zone: spec.zone_order_from(zone) for zone in spec.zone_names
        }
        self._entered: Dict[str, int] = {z: 0 for z in spec.zone_names}
        self._forwarded_in: Dict[str, int] = {z: 0 for z in spec.zone_names}
        self._forwarded_out: Dict[str, int] = {z: 0 for z in spec.zone_names}
        self._forwards = 0
        self._forward_attempts = 0
        self._unplaced = 0
        self._cross_zone_rtt = 0.0
        # Severed inter-zone links (unordered pairs) + the per-epoch memo
        # of zones whose every worker is DEAD; both feed the partition-
        # aware forwarding walk (PR 6).
        self._partitions: Set[FrozenSet[str]] = set()
        self._dead_zone_cache: Tuple[int, FrozenSet[str]] = (-1, frozenset())
        # Hand-off slot for the drain path (PR 9): _drain_route stashes
        # the drain pass's hops here and FederatedPlacement._rebind picks
        # them up; thread-local because invoke-path brownout re-routes
        # run outside the drain lock.
        self._drain_hops = threading.local()
        if policy is not None:
            self.apply_policy(policy, strict=strict_policies)

    # -- entrypoint access -------------------------------------------------------

    def _gateways(self) -> Tuple[ZoneGateway, ...]:
        return tuple(self._zone_gateways[z] for z in self._spec.zone_names)

    # -- static analysis context -------------------------------------------------

    def _analysis_entry_zones(self) -> Tuple[Optional[str], ...]:
        """Federated plans are verified once per entry zone."""
        return tuple(self._spec.zone_names)

    def _analysis_federation(self) -> FederationView:
        """Forwarding table so per-entry verdicts fold in forward targets."""
        return FederationView(zone_order=dict(self._zone_order))

    @property
    def spec(self) -> FederationSpec:
        return self._spec

    @property
    def zones(self) -> Tuple[str, ...]:
        return self._spec.zone_names

    def zone_gateway(self, zone: str) -> ZoneGateway:
        """The entrypoint of one zone (read-mostly; tests and metrics)."""
        return self._zone_gateways[zone]

    def _resolve_entry(self, entry_zone: Optional[str]) -> str:
        if entry_zone is None:
            return self._spec.entry_zone
        if entry_zone not in self._zone_gateways:
            raise ValueError(
                f"unknown entry zone {entry_zone!r}; federation zones are "
                f"{list(self._spec.zone_names)}"
            )
        return entry_zone

    # -- partitions + zone reachability (PR 6) -----------------------------------

    def _require_zone(self, zone: str) -> None:
        if zone not in self._zone_gateways:
            raise ValueError(
                f"unknown federation zone {zone!r}; zones are "
                f"{list(self._spec.zone_names)}"
            )

    def sever(self, zone_a: str, zone_b: str) -> None:
        """Partition the inter-zone link ``zone_a ↔ zone_b`` (symmetric).

        While severed, neither zone forwards to the other: the partition
        filters :func:`~repro.core.scheduler.gateway.forward_targets` and
        converts a designated direct placement across the severed link
        into a failure (the request then continues the filtered
        forwarding walk, or fails if its tolerance pins it home).
        Idempotent; in-zone scheduling on both sides is unaffected.
        """
        self._require_zone(zone_a)
        self._require_zone(zone_b)
        if zone_a == zone_b:
            raise ValueError(f"cannot sever zone {zone_a!r} from itself")
        self._partitions.add(frozenset((zone_a, zone_b)))

    def heal(self, zone_a: str, zone_b: str) -> None:
        """Undo :meth:`sever` (idempotent). Forwarding order after the
        heal is exactly the pre-partition order — the partition filter
        preserves dedup slots, so nothing is reordered."""
        self._require_zone(zone_a)
        self._require_zone(zone_b)
        self._partitions.discard(frozenset((zone_a, zone_b)))

    def partitioned(self, zone_a: str, zone_b: str) -> bool:
        """Is the ``zone_a ↔ zone_b`` link currently severed?"""
        return frozenset((zone_a, zone_b)) in self._partitions

    @property
    def partitions(self) -> Tuple[Tuple[str, str], ...]:
        """Currently-severed links as sorted (a, b) pairs, sorted."""
        return tuple(sorted(tuple(sorted(p)) for p in self._partitions))

    def _dead_zones(self) -> FrozenSet[str]:
        """Zones whose every worker is DEAD — unroutable, so the
        forwarding walk skips them. Memoized per topology epoch: DEAD
        transitions and revivals are structural (they bump the epoch).
        The rescan walks the per-zone member map with early-out — a
        healthy zone costs one worker check — so an epoch bump in one
        zone charges O(zones), not O(cluster workers), to every
        entrypoint's next request."""
        epoch = self._watcher.cluster.topology_epoch
        cached_epoch, cached = self._dead_zone_cache
        if cached_epoch == epoch:
            return cached
        dead_zones: Set[str] = set()
        for zone, members in self._watcher.cluster.zone_members().items():
            if members and all(w.dead for w in members):
                dead_zones.add(zone)
        dead = frozenset(dead_zones)
        self._dead_zone_cache = (epoch, dead)
        return dead

    def _unreachable_from(self, zone: str) -> FrozenSet[str]:
        """Zones ``zone`` cannot currently deliver work to: partitioned
        peers plus all-DEAD zones. Empty (and cheap) in the fault-free
        case."""
        dead = self._dead_zones()
        if not self._partitions:
            return dead
        cut = {
            other
            for other in self._spec.zone_names
            if frozenset((zone, other)) in self._partitions
        }
        return dead | cut if cut else dead

    @staticmethod
    def _severed_decision(
        decision: ScheduleDecision, worker_zone: str, from_zone: str
    ) -> ScheduleDecision:
        """Convert a scheduled decision whose worker sits behind a severed
        link into a failure (``failed_by_policy`` stays False — this is a
        *worker-side* failure, so retry policies apply)."""
        trace = list(decision.trace)
        trace.append(
            TraceEvent(
                "forward",
                f"placement in zone {worker_zone!r} severed: unreachable "
                f"from {from_zone!r} (partition)",
            )
        )
        return ScheduleDecision(
            outcome=Outcome.FAILED,
            controller=decision.controller,
            tag=decision.tag,
            used_default_fallback=decision.used_default_fallback,
            zone_restriction=decision.zone_restriction,
            failed_by_policy=False,
            trace=trace,
        )

    # -- routing + forwarding ----------------------------------------------------

    def route(
        self,
        invocation: Invocation,
        *,
        entry_zone: Optional[str] = None,
        trace: bool = False,
    ) -> Tuple[ScheduleDecision, Tuple[ForwardHop, ...]]:
        """Route one invocation without admitting it.

        Zone-local pass at the entry zone first; on failure, the
        forwarding walk over :func:`forward_targets` — each target
        zone's own gateway evaluates the request zone-locally, so the
        forwarded decision consumes *that* zone's RNG stream/cursors.
        Returns the final decision plus the hop record (failed forward
        attempts included).
        """
        entry = self._resolve_entry(entry_zone)
        self._entered[entry] += 1
        return self._route_from(entry, invocation, trace)

    def _route_from(
        self,
        entry: str,
        invocation: Invocation,
        trace: bool,
        script: Optional[TappScript] = None,
    ) -> Tuple[ScheduleDecision, Tuple[ForwardHop, ...]]:
        gateway = self._zone_gateways[entry]
        cluster = self._watcher.cluster
        unreachable = self._unreachable_from(entry)
        breaker = self._breaker
        decision = gateway.route(invocation, trace=trace, entry_zone=entry,
                                 script=script)
        if decision.scheduled:
            worker_zone = cluster.workers[decision.worker].zone
            if worker_zone == entry:
                return decision, ()
            if (worker_zone not in unreachable
                    and (breaker is None
                         or breaker.allow(entry, worker_zone))):
                # A designated-controller block placed the work in its home
                # zone directly: that is a cross-zone hop too, and it pays.
                hop = ForwardHop(
                    entry, worker_zone, self._spec.rtt(entry, worker_zone),
                    True,
                )
                self._account_hops(entry, worker_zone, (hop,))
                if breaker is not None:
                    breaker.record_success(entry, worker_zone, rtt=hop.rtt)
                return decision, (hop,)
            # The designated placement sits behind a severed link (or an
            # open circuit): the entry zone cannot deliver it. Convert to
            # a failure and walk the (partition-filtered) forward targets
            # instead — which, for tolerance none/same, pin the function
            # to its (now unreachable) home zone, so the walk is empty and
            # the request fails rather than escaping its designated zone.
            # The entry gateway's routed/scheduled counters already moved;
            # the severed outcome is accounted at this (platform) layer.
            if breaker is not None and worker_zone in unreachable:
                breaker.record_failure(entry, worker_zone)
            decision = self._severed_decision(decision, worker_zone, entry)

        hops: List[ForwardHop] = []
        for target in forward_targets(
            script if script is not None else self._watcher.script,
            invocation.tag,
            cluster,
            entry,
            self._zone_order[entry],
            unreachable=unreachable,
        ):
            target_gateway = self._zone_gateways.get(target)
            if target_gateway is None:
                continue  # a home zone outside the federation's entrypoints
            if breaker is not None and not breaker.allow(entry, target):
                # Open circuit: the link consumed no forward attempt — the
                # breaker lets one probe through every probe_interval-th
                # suppressed attempt, and only that probe pays a hop.
                continue
            forwarded = target_gateway.route(
                invocation, trace=trace, entry_zone=target, script=script
            )
            if forwarded.scheduled:
                # The target zone's scheduler may itself place the work in
                # a *third* zone (a designated block's tolerance
                # restriction). That last leg is chargeable too — unless
                # *it* crosses a severed link, in which case the target
                # cannot deliver either and the walk continues.
                worker_zone = cluster.workers[forwarded.worker].zone
                if (worker_zone == target
                        or worker_zone not in self._unreachable_from(target)):
                    taken = [
                        ForwardHop(
                            entry, target, self._spec.rtt(entry, target), True
                        )
                    ]
                    if worker_zone != target:
                        taken.append(
                            ForwardHop(
                                target, worker_zone,
                                self._spec.rtt(target, worker_zone), True,
                            )
                        )
                    hops.extend(taken)
                    self._account_hops(entry, worker_zone, taken)
                    if breaker is not None:
                        breaker.record_success(entry, target,
                                               rtt=taken[0].rtt)
                    return forwarded, tuple(hops)
            hop = ForwardHop(
                entry, target, self._spec.rtt(entry, target), False
            )
            hops.append(hop)
            self._account_hops(entry, None, (hop,))
            if breaker is not None:
                breaker.record_failure(entry, target)
        self._unplaced += 1
        # Every allowed zone declined: report the entry zone's decision
        # (its failure narrative is the one the caller entered through).
        return decision, tuple(hops)

    def _account_hops(
        self,
        entry: str,
        placed_zone: Optional[str],
        hops: Sequence[ForwardHop],
    ) -> None:
        """Charge a routing step's hops; ``placed_zone`` is where the work
        actually landed (None: nothing placed). Zones added to the live
        cluster after construction are counted too (``.get`` defaults),
        though only spec-declared zones get a :class:`ZoneStats` row."""
        for hop in hops:
            self._forward_attempts += 1
            self._cross_zone_rtt += hop.rtt
        if placed_zone is not None:
            self._forwards += 1
            self._forwarded_out[entry] = (
                self._forwarded_out.get(entry, 0) + 1
            )
            self._forwarded_in[placed_zone] = (
                self._forwarded_in.get(placed_zone, 0) + 1
            )

    def _drain_route(
        self,
        zone: Optional[str],
        invocation: Invocation,
        script: Optional[TappScript] = None,
    ) -> ScheduleDecision:
        """Route a queued (or brownout-degraded) invocation from the
        entry zone it was parked at, through the full forwarding walk.
        The drain pass's hops are stashed for the immediately following
        :meth:`FederatedPlacement._rebind` (thread-local: the core calls
        the pair back-to-back on this thread)."""
        entry = self._resolve_entry(zone)
        decision, hops = self._route_from(entry, invocation, False,
                                          script=script)
        self._drain_hops.value = hops if decision.scheduled else None
        return decision

    # -- unified invocation flow -------------------------------------------------

    def invoke(
        self,
        function: Union[str, Invocation],
        *,
        entry_zone: Optional[str] = None,
        tag: Optional[str] = None,
        model_id: Optional[str] = None,
        request_id: int = 0,
        trace: bool = False,
        retry: Optional[RetryPolicy] = None,
        now: Optional[float] = None,
    ) -> FederatedPlacement:
        """Route (zone-local first, forward per tolerance) **and** admit.

        With a :class:`RetryPolicy` in force (argument > routed
        controller's spec > platform default), an invocation no zone
        could take is re-routed from the same entry zone up to
        ``max_attempts`` times, deterministic backoff charged to
        ``retry_wait``; every attempt's hops are in ``hops`` (the entry
        gateway paid their RTT). ``followup: fail`` stays terminal.

        With an :class:`OverloadSpec` queue armed, an invocation no zone
        could take after retries is parked in the *entry zone's*
        admission queue instead (``Placement.queued``); completions
        drain it through the same entry-zone forwarding walk. ``now``
        is the caller's clock for queue deadlines.
        """
        invocation = self._coerce_invocation(function, tag, model_id,
                                             request_id)
        entry = self._resolve_entry(entry_zone)
        if self._lifecycle is not None and now is not None:
            # Lazy janitor tick, same as the flat façade: stale warm
            # instances expire before any zone ranks by warmth.
            self._lifecycle.expire(now)
        self._entered[entry] += 1
        decision, hops = self._route_from(entry, invocation, trace)
        attempts, waited = 1, 0.0
        if not decision.scheduled and not decision.failed_by_policy:
            policy = self._retry_policy_for(decision.controller, retry)
            if policy is not None:
                all_hops = list(hops)
                while (not decision.scheduled
                       and not decision.failed_by_policy
                       and policy.allows(attempts, waited)):
                    waited += policy.backoff(attempts)
                    attempts += 1
                    self._retries += 1
                    decision, hops = self._route_from(entry, invocation,
                                                      trace)
                    all_hops.extend(hops)
                hops = tuple(all_hops)
        worker_ref, ledger, warm_hit = self._admit(invocation, decision)
        placement = FederatedPlacement(
            invocation, decision, worker_ref is not None, self._watcher,
            ledger, entry, hops, worker_ref,
        )
        placement._core = self
        placement.warm_hit = warm_hit
        placement.attempts = attempts
        placement.retry_wait = waited
        # Queue armed → park in the entry zone's queue instead of failing
        # (failed_by_policy does not gate it: a saturated tAPP evaluation
        # reports followup-fail exhaustion — see TappPlatform.invoke).
        if (not placement.scheduled
                and self._overload is not None
                and self._overload.queue is not None):
            placement = self._enqueue_overflow(placement, entry, now)
        return placement

    def retry(
        self,
        placement: FederatedPlacement,
        *,
        retry: Optional[RetryPolicy] = None,
    ) -> Optional[FederatedPlacement]:
        """Re-route a failed federated placement from its entry zone.

        The workers earlier attempts failed on are masked out of the
        re-route, and the forwarding walk runs against the *current*
        partition/death picture — a retry routes around zones that died
        or were severed since the original attempt. Returns ``None``
        when no retry is issued (no policy, budget spent, or the failure
        was a terminal ``followup: fail`` policy verdict); otherwise the
        replacement placement, whose ``hops`` cover only the re-route
        (the original attempt's hops were already charged).
        """
        policy = self._retry_policy_for(placement.controller, retry)
        if policy is None or placement.failed_by_policy:
            return None
        if not policy.allows(placement.attempts, placement.retry_wait):
            return None
        failed = placement.failed_workers
        if placement.worker is not None:
            failed = failed + (placement.worker,)
        self._retries += 1
        entry = placement.entry_zone
        self._entered[entry] += 1
        invocation = placement.invocation
        decision, hops = self._masked_route(
            failed, lambda: self._route_from(entry, invocation, False)
        )
        worker_ref, ledger, warm_hit = self._admit(invocation, decision)
        replacement = FederatedPlacement(
            invocation, decision, worker_ref is not None, self._watcher,
            ledger, entry, hops, worker_ref,
        )
        replacement._core = self
        replacement.warm_hit = warm_hit
        replacement.attempts = placement.attempts + 1
        replacement.retry_wait = (
            placement.retry_wait + policy.backoff(placement.attempts)
        )
        replacement.failed_workers = failed
        return replacement

    def invoke_batch(
        self,
        invocations: Iterable[Union[str, Invocation]],
        *,
        entry_zone: Optional[str] = None,
        entry_zones: Optional[Sequence[Optional[str]]] = None,
        trace: bool = False,
        on_placement: Optional[Callable[[FederatedPlacement], None]] = None,
        now: Optional[float] = None,
    ) -> List[FederatedPlacement]:
        """Invoke a batch, each item entering at its own zone.

        ``entry_zones`` aligns with ``invocations`` (``None`` entries
        fall back to ``entry_zone`` / the default entry); placements are
        admitted in order, each before the next is routed, so results
        are identical to a sequence of :meth:`invoke` calls — the same
        contract as ``TappPlatform.invoke_batch``.
        """
        invs = [
            inv if isinstance(inv, Invocation) else Invocation(function=inv)
            for inv in invocations
        ]
        if entry_zones is not None and len(entry_zones) != len(invs):
            raise ValueError(
                f"entry_zones has {len(entry_zones)} entries for "
                f"{len(invs)} invocations"
            )
        placements: List[FederatedPlacement] = []
        for index, invocation in enumerate(invs):
            zone = entry_zones[index] if entry_zones is not None else None
            placement = self.invoke(
                invocation, entry_zone=zone or entry_zone, trace=trace,
                now=now,
            )
            placements.append(placement)
            if on_placement is not None:
                on_placement(placement)
        return placements

    # -- observability -----------------------------------------------------------

    def explain(
        self,
        function: Union[str, Invocation],
        *,
        entry_zone: Optional[str] = None,
        tag: Optional[str] = None,
        model_id: Optional[str] = None,
    ) -> FederationExplainReport:
        """The federated "why": one typed report per zone visited.

        Mirrors :meth:`route` — entry-zone pass, then the forwarding walk
        until a zone accepts — but through each gateway's side-effect-free
        ``probe``, so nothing is admitted, no stats move, and every
        zone's RNG stream/cursors are restored.
        """
        invocation = self._coerce_invocation(function, tag, model_id)
        entry = self._resolve_entry(entry_zone)
        cluster = self._watcher.cluster
        unreachable = self._unreachable_from(entry)
        gateway = self._zone_gateways[entry]
        decision = gateway.probe(invocation, entry_zone=entry)
        if decision.scheduled:
            worker_zone = cluster.workers[decision.worker].zone
            if worker_zone != entry and worker_zone in unreachable:
                # Mirror _route_from's severed conversion: the designated
                # placement is behind a partition, so the live path fails
                # it and walks the filtered targets.
                decision = self._severed_decision(decision, worker_zone,
                                                  entry)
        hops = [
            ZoneHopReport(
                zone=entry, rtt=0.0, forwarded=False,
                report=self._annotate_explain(
                    build_explain_report(invocation, decision),
                    invocation.tag, entry,
                ),
            )
        ]
        final = decision
        if not decision.scheduled:
            for target in forward_targets(
                self._watcher.script, invocation.tag, cluster, entry,
                self._zone_order[entry],
                unreachable=unreachable,
            ):
                target_gateway = self._zone_gateways.get(target)
                if target_gateway is None:
                    continue
                probed = target_gateway.probe(invocation, entry_zone=target)
                if probed.scheduled:
                    # Mirror the third-leg severed check of _route_from.
                    worker_zone = cluster.workers[probed.worker].zone
                    if (worker_zone != target
                            and worker_zone in self._unreachable_from(target)):
                        probed = self._severed_decision(probed, worker_zone,
                                                        target)
                hops.append(
                    ZoneHopReport(
                        zone=target,
                        rtt=self._spec.rtt(entry, target),
                        forwarded=True,
                        report=self._annotate_explain(
                            build_explain_report(invocation, probed),
                            invocation.tag, target,
                        ),
                    )
                )
                if probed.scheduled:
                    final = probed
                    break
        placement_zone = None
        forward_rtt = sum(h.rtt for h in hops)
        if final.scheduled:
            placement_zone = cluster.workers[final.worker].zone
            # Mirror _route_from's charging exactly: the last leg from
            # the zone that evaluated the request (the entry pass, or the
            # last forwarding hop) to where the worker actually lives is
            # a chargeable hop too — the designated cross-zone placement
            # case, whichever zone's pass produced it.
            evaluated_at = hops[-1].zone
            if placement_zone != evaluated_at:
                forward_rtt += self._spec.rtt(evaluated_at, placement_zone)
        return FederationExplainReport(
            invocation=invocation,
            entry_zone=entry,
            scheduled=final.scheduled,
            worker=final.worker,
            controller=final.controller,
            placement_zone=placement_zone,
            forward_rtt=forward_rtt,
            hops=tuple(hops),
            unreachable_zones=tuple(sorted(unreachable)),
            overload_note=self._overload_note(entry),
            open_circuits=(
                self._breaker.open_circuits()
                if self._breaker is not None else ()
            ),
        )

    def prewarm(self) -> int:
        """Warm every zone gateway's indexes (shared store: overlapping
        entries are cache hits). Returns total block indexes touched."""
        return sum(gw.prewarm() for gw in self._gateways())

    def stats(self) -> FederationStats:
        cluster = self._watcher.cluster
        zone_rows: List[ZoneStats] = []
        totals = {"routed": 0, "tapp": 0, "vanilla": 0, "failed": 0,
                  "reloads": 0}
        shards = self.ledger_snapshot()
        for zone in self._spec.zone_names:
            gw_stats = self._zone_gateways[zone].stats
            workers = [w for w in cluster.workers.values() if w.zone == zone]
            admitted, completed, evicted = shards.get(zone, (0, 0, 0))
            queue = self._overload_queues.get(zone)
            qsnap = queue.snapshot() if queue is not None else {}
            zone_rows.append(
                ZoneStats(
                    zone=zone,
                    routed=gw_stats.routed,
                    tapp_routed=gw_stats.tapp_routed,
                    vanilla_routed=gw_stats.vanilla_routed,
                    failed=gw_stats.failed,
                    script_reloads=gw_stats.script_reloads,
                    entered=self._entered[zone],
                    forwarded_in=self._forwarded_in[zone],
                    forwarded_out=self._forwarded_out[zone],
                    workers=len(workers),
                    inflight=sum(w.inflight for w in workers),
                    admitted=admitted,
                    completed=completed,
                    evicted=evicted,
                    queued=qsnap.get("queued_total", 0),
                    shed=qsnap.get("shed", 0),
                    deadline_exceeded=qsnap.get("deadline_exceeded", 0),
                    queue_depth=qsnap.get("depth", 0),
                )
            )
            totals["routed"] += gw_stats.routed
            totals["tapp"] += gw_stats.tapp_routed
            totals["vanilla"] += gw_stats.vanilla_routed
            totals["failed"] += gw_stats.failed
            totals["reloads"] += gw_stats.script_reloads
        aggregate = self._platform_stats(
            routed=totals["routed"],
            tapp_routed=totals["tapp"],
            vanilla_routed=totals["vanilla"],
            failed=totals["failed"],
            script_reloads=totals["reloads"],
        )
        return FederationStats(
            aggregate=aggregate,
            zones=tuple(zone_rows),
            forwards=self._forwards,
            forward_attempts=self._forward_attempts,
            unplaced=self._unplaced,
            cross_zone_rtt=self._cross_zone_rtt,
            open_circuits=(
                self._breaker.open_circuits()
                if self._breaker is not None else ()
            ),
        )
