"""Overload-resilience layer (PR 9): admission queues, load shedding,
circuit breakers, and brownout degradation.

The scheduler through PR 8 decides *where* a function runs but has no
story for *when the cluster cannot run it*: a saturated ``ItemIndex``
answers "unplaced" in O(1) and the request is simply lost, and a slow
or partitioned remote zone is re-probed on every federated forward.
This module supplies the four missing mechanisms, all **opt-in** and
off by default — with no :class:`OverloadSpec` configured, placements,
traces, RNG streams, cursors, and ledger counters are bit-identical to
the pre-overload platform (property-tested):

* :class:`QueueSpec` / :class:`AdmissionQueue` — a bounded per-zone
  admission queue with a FIFO or EDF (earliest-deadline-first)
  discipline. An ``invoke`` that finds no capacity enqueues instead of
  failing; ledger completions drain the queue through the existing
  O(1) index path. Entries whose deadline passed are counted as
  ``deadline_exceeded`` and never placed.
* priority load shedding — when a queue is full, the lowest-priority
  entrant is shed (tAPP blocks carry a ``priority:`` clause; a tag's
  priority is the max over its blocks).
* :class:`BreakerSpec` / :class:`CircuitBreaker` — a closed → open →
  half-open breaker keyed by (source, target) zone on the federated
  forwarding path, fed by forward failures and RTT-budget violations,
  so a dead or saturated zone stops consuming forward attempts until
  a half-open probe succeeds. Cooldown is measured in suppressed
  attempts (not wall time) so behaviour stays deterministic.
* :class:`BrownoutSpec` / :class:`BrownoutController` +
  :func:`degrade_script` — under sustained saturation (queue depth at
  or above a high-water mark for N consecutive observations), tags
  that opt in via ``on-overload:`` re-route through a pre-compiled
  degraded plan (soft constraints dropped; tolerance widened for
  ``any-zone``), reverting at the low-water mark. The degraded plan is
  compiled and statically verified at ``apply_policy`` time like the
  primary plan, so a brownout can never swap in a plan with
  proven-unplaceable tags.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

from repro.core.tapp.ast import (
    Block,
    ControllerClause,
    OnOverload,
    TagPolicy,
    TappScript,
    TopologyTolerance,
    WorkerRef,
    WorkerSet,
)

__all__ = [
    "AdmissionQueue",
    "BreakerSpec",
    "BrownoutController",
    "BrownoutSpec",
    "CircuitBreaker",
    "OverloadSpec",
    "QueueEntry",
    "QueueSpec",
    "degrade_script",
]


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QueueSpec:
    """Bounded deadline-aware admission queue configuration (per zone).

    ``deadline`` bounds how long an entry may wait before it is counted
    as ``deadline_exceeded`` (None: entries never expire); ``discipline``
    picks the drain order: ``fifo`` (arrival order) or ``edf``
    (earliest absolute deadline first; deadline-less entries last).
    """

    depth: int = 64
    deadline: Optional[float] = None
    discipline: str = "fifo"

    def __post_init__(self) -> None:
        if self.depth <= 0:
            raise ValueError(f"queue depth must be positive, got {self.depth}")
        if self.deadline is not None and self.deadline <= 0:
            raise ValueError(
                f"queue deadline must be positive, got {self.deadline}"
            )
        if self.discipline not in ("fifo", "edf"):
            raise ValueError(
                f"unknown queue discipline {self.discipline!r}; "
                f"expected 'fifo' or 'edf'"
            )


@dataclasses.dataclass(frozen=True)
class BreakerSpec:
    """Per-(source, target)-zone circuit breaker on forwarding.

    ``failure_threshold`` consecutive forward failures open the circuit;
    while open, every ``probe_interval``-th suppressed attempt is let
    through as a half-open probe (deterministic: cooldown is counted in
    suppressed attempts, not wall time). ``rtt_budget`` (seconds)
    additionally counts a *successful* forward whose hop RTT exceeds
    the budget as a failure — the slow-zone feed.
    """

    failure_threshold: int = 3
    probe_interval: int = 8
    rtt_budget: Optional[float] = None

    def __post_init__(self) -> None:
        if self.failure_threshold <= 0:
            raise ValueError(
                f"failure_threshold must be positive, got "
                f"{self.failure_threshold}"
            )
        if self.probe_interval <= 0:
            raise ValueError(
                f"probe_interval must be positive, got {self.probe_interval}"
            )
        if self.rtt_budget is not None and self.rtt_budget <= 0:
            raise ValueError(
                f"rtt_budget must be positive, got {self.rtt_budget}"
            )


@dataclasses.dataclass(frozen=True)
class BrownoutSpec:
    """Hysteresis band for brownout degradation.

    Brownout activates after queue depth has been observed at or above
    ``high_water`` for ``sustain`` consecutive observations, and
    deactivates the first time depth falls to ``low_water`` or below.
    Between the marks the current state holds (hysteresis).
    """

    high_water: int = 8
    low_water: int = 2
    sustain: int = 3

    def __post_init__(self) -> None:
        if self.high_water <= 0:
            raise ValueError(
                f"high_water must be positive, got {self.high_water}"
            )
        if self.low_water < 0:
            raise ValueError(
                f"low_water must be non-negative, got {self.low_water}"
            )
        if self.low_water >= self.high_water:
            raise ValueError(
                f"low_water ({self.low_water}) must be below high_water "
                f"({self.high_water})"
            )
        if self.sustain <= 0:
            raise ValueError(f"sustain must be positive, got {self.sustain}")


@dataclasses.dataclass(frozen=True)
class OverloadSpec:
    """Umbrella opt-in: any combination of queue / breaker / brownout.

    Brownout requires a queue (its signal is queue depth).
    """

    queue: Optional[QueueSpec] = None
    breaker: Optional[BreakerSpec] = None
    brownout: Optional[BrownoutSpec] = None

    def __post_init__(self) -> None:
        if self.brownout is not None and self.queue is None:
            raise ValueError(
                "brownout requires a queue (its saturation signal is "
                "queue depth); set OverloadSpec.queue too"
            )


# ---------------------------------------------------------------------------
# Admission queue
# ---------------------------------------------------------------------------


class QueueEntry:
    """One queued (unplaced) invocation awaiting capacity."""

    __slots__ = ("placement", "priority", "enqueued_at", "deadline", "seq")

    def __init__(self, placement, priority: int, enqueued_at: Optional[float],
                 deadline: Optional[float], seq: int) -> None:
        self.placement = placement
        self.priority = priority
        self.enqueued_at = enqueued_at
        self.deadline = deadline  # absolute; None = never expires
        self.seq = seq


class AdmissionQueue:
    """A bounded deadline-aware queue of unplaced invocations.

    Depth is small and bounded (``QueueSpec.depth``), so linear scans
    are cheap and keep the implementation obviously correct; the hot
    invoke path never touches this class unless routing already failed.
    """

    def __init__(self, spec: QueueSpec) -> None:
        self.spec = spec
        self._entries: List[QueueEntry] = []
        self._seq = 0
        self._lock = threading.Lock()
        # Counters (monotonic).
        self.queued_total = 0
        self.shed = 0
        self.deadline_exceeded = 0
        self.drained = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def depth(self) -> int:
        return len(self._entries)

    def offer(
        self, placement, priority: int, now: Optional[float]
    ) -> Tuple[str, Optional[QueueEntry]]:
        """Enqueue a placement, shedding the lowest-priority entrant if
        full. Returns ``("queued", entry)`` when the newcomer got a
        slot, or ``("shed", victim_entry)`` — the victim is the
        newcomer itself unless a lower-priority queued entry was
        evicted to make room."""
        deadline = None
        if self.spec.deadline is not None and now is not None:
            deadline = now + self.spec.deadline
        with self._lock:
            self._seq += 1
            entry = QueueEntry(placement, priority, now, deadline, self._seq)
            if len(self._entries) < self.spec.depth:
                self._entries.append(entry)
                self.queued_total += 1
                return "queued", entry
            # Full: shed the lowest-priority entrant. Ties break toward
            # the youngest queued entry (preserves FIFO fairness among
            # equals); the newcomer loses ties against incumbents.
            victim = min(self._entries, key=lambda e: (e.priority, -e.seq))
            if victim.priority >= priority:
                self.shed += 1
                return "shed", entry
            self._entries.remove(victim)
            self._entries.append(entry)
            self.queued_total += 1
            self.shed += 1
            return "shed", victim

    def expire(self, now: Optional[float]) -> List[QueueEntry]:
        """Remove (and count) every entry whose deadline has passed."""
        if now is None:
            return []
        with self._lock:
            expired = [
                e for e in self._entries
                if e.deadline is not None and e.deadline < now
            ]
            if expired:
                self._entries = [
                    e for e in self._entries if e not in expired
                ]
                self.deadline_exceeded += len(expired)
        return expired

    def head(self) -> Optional[QueueEntry]:
        """The entry the discipline would drain next (not removed)."""
        with self._lock:
            if not self._entries:
                return None
            if self.spec.discipline == "edf":
                return min(
                    self._entries,
                    key=lambda e: (
                        e.deadline if e.deadline is not None else float("inf"),
                        e.seq,
                    ),
                )
            return self._entries[0]

    def remove(self, entry: QueueEntry, *, drained: bool) -> bool:
        """Take one entry out (drain success, or external cancellation)."""
        with self._lock:
            try:
                self._entries.remove(entry)
            except ValueError:
                return False
            if drained:
                self.drained += 1
            return True

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {
                "depth": len(self._entries),
                "queued_total": self.queued_total,
                "shed": self.shed,
                "deadline_exceeded": self.deadline_exceeded,
                "drained": self.drained,
            }


# ---------------------------------------------------------------------------
# Circuit breaker
# ---------------------------------------------------------------------------


class _BreakerState:
    __slots__ = ("failures", "open", "suppressed", "probing")

    def __init__(self) -> None:
        self.failures = 0
        self.open = False
        self.suppressed = 0
        self.probing = False


class CircuitBreaker:
    """Closed → open → half-open breaker keyed by (source, target) zone.

    Deterministic by construction: the open-state cooldown is counted
    in *suppressed attempts* rather than wall time — while open, every
    ``probe_interval``-th suppressed attempt is let through as a
    half-open probe. A probe success closes the circuit; a probe
    failure restarts the cooldown.
    """

    def __init__(self, spec: BreakerSpec) -> None:
        self.spec = spec
        self._states: Dict[Tuple[str, str], _BreakerState] = {}
        self._lock = threading.Lock()

    def _state(self, source: str, target: str) -> _BreakerState:
        key = (source, target)
        state = self._states.get(key)
        if state is None:
            state = self._states[key] = _BreakerState()
        return state

    def allow(self, source: str, target: str) -> bool:
        """May ``source`` attempt a forward to ``target`` right now?"""
        with self._lock:
            state = self._states.get((source, target))
            if state is None or not state.open:
                return True
            state.suppressed += 1
            if state.suppressed % self.spec.probe_interval == 0:
                state.probing = True
                return True  # half-open probe
            return False

    def record_success(
        self, source: str, target: str, *, rtt: Optional[float] = None
    ) -> None:
        """A forward to ``target`` succeeded. An RTT above the budget
        still counts as a failure (the slow-zone feed)."""
        if (self.spec.rtt_budget is not None and rtt is not None
                and rtt > self.spec.rtt_budget):
            self.record_failure(source, target)
            return
        with self._lock:
            state = self._states.get((source, target))
            if state is None:
                return
            state.failures = 0
            state.open = False
            state.suppressed = 0
            state.probing = False

    def record_failure(self, source: str, target: str) -> None:
        with self._lock:
            state = self._state(source, target)
            if state.open:
                # Probe failed (or a straggler attempt): restart cooldown.
                state.suppressed = 0
                state.probing = False
                return
            state.failures += 1
            if state.failures >= self.spec.failure_threshold:
                state.open = True
                state.suppressed = 0

    def is_open(self, source: str, target: str) -> bool:
        with self._lock:
            state = self._states.get((source, target))
            return state is not None and state.open

    def open_circuits(self) -> Tuple[Tuple[str, str], ...]:
        with self._lock:
            return tuple(sorted(
                key for key, state in self._states.items() if state.open
            ))

    def snapshot(self) -> Dict[Tuple[str, str], Dict[str, int]]:
        with self._lock:
            return {
                key: {
                    "failures": state.failures,
                    "open": int(state.open),
                    "suppressed": state.suppressed,
                }
                for key, state in self._states.items()
            }


# ---------------------------------------------------------------------------
# Brownout
# ---------------------------------------------------------------------------


class BrownoutController:
    """Hysteresis tracker turning queue depth into a brownout bit."""

    def __init__(self, spec: BrownoutSpec) -> None:
        self.spec = spec
        self.active = False
        self.activations = 0
        self._above = 0

    def observe(self, depth: int) -> bool:
        """Feed one queue-depth observation; returns the brownout bit."""
        if depth >= self.spec.high_water:
            self._above += 1
            if not self.active and self._above >= self.spec.sustain:
                self.active = True
                self.activations += 1
        elif depth <= self.spec.low_water:
            self._above = 0
            self.active = False
        # Between the marks: hold state, but a dip below high_water
        # breaks the activation streak.
        elif not self.active:
            self._above = 0
        return self.active


def _degrade_item(item):
    if isinstance(item, WorkerRef):
        if item.affinity is None and item.anti_affinity is None:
            return item
        return dataclasses.replace(item, affinity=None, anti_affinity=None)
    if isinstance(item, WorkerSet):
        if item.affinity is None and item.anti_affinity is None:
            return item
        return dataclasses.replace(item, affinity=None, anti_affinity=None)
    return item


def _degrade_block(block: Block, mode: OnOverload) -> Block:
    controller = block.controller
    if (mode is OnOverload.ANY_ZONE and controller is not None
            and controller.topology_tolerance is not TopologyTolerance.ALL):
        controller = ControllerClause(
            label=controller.label,
            topology_tolerance=TopologyTolerance.ALL,
        )
    return dataclasses.replace(
        block,
        controller=controller,
        affinity=None,
        anti_affinity=None,
        workers=tuple(_degrade_item(item) for item in block.workers),
    )


def _degrade_tag(tag: TagPolicy) -> TagPolicy:
    mode = tag.on_overload
    if mode is None or mode is OnOverload.REJECT:
        # REJECT is handled at admission time (immediate shed under
        # brownout); the plan itself is unchanged.
        return tag
    return dataclasses.replace(
        tag,
        blocks=tuple(_degrade_block(block, mode) for block in tag.blocks),
    )


def degrade_script(script: TappScript) -> Optional[TappScript]:
    """The pre-compiled brownout plan: soft constraints dropped.

    For every tag with ``on-overload: relax-affinity``, affinity /
    anti-affinity clauses are removed (block- and item-level);
    ``any-zone`` additionally widens designated controllers'
    ``topology_tolerance`` to ``all`` so federated forwarding may
    escape the home zone. Tags without an ``on-overload`` clause (and
    ``reject`` tags) pass through untouched. Returns ``None`` when no
    tag opts into a degraded *plan* — then there is nothing to
    pre-compile or verify.
    """
    if not any(
        tag.on_overload in (OnOverload.RELAX_AFFINITY, OnOverload.ANY_ZONE)
        for tag in script.tags
    ):
        return None
    return dataclasses.replace(
        script,
        tags=tuple(_degrade_tag(tag) for tag in script.tags),
    )
