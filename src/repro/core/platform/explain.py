"""Typed scheduling explanations built from the engine's trace machinery.

``TappPlatform.explain`` evaluates an invocation with tracing on and
lifts the flat :class:`~repro.core.scheduler.engine.TraceEvent` stream
into a structured report: per-block controller resolution notes and
per-worker candidate verdicts (valid, or the first violated constraint),
plus the tag/followup narration. The trace strings stay the single
source of truth — this module only parses the shapes the engine and the
vanilla baseline emit, so interpreter, compiled, and vanilla paths all
explain identically.

``TappFederation.explain`` stacks one of these reports per zone the
request visited: the entry zone's zone-local pass, then each forwarding
hop with the RTT the network model charged it — the
:class:`FederationExplainReport` per-zone forwarding hop report.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.core.scheduler.engine import (
    Invocation,
    ScheduleDecision,
    TraceEvent,
)

_BLOCK_RE = re.compile(r"^block\[(\d+)\]: (.*)$", re.S)


@dataclasses.dataclass(frozen=True)
class CandidateReport:
    """One worker's verdict inside one block evaluation."""

    worker: str
    valid: bool
    reason: Optional[str]  # first violated constraint; None when valid
    detail: str            # the raw trace detail
    # True when the static analyzer proved the active policy can never
    # place this invocation's tag on the worker — the rejection is a
    # property of the (policy × topology), not of current load.
    inevitable: bool = False
    # Warm-pool verdict (PR 10): does this worker hold an idle warm
    # instance of the invocation's function right now? None when the
    # lifecycle layer is unarmed (no warm/cold distinction exists).
    warm: Optional[bool] = None

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        verdict = "valid" if self.valid else f"rejected — {self.reason}"
        if self.inevitable:
            verdict += " (statically inevitable)"
        if self.warm is not None:
            verdict += " [warm]" if self.warm else " [cold]"
        return f"{self.worker}: {verdict}"


@dataclasses.dataclass(frozen=True)
class BlockReport:
    """One scheduling block's evaluation: controller resolution + verdicts."""

    index: Optional[int]   # block index in the tag (None: vanilla baseline)
    controller_notes: Tuple[str, ...]
    candidates: Tuple[CandidateReport, ...]

    @property
    def rejected(self) -> Tuple[CandidateReport, ...]:
        return tuple(c for c in self.candidates if not c.valid)


@dataclasses.dataclass(frozen=True)
class ExplainReport:
    """The full structured answer to "why did/didn't this schedule?"."""

    invocation: Invocation
    scheduled: bool
    worker: Optional[str]
    controller: Optional[str]
    tag: Optional[str]
    used_default_fallback: bool
    zone_restriction: Optional[str]
    failed_by_policy: bool
    blocks: Tuple[BlockReport, ...]
    notes: Tuple[str, ...]          # tag / followup narration, in order
    trace: Tuple[TraceEvent, ...]   # the raw events, for provenance
    # Failure-detector / partition narration (PR 6): why the platform
    # layer overrode or annotated this decision (e.g. a designated
    # placement severed by an inter-zone partition).
    failure_notes: Tuple[str, ...] = ()
    # Workers whose rejections the static analyzer proved inevitable
    # (PR 8): the active policy can never place this tag on them, under
    # any load — distinct from dynamic (load-dependent) rejections.
    inevitable_workers: Tuple[str, ...] = ()

    def rejections(self) -> Dict[str, str]:
        """worker → last rejection reason across every block evaluated."""
        out: Dict[str, str] = {}
        for block in self.blocks:
            for candidate in block.candidates:
                if not candidate.valid and candidate.reason is not None:
                    out[candidate.worker] = candidate.reason
        return out

    def render(self) -> str:
        """Human-readable summary (the structured sibling of `explain()`)."""
        head = (
            f"{self.invocation.function!r} tag={self.invocation.tag!r} → "
            + (
                f"worker={self.worker} controller={self.controller}"
                if self.scheduled
                else "NOT SCHEDULED"
                + (" (failed by policy)" if self.failed_by_policy else "")
            )
        )
        lines = [head]
        if self.inevitable_workers:
            lines.append(
                "  ! statically inevitable rejections: "
                + ", ".join(self.inevitable_workers)
            )
        for note in self.failure_notes:
            lines.append(f"  ! {note}")
        for note in self.notes:
            lines.append(f"  · {note}")
        for block in self.blocks:
            label = "block" if block.index is None else f"block[{block.index}]"
            for note in block.controller_notes:
                lines.append(f"  {label}: {note}")
            for candidate in block.candidates:
                lines.append(f"    {candidate}")
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class ZoneHopReport:
    """One zone's view of a federated evaluation.

    The first hop is always the entry zone's zone-local pass
    (``forwarded=False``, ``rtt=0``); subsequent hops are forwarding
    attempts in the order the federation tried them, each carrying the
    inter-zone RTT the network model charged for the hop.
    """

    zone: str
    rtt: float
    forwarded: bool
    report: ExplainReport

    @property
    def scheduled(self) -> bool:
        return self.report.scheduled


@dataclasses.dataclass(frozen=True)
class FederationExplainReport:
    """Why a federated invocation landed where it did, hop by hop."""

    invocation: Invocation
    entry_zone: str
    scheduled: bool
    worker: Optional[str]
    controller: Optional[str]
    placement_zone: Optional[str]
    forward_rtt: float               # total RTT charged across hops
    hops: Tuple[ZoneHopReport, ...]
    # Zones the entry zone could not reach when this report was built
    # (inter-zone partitions + all-workers-DEAD zones); the forwarding
    # walk skipped them (PR 6).
    unreachable_zones: Tuple[str, ...] = ()
    # Overload layer (PR 9): the entry zone's admission-queue state line
    # (None when the queue layer is off) and the (source, target) circuit
    # breakers currently open — an open breaker suppresses the forwarding
    # walk down to its half-open probe rate.
    overload_note: Optional[str] = None
    open_circuits: Tuple[Tuple[str, str], ...] = ()

    @property
    def forwarded(self) -> bool:
        """Did the request leave its entry zone (placement or attempts)?"""
        return self.placement_zone not in (None, self.entry_zone) or any(
            h.forwarded for h in self.hops
        )

    def rejections(self) -> Dict[str, str]:
        """worker → last rejection reason across every zone evaluated."""
        out: Dict[str, str] = {}
        for hop in self.hops:
            out.update(hop.report.rejections())
        return out

    def render(self) -> str:
        head = (
            f"{self.invocation.function!r} tag={self.invocation.tag!r} "
            f"entry={self.entry_zone!r} → "
            + (
                f"worker={self.worker} controller={self.controller} "
                f"zone={self.placement_zone}"
                + (
                    f" (forwarded, +{self.forward_rtt * 1e3:.1f}ms)"
                    if self.forwarded else ""
                )
                if self.scheduled
                else "NOT SCHEDULED"
            )
        )
        lines = [head]
        if self.unreachable_zones:
            lines.append(
                "  ! unreachable zones: "
                + ", ".join(repr(z) for z in self.unreachable_zones)
            )
        if self.open_circuits:
            lines.append(
                "  ! open circuits: "
                + ", ".join(f"{s!r}→{t!r}" for s, t in self.open_circuits)
            )
        if self.overload_note is not None:
            lines.append(f"  {self.overload_note}")
        for hop in self.hops:
            label = (
                f"zone {hop.zone!r} (entry pass)"
                if not hop.forwarded
                else f"zone {hop.zone!r} (forwarded, +{hop.rtt * 1e3:.1f}ms)"
            )
            lines.append(f"-- {label} --")
            lines.extend("  " + line for line in hop.report.render().splitlines())
        return "\n".join(lines)


def annotate_inevitable(
    report: ExplainReport, selectable: frozenset
) -> ExplainReport:
    """Mark rejected candidates outside the statically-selectable set.

    ``selectable`` is the analyzer's verdict for the invocation's
    resolved tag (workers some admission sequence can place it on); a
    rejected candidate outside it is statically inevitable — no load
    state would have changed the outcome.
    """
    blocks: List[BlockReport] = []
    doomed: set = set()
    changed = False
    for block in report.blocks:
        candidates = []
        for c in block.candidates:
            if not c.valid and c.worker not in selectable:
                candidates.append(dataclasses.replace(c, inevitable=True))
                doomed.add(c.worker)
                changed = True
            else:
                candidates.append(c)
        blocks.append(dataclasses.replace(block, candidates=tuple(candidates)))
    if not changed:
        return report
    return dataclasses.replace(
        report,
        blocks=tuple(blocks),
        inevitable_workers=tuple(sorted(doomed)),
    )


def annotate_warmth(report: ExplainReport, is_warm) -> ExplainReport:
    """Stamp every candidate's warm/cold verdict (armed platforms only).

    ``is_warm`` maps a worker name to whether it holds an idle warm
    instance of the report's function — the same ``warm_idle`` signal
    the ``warm-first`` strategy ranks by, so the report shows exactly
    the ordering evidence the scheduler saw.
    """
    blocks: List[BlockReport] = []
    changed = False
    for block in report.blocks:
        candidates = []
        for c in block.candidates:
            candidates.append(
                dataclasses.replace(c, warm=bool(is_warm(c.worker)))
            )
            changed = True
        blocks.append(dataclasses.replace(block, candidates=tuple(candidates)))
    if not changed:
        return report
    return dataclasses.replace(report, blocks=tuple(blocks))


def _parse_candidate(detail: str) -> CandidateReport:
    worker, _, rest = detail.partition(": ")
    if rest.startswith("VALID"):
        return CandidateReport(worker=worker, valid=True, reason=None,
                               detail=detail)
    reason = rest
    if reason.startswith("invalid — "):
        reason = reason[len("invalid — "):]
    return CandidateReport(worker=worker, valid=False, reason=reason,
                           detail=detail)


def build_explain_report(
    invocation: Invocation, decision: ScheduleDecision
) -> ExplainReport:
    """Lift a traced decision into the typed per-block/per-worker report."""
    blocks: List[BlockReport] = []
    notes: List[str] = []
    cur_index: Optional[int] = None
    cur_notes: List[str] = []
    cur_candidates: List[CandidateReport] = []
    started = False

    def flush() -> None:
        nonlocal cur_notes, cur_candidates, started
        if started:
            blocks.append(
                BlockReport(
                    index=cur_index,
                    controller_notes=tuple(cur_notes),
                    candidates=tuple(cur_candidates),
                )
            )
        cur_notes, cur_candidates, started = [], [], False

    for event in decision.trace:
        if event.kind == "controller":
            match = _BLOCK_RE.match(event.detail)
            index = int(match.group(1)) if match else None
            note = match.group(2) if match else event.detail
            # A controller event opens a new block report unless it is a
            # continuation of the same block (the gateway retrying the next
            # round-robin controller inside one controller-less block).
            if started and index != cur_index:
                flush()
            started = True
            cur_index = index
            cur_notes.append(note)
        elif event.kind == "candidate":
            started = True
            if ": " in event.detail:
                cur_candidates.append(_parse_candidate(event.detail))
            else:
                # Worker-less narration ("no workers") — a block note, not
                # a pseudo-worker rejection.
                cur_notes.append(event.detail)
        else:  # "tag" | "followup"
            flush()
            cur_index = None
            notes.append(event.detail)
    flush()

    return ExplainReport(
        invocation=invocation,
        scheduled=decision.scheduled,
        worker=decision.worker,
        controller=decision.controller,
        tag=decision.tag,
        used_default_fallback=decision.used_default_fallback,
        zone_restriction=decision.zone_restriction,
        failed_by_policy=decision.failed_by_policy,
        blocks=tuple(blocks),
        notes=tuple(notes),
        trace=tuple(decision.trace),
    )
