"""``TappPlatform`` — the paper's platform (§4) behind one typed API.

The paper's contribution is a *system*: gateway (§4.3), watcher (§4.2),
per-zone controllers, and live tAPP reload (§4.5) working together. This
façade owns that wiring so callers stop hand-assembling it:

* **declarative construction** — a :class:`ClusterSpec` builds the live
  topology; lifecycle methods (``add_worker``, ``drain``,
  ``mark_unhealthy``) route through the watcher, so epoch-based view
  invalidation stays correct no matter who mutates the deployment;
* **policy lifecycle** — ``apply_policy`` validates, dry-runs against
  the live topology, compiles, and atomically swaps a versioned
  :class:`PolicyHandle`; ``rollback`` restores the previous policy from
  a bounded history;
* **unified invocation flow** — ``invoke`` / ``invoke_batch`` route
  *and* admit in one step and hand back a :class:`Placement` whose
  ``complete()`` retires the running-function ticket (the affinity
  signal), collapsing the gateway/controller two-step;
* **observability** — ``explain`` returns a typed per-block/per-worker
  rejection report, ``stats`` a point-in-time snapshot, and
  ``subscribe`` a feed of platform events.

Since PR 5 the machinery is split: :class:`PlatformCore` holds
everything that does not depend on how many entrypoints exist (the
watcher, the admission ledger, the policy lifecycle, topology
lifecycle, events), and ``TappPlatform`` is the degenerate
single-entrypoint instantiation — one flat :class:`Gateway` over the
whole cluster. The multi-zone instantiation is
:class:`~repro.core.platform.federation.TappFederation`: one
:class:`~repro.core.scheduler.gateway.ZoneGateway` per zone over the
same core. The underlying parts remain importable for tests and power
users, but the façades are the only modules that should construct them.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import (
    Callable,
    Deque,
    Dict,
    Iterable,
    List,
    Mapping,
    Optional,
    Sequence,
    Tuple,
    Union,
)

from repro.core.analysis import AnalysisReport, FederationView, analyze_plan
from repro.core.platform.explain import (
    ExplainReport,
    annotate_inevitable,
    annotate_warmth,
    build_explain_report,
)
from repro.core.platform.lifecycle import LifecycleManager, LifecycleSpec
from repro.core.platform.overload import (
    AdmissionQueue,
    BrownoutController,
    CircuitBreaker,
    OverloadSpec,
    degrade_script,
)
from repro.core.platform.policy import (
    PolicyDryRun,
    PolicyError,
    PolicyHandle,
)
from repro.core.platform.specs import (
    ClusterSpec,
    ControllerSpec,
    RetryPolicy,
    WorkerSpec,
)
from repro.core.scheduler.controller import ControllerRuntime
from repro.core.scheduler.engine import Invocation, ScheduleDecision
from repro.core.scheduler.gateway import Gateway
from repro.core.scheduler.state import (
    ClusterState,
    ControllerState,
    HealthState,
    WorkerState,
)
from repro.core.scheduler.topology import DistributionPolicy
from repro.core.scheduler.watcher import (
    HealthTransition,
    LeaseConfig,
    Watcher,
)
from repro.core.tapp.ast import DEFAULT_TAG, OnOverload, TappScript
from repro.core.tapp.compile import compile_script
from repro.core.tapp.parser import parse_tapp
from repro.core.tapp.validate import validate_script

#: Platform event kinds forwarded to subscribers: the watcher's
#: "topology" / "script", plus "policy" (apply) and "rollback".
Subscriber = Callable[[str], None]

PolicyInput = Union[str, TappScript]


class UnknownWorkerError(KeyError):
    """A platform entry point named a worker the cluster does not have.

    Raised (instead of a bare ``KeyError``) by the topology/health
    lifecycle methods so a heartbeat for a deregistered worker fails
    loudly rather than resurrecting a drained worker's state.
    """

    def __init__(self, name: str) -> None:
        super().__init__(name)
        self.worker = name

    def __str__(self) -> str:
        return (
            f"unknown worker {self.worker!r} (never registered, or already "
            f"deregistered — a drained worker's state is not resurrectable)"
        )


class _UnknownWorkerGuard:
    """Context manager turning the watcher's ``KeyError`` for an unknown
    worker into :class:`UnknownWorkerError` (already-wrapped errors pass
    through untouched)."""

    __slots__ = ("name",)

    def __init__(self, name: str) -> None:
        self.name = name

    def __enter__(self) -> "_UnknownWorkerGuard":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if (
            exc_type is not None
            and issubclass(exc_type, KeyError)
            and not isinstance(exc, UnknownWorkerError)
        ):
            raise UnknownWorkerError(self.name) from None
        return False


class _Ledger:
    """Mutable admit/complete/evict counters shared with live placements.

    Invariant: ``admitted == completed + evicted + live inflight``. A
    ticket is *evicted* when its worker is deregistered while the work
    runs — the drain-path removal reconciles those tickets here, and the
    placement's later ``complete()`` sees the watcher decline the retire
    (the worker is gone) and does not double-count it as a completion.

    Since PR 7 the platform keeps one shard per worker *zone* (plus a
    ``None`` shard for un-admitted placements), so per-zone entrypoints
    mostly touch zone-local counters instead of one shared object; the
    invariant holds per shard, and therefore for the sums the stats
    snapshots report. Writes are *not* single-writer, though —
    cross-zone forwarding charges the ticket to the ticket worker's
    zone, so an entrypoint of zone A can increment zone B's shard
    concurrently with zone B's own thread — hence every counter update
    and every snapshot read of the triple goes through the shard's own
    lock (uncontended in the zone-local common case).
    """

    __slots__ = ("admitted", "completed", "evicted", "lock")

    def __init__(self) -> None:
        self.admitted = 0
        self.completed = 0
        self.evicted = 0
        self.lock = threading.Lock()

    def add_admitted(self, n: int = 1) -> None:
        with self.lock:
            self.admitted += n

    def add_completed(self, n: int = 1) -> None:
        with self.lock:
            self.completed += n

    def add_evicted(self, n: int = 1) -> None:
        with self.lock:
            self.evicted += n

    def snapshot(self) -> Tuple[int, int, int]:
        """Consistent ``(admitted, completed, evicted)`` triple."""
        with self.lock:
            return (self.admitted, self.completed, self.evicted)


class Placement:
    """The result of one unified invoke: decision + admission ticket.

    ``complete()`` retires the ticket (releasing the slot and the
    running-function multiset entry the affinity constraints read); it is
    idempotent, and a no-op for placements that were never admitted
    (policy failure / no valid worker). A plain ``__slots__`` class: one
    is created per invocation on the serving hot path, so construction
    cost is kept at raw-attribute-write level.
    """

    __slots__ = ("invocation", "decision", "admitted", "completed",
                 "_watcher", "_ledger", "_worker_ref", "_generation",
                 "attempts", "retry_wait", "failed_workers",
                 "_core", "queued", "queue_outcome", "queue_wait",
                 "warm_hit")

    def __init__(
        self,
        invocation: Invocation,
        decision: ScheduleDecision,
        admitted: bool,
        watcher: Watcher,
        ledger: _Ledger,
        worker_ref: Optional[WorkerState] = None,
    ) -> None:
        self.invocation = invocation
        self.decision = decision
        self.admitted = admitted
        self.completed = False
        self._watcher = watcher
        self._ledger = ledger
        # The live worker the ticket was taken on: complete() retires
        # against exactly this instance, so a later worker re-using the
        # name can never have its counters decremented by a dead ticket.
        self._worker_ref = worker_ref
        # Incarnation at admission: a crash (DEAD transition) evicts the
        # ticket and bumps the worker's generation, so complete() declines.
        self._generation = 0 if worker_ref is None else worker_ref.generation
        # Retry bookkeeping (see TappPlatform.retry): total attempts this
        # placement represents, cumulative deterministic backoff charged,
        # and the workers earlier attempts failed on (excluded from
        # subsequent re-routes).
        self.attempts = 1
        self.retry_wait = 0.0
        self.failed_workers: Tuple[str, ...] = ()
        # Overload layer (PR 9). ``_core`` backref lets complete() drain
        # the admission queues and record duplicate completes; ``queued``
        # marks a placement parked in an admission queue, and
        # ``queue_outcome`` its fate ("drained" / "shed" /
        # "deadline_exceeded"; None while still waiting).
        self._core: Optional["PlatformCore"] = None
        self.queued = False
        self.queue_outcome: Optional[str] = None
        self.queue_wait = 0.0
        # Warm-pool layer (PR 10): did the admission reuse an idle warm
        # instance? None when the lifecycle layer is unarmed or nothing
        # was admitted; the simulator prices cold starts off this flag.
        self.warm_hit: Optional[bool] = None

    @property
    def scheduled(self) -> bool:
        return self.decision.scheduled

    @property
    def worker(self) -> Optional[str]:
        return self.decision.worker

    @property
    def controller(self) -> Optional[str]:
        return self.decision.controller

    @property
    def tag(self) -> Optional[str]:
        return self.decision.tag

    @property
    def failed_by_policy(self) -> bool:
        return self.decision.failed_by_policy

    @property
    def retried(self) -> bool:
        return self.attempts > 1

    @property
    def ticket_alive(self) -> bool:
        """Is the admission ticket still live on its original worker
        incarnation? ``False`` once completed, or after the worker was
        deregistered or crashed (either way the ticket was reconciled as
        a ledger eviction and the work it covered died)."""
        if not self.admitted or self.completed:
            return False
        worker = self._worker_ref
        if worker is None or worker.generation != self._generation:
            return False
        return self._watcher.cluster.workers.get(self.decision.worker) is worker

    def _rebind(
        self,
        decision: ScheduleDecision,
        admitted: bool,
        ledger: _Ledger,
        worker_ref: Optional[WorkerState],
    ) -> None:
        """Re-point this placement at a freshly-admitted decision (the
        queue-drain / brownout-reroute path): the original invoke handed
        out an un-admitted ticket, and capacity showed up later."""
        self.decision = decision
        self.admitted = admitted
        self.completed = False
        self._ledger = ledger
        self._worker_ref = worker_ref
        self._generation = 0 if worker_ref is None else worker_ref.generation

    def complete(self, *, slow: bool = False,
                 now: Optional[float] = None) -> bool:
        """Retire the admission ticket. Idempotent-or-loud: returns
        ``True`` only the one time a live ticket is actually released;
        ``False`` on a double complete (recorded in the platform's
        ``duplicate_completions`` counter), an un-admitted placement, or
        a ticket that was already reconciled as an eviction (worker
        deregistered or crashed while the work ran) — none of which
        touch the ledger again. ``now`` is the caller's clock, used to
        expire admission-queue deadlines when the freed slot triggers a
        queue drain (PR 9)."""
        if self.completed or not self.admitted:
            if self.completed and self.admitted and self._core is not None:
                # A second complete() on the same ticket: harmless (the
                # ledger is untouched) but a caller bug worth surfacing.
                self._core._duplicate_completions += 1
            return False
        self.completed = True
        retired = False
        if self._watcher.record_completion(
            self.decision.worker,
            self.decision.controller or "?",
            self.invocation.function,
            slow=slow,
            expected=self._worker_ref,
            generation=self._generation,
        ):
            self._ledger.add_completed()
            retired = True
        # else: the worker was evicted mid-run (deregistration or crash);
        # the eviction already reconciled this ticket.
        core = self._core
        if retired and core is not None and core._lifecycle is not None:
            # Park the instance back in its warm pool *before* the queue
            # drain below, so a drained head routed onto this worker sees
            # the warmth this completion just created. The lazy janitor
            # tick runs first: deadlines ≤ now expire before the new
            # instance parks (its own deadline is now + keep_alive).
            lifecycle = core._lifecycle
            if now is not None:
                lifecycle.expire(now)
            lifecycle.on_complete(
                self._worker_ref,
                self.invocation.function,
                self.decision.controller,
                now,
            )
        if core is not None and core._overload_queues:
            # A slot was freed (or at least a ticket retired): give the
            # admission queues a chance to place their heads through the
            # same O(1) index path the original invoke used.
            core._drain_queues(now)
        return retired

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"Placement(function={self.invocation.function!r}, "
            f"tag={self.invocation.tag!r}, worker={self.worker!r}, "
            f"controller={self.controller!r}, admitted={self.admitted}, "
            f"completed={self.completed})"
        )


@dataclasses.dataclass(frozen=True)
class PlatformStats:
    """Point-in-time platform snapshot (routing + admissions + topology)."""

    routed: int
    tapp_routed: int
    vanilla_routed: int
    failed: int
    script_reloads: int
    admitted: int
    completed: int
    inflight: int
    workers: int
    controllers: int
    policy_version: Optional[int]
    topology_epoch: int
    # Volatile-load events recorded by the admission ledger / heartbeats —
    # the stream the candidate indexes consume incrementally.
    load_events: int = 0
    # Admission tickets that died with a deregistered worker (see _Ledger).
    evicted: int = 0
    # Retry re-routes issued by the platform's RetryPolicy machinery.
    retries: int = 0
    # Failure-detector verdicts currently in force.
    suspect_workers: int = 0
    dead_workers: int = 0
    # Overload layer (PR 9); all zero while the layer is off/idle.
    queued: int = 0              # entries ever enqueued (cumulative)
    shed: int = 0                # entries shed by priority / reject
    deadline_exceeded: int = 0   # entries expired waiting
    queue_depth: int = 0         # entries currently waiting
    duplicate_completions: int = 0
    brownout_reroutes: int = 0   # placements served via the degraded plan
    # Warm-pool lifecycle (PR 10); all zero while the layer is unarmed.
    cold_starts: int = 0         # admissions that spawned a new instance
    warm_hits: int = 0           # admissions that reused an idle instance
    expirations: int = 0         # instances terminated (janitor + idle cap)
    idle_instances: int = 0      # instances currently parked warm


class PlatformCore:
    """Entrypoint-count-agnostic platform machinery.

    Owns the watcher (authoritative cluster state + script store), the
    controller runtime, the admission ledger, the policy lifecycle, the
    topology lifecycle, and event fan-out. Subclasses provide the
    entrypoints: :class:`TappPlatform` one flat gateway,
    :class:`~repro.core.platform.federation.TappFederation` one
    :class:`ZoneGateway` per zone — all sharing this core's watcher, so a
    policy swap or topology change invalidates every entrypoint's caches
    through one notification.
    """

    def __init__(
        self,
        cluster: Optional[ClusterState],
        *,
        watcher: Optional[Watcher] = None,
        compiled: bool = True,
        strict_policies: bool = False,
        max_policy_history: int = 8,
        retry: Optional[RetryPolicy] = None,
        lease: Optional[LeaseConfig] = None,
        overload: Optional[OverloadSpec] = None,
        lifecycle: Optional[LifecycleSpec] = None,
    ) -> None:
        # ``watcher`` adopts an existing instance (the legacy-shim
        # migration path) instead of building one around ``cluster``.
        self._watcher = (
            watcher if watcher is not None else Watcher(cluster, lease=lease)
        )
        if watcher is not None and lease is not None:
            self._watcher.configure_lease(lease)
        self._runtime = ControllerRuntime(self._watcher)
        # Warm-pool lifecycle (PR 10), entirely dormant without a
        # LifecycleSpec: no pools, no warmth journal events, and every
        # hook site is one None check — the unarmed platform stays
        # bit-identical to the pre-lifecycle one.
        self._lifecycle = (
            LifecycleManager(lifecycle, self._watcher.cluster)
            if lifecycle is not None else None
        )
        if self._lifecycle is not None:
            self._watcher.attach_lifecycle(self._lifecycle)
        # Zone-sharded admission ledger (PR 7): one counter shard per
        # worker zone, plus the ``None`` shard for un-admitted
        # placements. Writes are zone-local (each placement holds the
        # shard of the zone its ticket was taken in); the lock guards
        # only shard-map growth and cross-zone snapshot reads, never the
        # admit/complete hot path.
        self._ledger_lock = threading.Lock()
        self._ledgers: Dict[Optional[str], _Ledger] = {None: _Ledger()}
        # Platform-default retry policy + per-controller overrides (from
        # ControllerSpec.retry); resolution order per placement: explicit
        # call argument > routed controller's policy > platform default.
        self._retry = retry
        self._controller_retry: Dict[str, RetryPolicy] = {}
        self._retries = 0
        self._compiled = compiled
        self._strict_policies = strict_policies
        self._active: Optional[PolicyHandle] = None
        self._history: Deque[PolicyHandle] = deque(maxlen=max_policy_history)
        # Serialises whole policy transitions (publish + handle/history
        # bookkeeping + plan priming), not just the watcher's swap, so
        # concurrent applies cannot leave `policy` pointing at a handle
        # that is not the published script.
        self._policy_lock = threading.Lock()
        # Overload-resilience layer (PR 9), entirely dormant without an
        # OverloadSpec: the queue map stays empty (complete()'s drain
        # check is one falsy dict read), and the breaker / brownout
        # hooks are None-checked on their (already off-hot-path) sites.
        self._overload = overload
        self._overload_queues: Dict[Optional[str], AdmissionQueue] = {}
        self._breaker = (
            CircuitBreaker(overload.breaker)
            if overload is not None and overload.breaker is not None
            else None
        )
        self._brownout = (
            BrownoutController(overload.brownout)
            if overload is not None and overload.brownout is not None
            else None
        )
        self._drain_lock = threading.Lock()
        self._duplicate_completions = 0
        self._brownout_reroutes = 0
        # The pre-compiled brownout plan: (degraded_script, plan), set by
        # apply_policy when the active script opts in via on-overload.
        self._degraded = None
        # Observer hook for queue lifecycle events ("drained" / "shed" /
        # "expired"); the sim uses it to resume parked requests.
        self.on_queue_event: Optional[
            Callable[[str, Placement, Optional[float]], None]
        ] = None
        self._subscribers: List[Subscriber] = []
        self._watcher.subscribe(self._emit)

    # -- entrypoints (provided by subclasses) -----------------------------------

    def _gateways(self) -> Iterable[Gateway]:
        raise NotImplementedError

    # -- static analysis context (subclasses refine) ----------------------------

    def _analysis_distribution(self) -> Optional[DistributionPolicy]:
        """The distribution policy the analyzer evaluates views under."""
        for gateway in self._gateways():
            return gateway.distribution
        return None

    def _analysis_entry_zones(self) -> Tuple[Optional[str], ...]:
        """Entry contexts to verify: flat platforms evaluate context-free."""
        return (None,)

    def _analysis_federation(self) -> Optional[FederationView]:
        """Forwarding context (federated platforms only)."""
        return None

    def _analyze_policy_plan(
        self,
        plan,
        *,
        starvation_floor: int = 1,
        tags: Optional[Sequence[str]] = None,
    ) -> Optional[AnalysisReport]:
        """Run the static verifier on a lowered plan against live topology."""
        distribution = self._analysis_distribution()
        if distribution is None:
            return None
        return analyze_plan(
            plan,
            self._watcher.cluster,
            distribution,
            entry_zones=self._analysis_entry_zones(),
            starvation_floor=starvation_floor,
            federation=self._analysis_federation(),
            tags=tags,
        )

    def _analysis_plan(self, script: TappScript):
        """Identity-memoized lowering of the active script (explain path)."""
        memo = getattr(self, "_plan_memo", None)
        if memo is None or memo[0] is not script:
            memo = (script, compile_script(script))
            self._plan_memo = memo
        return memo[1]

    def _annotate_explain(
        self,
        report: ExplainReport,
        tag: Optional[str],
        entry_zone: Optional[str],
    ) -> ExplainReport:
        """Mark rejected candidates the active policy can *never* accept.

        A rejection is statically inevitable when the analyzer's verdict
        for the invocation's resolved tag (from this entry context,
        forwarding included) shows no admission sequence ever placing the
        tag on that worker — the operator-facing split between "policy
        can never work here" and "cluster is busy right now".

        With the warm-pool lifecycle armed, every candidate is also
        stamped warm/cold — the exact ``warm_idle`` evidence a
        ``warm-first`` strategy ranked by at evaluation time.
        """
        if self._lifecycle is not None:
            workers = self._watcher.cluster.workers
            fhash = report.invocation.hash

            def _is_warm(name: str) -> bool:
                worker = workers.get(name)
                return (worker is not None
                        and worker.warm_idle.get(fhash, 0) > 0)

            report = annotate_warmth(report, _is_warm)
        handle = self._active
        if handle is None or not handle.script.tags:
            return report
        script = handle.script
        try:
            plan = self._analysis_plan(script)
        except Exception:
            # Interpreter-only script the compiler rejects: the engine
            # still runs it, so there is nothing static to prove.
            return report
        resolved = tag if tag is not None and tag in plan.tags else DEFAULT_TAG
        if resolved not in plan.tags:
            return report
        analysis = self._analyze_policy_plan(plan, tags=(resolved,))
        if analysis is None:
            return report
        selectable = analysis.selectable(resolved, entry_zone)
        if selectable is None:
            return report
        return annotate_inevitable(report, selectable)

    # -- events ----------------------------------------------------------------

    def subscribe(self, callback: Subscriber) -> None:
        """Receive platform events: "topology", "script", "policy",
        "rollback" (watcher events are forwarded)."""
        self._subscribers.append(callback)

    def _emit(self, kind: str) -> None:
        for cb in list(self._subscribers):
            cb(kind)

    # -- component access (read-mostly; never construct these yourself) --------

    @property
    def watcher(self) -> Watcher:
        return self._watcher

    @property
    def runtime(self) -> ControllerRuntime:
        return self._runtime

    @property
    def cluster(self) -> ClusterState:
        return self._watcher.cluster

    @property
    def compiled(self) -> bool:
        """Whether the entrypoints run the compiled fast path."""
        return self._compiled

    # -- topology lifecycle -----------------------------------------------------

    def add_worker(
        self, spec: Union[WorkerSpec, WorkerState, Mapping, None] = None, **fields
    ) -> None:
        """Register a worker (spec, live state, mapping, or kwargs)."""
        if spec is None:
            spec = WorkerSpec(**fields)
        if isinstance(spec, WorkerState):
            worker = spec
        else:
            worker = WorkerSpec.coerce(spec).build()
        self._watcher.register_worker(worker)

    def remove_worker(self, name: str) -> None:
        """Deregister a worker through the watcher's drain path.

        The watcher clears health + reachability before the membership
        change (no admission can race the removal) and reports how many
        admission tickets died with the worker; those are reconciled as
        ledger evictions, so ``admitted == completed + evicted + inflight``
        keeps holding and nothing strands.
        """
        removed = self._watcher.deregister_worker(name)
        if removed is not None and removed.inflight:
            self._ledger_for(removed.zone).add_evicted(removed.inflight)

    def add_controller(
        self,
        spec: Union[ControllerSpec, ControllerState, Mapping, str, None] = None,
        **fields,
    ) -> None:
        if spec is None:
            spec = ControllerSpec(**fields)
        elif isinstance(spec, str):
            spec = ControllerSpec(name=spec, **fields)
        if isinstance(spec, ControllerState):
            controller = spec
        else:
            coerced = ControllerSpec.coerce(spec)
            if coerced.retry is not None:
                self._controller_retry[coerced.name] = coerced.retry
            if coerced.keep_alive is not None and self._lifecycle is not None:
                self._lifecycle.set_controller_keep_alive(
                    coerced.name, coerced.keep_alive
                )
            controller = coerced.build()
        self._watcher.register_controller(controller)

    def remove_controller(self, name: str) -> None:
        """Deregister a controller (drained by the watcher before removal,
        symmetric to :meth:`remove_worker`)."""
        self._controller_retry.pop(name, None)
        if self._lifecycle is not None:
            self._lifecycle.forget_controller(name)
        self._watcher.deregister_controller(name)

    def _adopt_controller_policies(
        self, controllers: Iterable[ControllerSpec]
    ) -> None:
        """Collect per-controller retry policies (and lifecycle
        keep-alive overrides) from declarative specs (the constructor
        path, where the cluster is built wholesale)."""
        for spec in controllers:
            if spec.retry is not None:
                self._controller_retry[spec.name] = spec.retry
            if spec.keep_alive is not None and self._lifecycle is not None:
                self._lifecycle.set_controller_keep_alive(
                    spec.name, spec.keep_alive
                )

    def drain(self, name: str) -> None:
        """Stop new admissions on a worker; running work keeps completing.

        Clears both health and reachability: unreachability is the
        *preliminary* invalidate condition of every policy (paper §3.3),
        so a drained worker is rejected no matter which ``invalidate``
        clause a script uses (``capacity_used`` and
        ``max_concurrent_invocations`` never consult health), and the
        admission ledger refuses new tickets outright — while completions
        still retire, which is what distinguishes a drain from a loss.
        """
        with self._wrap_unknown_worker(name):
            self._watcher.mark_drained(name)

    def restore(self, name: str) -> None:
        """Undo :meth:`drain` / :meth:`mark_unhealthy` /
        :meth:`mark_unreachable` / a failure-detector verdict (subscribers
        see the "topology" event, same as the marking side)."""
        with self._wrap_unknown_worker(name):
            self._watcher.mark_restored(name)

    def mark_unhealthy(self, name: str) -> None:
        with self._wrap_unknown_worker(name):
            self._watcher.mark_unhealthy(name)

    def mark_unreachable(self, name: str) -> None:
        with self._wrap_unknown_worker(name):
            self._watcher.mark_unreachable(name)

    def heartbeat(self, name: str, **fields) -> None:
        """Report live worker state (load / health / residency update).

        Raises :class:`UnknownWorkerError` for a worker that was never
        registered or has been deregistered — a late heartbeat must not
        resurrect a drained worker's state.
        """
        with self._wrap_unknown_worker(name):
            self._watcher.update_worker(name, **fields)

    @staticmethod
    def _wrap_unknown_worker(name: str):
        """Context manager lifting the watcher's ``KeyError`` for an
        unknown worker into the platform's :class:`UnknownWorkerError`."""
        return _UnknownWorkerGuard(name)

    # -- failure detection + recovery (PR 6) -------------------------------------

    def heartbeat_lease(
        self, name: str, now: float, **fields
    ) -> Optional[HealthTransition]:
        """Renew a worker's heartbeat lease (see
        :meth:`~repro.core.scheduler.watcher.Watcher.heartbeat_lease`);
        a heartbeat from a SUSPECT/DEAD worker restores it to HEALTHY and
        returns the transition. Unknown/deregistered workers raise
        :class:`UnknownWorkerError`."""
        with self._wrap_unknown_worker(name):
            return self._watcher.heartbeat_lease(name, now, **fields)

    def check_leases(self, now: float) -> List[HealthTransition]:
        """Advance the failure detector to ``now`` and reconcile the
        ledger: each DEAD verdict's evicted in-flight tickets are counted
        as ledger evictions (the deregistration-drain shape), keeping
        ``admitted == completed + evicted + inflight``."""
        transitions = self._watcher.check_leases(now)
        for transition in transitions:
            if transition.evicted:
                # DEAD workers stay registered, so the zone lookup holds.
                self._ledger_shard_of(transition.worker).add_evicted(
                    transition.evicted
                )
        return transitions

    def fail_worker(self, name: str) -> int:
        """Declare a worker DEAD now (crash signal / fault injection);
        evicts its in-flight tickets into the ledger and returns the
        evicted count. Idempotent; unknown workers raise
        :class:`UnknownWorkerError`."""
        with self._wrap_unknown_worker(name):
            worker = self._watcher.cluster.workers.get(name)
            zone = worker.zone if worker is not None else None
            evicted = self._watcher.mark_dead(name)
        self._ledger_for(zone).add_evicted(evicted)
        return evicted

    def suspect_worker(self, name: str) -> None:
        """Flag a worker SUSPECT (flappy heartbeat): deprioritized in
        candidate ordering but still placeable."""
        with self._wrap_unknown_worker(name):
            self._watcher.mark_suspect(name)

    # -- retry policy resolution --------------------------------------------------

    @property
    def retry_policy(self) -> Optional[RetryPolicy]:
        """The platform-default :class:`RetryPolicy` (None: no retries)."""
        return self._retry

    def _retry_policy_for(
        self,
        controller: Optional[str],
        override: Optional[RetryPolicy],
    ) -> Optional[RetryPolicy]:
        if override is not None:
            return override
        if controller is not None:
            policy = self._controller_retry.get(controller)
            if policy is not None:
                return policy
        return self._retry

    def _masked_route(self, exclude: Sequence[str], route):
        """Run ``route()`` with ``exclude`` workers masked unreachable —
        the already-tried exclusion of a retry re-route. The mask restores
        exactly the workers it masked, so a worker unreachable for other
        reasons stays that way."""
        masked = self._watcher.mask_unreachable(exclude)
        try:
            return route()
        finally:
            if masked:
                self._watcher.unmask(masked)

    # -- policy lifecycle ---------------------------------------------------------

    @property
    def policy(self) -> Optional[PolicyHandle]:
        return self._active

    @property
    def policy_history(self) -> Sequence[PolicyHandle]:
        """Previously-active policies, oldest first (bounded)."""
        return tuple(self._history)

    def _dry_run_from_report(self, report) -> PolicyDryRun:
        cluster = self._watcher.cluster
        return PolicyDryRun(
            report=report,
            known_zones=tuple(cluster.zones()),
            known_sets=tuple(cluster.set_labels()),
            known_controllers=tuple(cluster.controller_names()),
        )

    def dry_run_policy(self, policy: PolicyInput) -> PolicyDryRun:
        """Validate + statically analyze a script without applying it."""
        script, _ = self._coerce_policy(policy)
        cluster = self._watcher.cluster
        report = validate_script(
            script,
            known_controllers=cluster.controller_names(),
            known_worker_labels=cluster.worker_names(),
            known_set_labels=cluster.set_labels(),
        )
        dry_run = self._dry_run_from_report(report)
        try:
            plan = compile_script(script)
        except Exception:
            # Interpreter-only script: validation findings stand alone.
            return dry_run
        analysis = self._analyze_policy_plan(plan)
        if analysis is not None:
            dry_run = dataclasses.replace(dry_run, analysis=analysis)
        degraded = degrade_script(script)
        if degraded is not None:
            # The brownout plan is a deploy artifact too: verify it with
            # the same analyzer so its verdicts gate the apply.
            degraded_analysis = self._analyze_policy_plan(
                compile_script(degraded)
            )
            if degraded_analysis is not None:
                dry_run = dataclasses.replace(
                    dry_run, degraded_analysis=degraded_analysis
                )
        return dry_run

    def verify_policy(
        self,
        policy: Optional[PolicyInput] = None,
        *,
        starvation_floor: int = 1,
    ) -> AnalysisReport:
        """Statically verify a policy against the live topology.

        Defaults to the active policy. Returns the analyzer's
        :class:`~repro.core.analysis.AnalysisReport` — ``report.verdict()``
        renders the per-(tag × entry zone) reachability/satisfiability/
        starvation verdicts. ``starvation_floor`` flags tags whose static
        admission bound is positive but below it.
        """
        if policy is None:
            handle = self._active
            if handle is None:
                raise PolicyError("no active policy to verify")
            script: TappScript = handle.script
        else:
            script, _ = self._coerce_policy(policy)
        plan = compile_script(script)
        report = self._analyze_policy_plan(
            plan, starvation_floor=starvation_floor
        )
        if report is None:
            raise PolicyError(
                "platform has no entrypoints to analyze against"
            )
        return report

    def apply_policy(
        self, policy: PolicyInput, *, strict: Optional[bool] = None
    ) -> PolicyHandle:
        """Validate → dry-run → compile → atomically swap a new policy.

        The swap is all-or-nothing AND race-free: the dry-run gate, the
        compile check, and the swap all run under the watcher's lock (via
        ``publish_script``'s gate hook), so the script is never gated
        against a stale topology snapshot. A parse error, a blocking
        dry-run finding, or a failing compile leaves the active policy,
        the watcher's published script, and the history untouched.
        ``strict`` additionally rejects topology/constraint warnings
        (unknown controllers, worker labels, or set labels; contradictory
        affinity lists) and static-analysis *proofs* (tags no admission
        sequence can ever place); it defaults to the platform's
        ``strict_policies`` setting.
        """
        if strict is None:
            strict = self._strict_policies
        script, source = self._coerce_policy(policy)
        gated: dict = {}
        compiled_path = self._compiled

        def _gate(report) -> None:
            dry_run = self._dry_run_from_report(report)
            gated["dry_run"] = dry_run
            dry_run.raise_for(strict=strict)
            # Compile before the swap: a failing lowering must not
            # un-publish the previous script (the engine would otherwise
            # recompile lazily on the next decision and blow up
            # mid-traffic). The interpreter path never lowers, so it
            # skips the check rather than rejecting scripts it would run
            # — but still lowers opportunistically so the analyzer gets
            # a plan to verify.
            if compiled_path:
                plan = gated["plan"] = compile_script(script)
            else:
                try:
                    plan = compile_script(script)
                except Exception:
                    plan = None
            if plan is not None:
                # Static verification (reachability / satisfiability /
                # starvation) runs under the same lock, against the same
                # snapshot the dry-run saw; strict mode re-gates on the
                # analyzer's proofs before the swap.
                analysis = self._analyze_policy_plan(plan)
                if analysis is not None:
                    dry_run = dataclasses.replace(dry_run, analysis=analysis)
                    gated["dry_run"] = dry_run
                    dry_run.raise_for(strict=strict)
                # on-overload tags pre-compile a degraded brownout plan;
                # verify it under the same lock/snapshot as the primary,
                # so a brownout can never swap in a plan with
                # proven-unplaceable tags (strict mode re-gates).
                degraded = degrade_script(script)
                if degraded is not None:
                    degraded_plan = compile_script(degraded)
                    gated["degraded"] = (degraded, degraded_plan)
                    degraded_analysis = self._analyze_policy_plan(
                        degraded_plan
                    )
                    if degraded_analysis is not None:
                        dry_run = dataclasses.replace(
                            dry_run, degraded_analysis=degraded_analysis
                        )
                        gated["dry_run"] = dry_run
                        dry_run.raise_for(strict=strict)

        with self._policy_lock:
            published = self._watcher.publish_script(script, gate=_gate)
            if compiled_path:
                # The published script shares `script.tags`, so the gate's
                # plan is its plan — seed every entrypoint's engine cache
                # instead of recompiling on the first decision after the
                # swap (one plan object, shared by all zone gateways).
                for gateway in self._gateways():
                    gateway.prime(published, gated["plan"])
            self._degraded = gated.get("degraded")
            if self._degraded is not None and compiled_path:
                # Prime the degraded plan too: the brownout re-route must
                # not pay compilation mid-saturation.
                for gateway in self._gateways():
                    gateway.prime(*self._degraded)
            handle = PolicyHandle(
                version=published.version,
                script=published,
                source=source,
                dry_run=gated["dry_run"],
            )
            if self._active is not None:
                self._history.append(self._active)
            self._active = handle
        self._emit("policy")
        return handle

    def rollback(self) -> Optional[PolicyHandle]:
        """Restore the previous policy (bit-identical decisions).

        The restored script is re-published under a fresh version number;
        its content — and therefore every scheduling decision it produces —
        is identical to when it was last active. Rolling back past the
        oldest retained policy raises; rolling back a platform whose
        previous state was "no policy" restores the vanilla fallback.
        """
        with self._policy_lock:
            if self._active is None and not self._history:
                raise PolicyError("no policy history to roll back to")
            if not self._history:
                # Active policy but empty history → back to "no script".
                self._active = None
                self._degraded = None
                self._watcher.clear_script()
                self._emit("rollback")
                return None
            previous = self._history.pop()
            published = self._watcher.publish_script(
                previous.script, strict=True
            )
            if self._compiled:
                # Same compile-then-prime discipline as apply_policy, so
                # the first decision after the rollback stays
                # compilation-free too.
                plan = compile_script(previous.script)
                for gateway in self._gateways():
                    gateway.prime(published, plan)
            degraded = degrade_script(previous.script)
            try:
                self._degraded = (
                    None if degraded is None
                    else (degraded, compile_script(degraded))
                )
            except Exception:
                # Interpreter-only script: no lowered plan to pre-prime,
                # but the degraded script itself still routes.
                self._degraded = (degraded, None)
            if (self._degraded is not None and self._compiled
                    and self._degraded[1] is not None):
                for gateway in self._gateways():
                    gateway.prime(*self._degraded)
            self._active = dataclasses.replace(
                previous, version=published.version, script=published
            )
        self._emit("rollback")
        return self._active

    def clear_policy(self) -> None:
        """Remove the policy → vanilla fallback (paper §4.3). The cleared
        policy stays in history, so :meth:`rollback` restores it."""
        with self._policy_lock:
            if self._active is not None:
                self._history.append(self._active)
                self._active = None
            self._degraded = None
            self._watcher.clear_script()

    @staticmethod
    def _coerce_policy(policy: PolicyInput):
        if isinstance(policy, TappScript):
            return policy, policy.source
        script = parse_tapp(policy)
        return script, policy

    # -- admission ----------------------------------------------------------------

    def _ledger_for(self, zone: Optional[str]) -> _Ledger:
        """The ledger shard of one zone (created on first use; the lock
        covers only shard-map growth, not counter updates)."""
        shard = self._ledgers.get(zone)
        if shard is None:
            with self._ledger_lock:
                shard = self._ledgers.setdefault(zone, _Ledger())
        return shard

    def _ledger_shard_of(self, worker_name: Optional[str]) -> _Ledger:
        """The shard admissions on ``worker_name`` land in (the worker's
        zone; the ``None`` shard for unknown/deregistered workers)."""
        if worker_name is None:
            return self._ledgers[None]
        worker = self._watcher.cluster.workers.get(worker_name)
        return self._ledger_for(worker.zone if worker is not None else None)

    def ledger_snapshot(self) -> Dict[Optional[str], Tuple[int, int, int]]:
        """Per-zone ``(admitted, completed, evicted)`` counters.

        The shard map is frozen under the ledger lock; each shard's
        triple is then read under that shard's own counter lock (the
        same lock every increment takes — cross-zone forwarding means a
        shard is *not* single-writer), so each per-shard triple is
        internally consistent and the sums satisfy the ledger invariant.
        """
        with self._ledger_lock:
            shards = list(self._ledgers.items())
        return {zone: s.snapshot() for zone, s in shards}

    def _admit(
        self, invocation: Invocation, decision: ScheduleDecision
    ) -> Tuple[Optional[WorkerState], _Ledger, Optional[bool]]:
        """Record a scheduled decision's admission ticket (the single
        admission point of both façades); returns the live worker the
        ticket was taken on (None: nothing to admit), the ledger shard
        the ticket was charged to — the placement completes against
        exactly that shard — and the warm-pool verdict (did the armed
        lifecycle reuse an idle instance? None unarmed/unadmitted)."""
        worker = decision.worker
        if worker is None:
            return None, self._ledgers[None], None
        ticket_worker = self._watcher.record_admission(
            worker, decision.controller or "?", invocation.function
        )
        ledger = self._ledger_for(
            ticket_worker.zone if ticket_worker is not None else None
        )
        ledger.add_admitted()
        warm_hit: Optional[bool] = None
        if self._lifecycle is not None and ticket_worker is not None:
            warm_hit = self._lifecycle.on_admit(
                ticket_worker, invocation.function
            )
        return ticket_worker, ledger, warm_hit

    def place(
        self, invocation: Invocation, decision: ScheduleDecision
    ) -> Placement:
        """Admit a routed decision and hand back its ticket.

        The single admission point behind ``invoke`` / ``invoke_batch``;
        also usable directly with an externally-routed decision (legacy
        scheduler adapters).
        """
        worker_ref, ledger, warm_hit = self._admit(invocation, decision)
        placement = Placement(invocation, decision, worker_ref is not None,
                              self._watcher, ledger, worker_ref)
        placement._core = self
        placement.warm_hit = warm_hit
        return placement

    # -- warm-pool lifecycle (PR 10) ----------------------------------------------

    @property
    def lifecycle_spec(self) -> Optional[LifecycleSpec]:
        return self._lifecycle.spec if self._lifecycle is not None else None

    @property
    def lifecycle(self) -> Optional[LifecycleManager]:
        """The armed lifecycle manager (None: layer off). Read-mostly —
        the admission hooks feed it; callers tick the janitor via
        :meth:`expire_instances` and read :meth:`lifecycle_snapshot`."""
        return self._lifecycle

    def expire_instances(self, now: float) -> int:
        """Run the warm-pool expiration janitor up to ``now`` (explicit
        clock, same discipline as :meth:`check_leases`); returns the
        number of idle instances terminated. No-op (0) unarmed. The
        armed ``invoke``/``complete`` paths also run this lazily
        whenever they are handed a clock, so calling it directly is
        only needed to expire pools across idle gaps."""
        if self._lifecycle is None:
            return 0
        return self._lifecycle.expire(now)

    def lifecycle_snapshot(self) -> Dict[str, int]:
        """Warm-pool counters + occupancy (all-zero mapping unarmed)."""
        if self._lifecycle is None:
            return {
                "cold_starts": 0, "warm_hits": 0, "expirations": 0,
                "idle_instances": 0, "busy_instances": 0, "pools": 0,
            }
        return self._lifecycle.snapshot()

    # -- overload layer (PR 9) ----------------------------------------------------

    @property
    def overload_spec(self) -> Optional[OverloadSpec]:
        return self._overload

    @property
    def brownout_active(self) -> bool:
        return self._brownout is not None and self._brownout.active

    def queue_snapshot(self) -> Dict[Optional[str], Dict[str, int]]:
        """Per-zone admission-queue counters (empty when the layer is
        off or no overflow has ever been enqueued)."""
        return {
            zone: queue.snapshot()
            for zone, queue in sorted(
                self._overload_queues.items(),
                key=lambda kv: (kv[0] is not None, kv[0] or ""),
            )
        }

    def _queue_for(self, zone: Optional[str]) -> AdmissionQueue:
        """The admission queue of one entry zone (armed path only)."""
        queue = self._overload_queues.get(zone)
        if queue is None:
            queue = self._overload_queues[zone] = AdmissionQueue(
                self._overload.queue
            )
        return queue

    def _compiled_policy_tag(self, tag: Optional[str]):
        """The active policy's CompiledTag an invocation tag resolves to
        (None without a policy, or when the script cannot be lowered)."""
        handle = self._active
        if handle is None or not handle.script.tags:
            return None
        try:
            plan = self._analysis_plan(handle.script)
        except Exception:
            return None
        resolved = tag if tag is not None and tag in plan.tags else DEFAULT_TAG
        return plan.tags.get(resolved, plan.default)

    def _queue_priority(self, tag: Optional[str]) -> int:
        ctag = self._compiled_policy_tag(tag)
        return 0 if ctag is None else ctag.priority

    def _queue_on_overload(self, tag: Optional[str]) -> Optional[OnOverload]:
        ctag = self._compiled_policy_tag(tag)
        return None if ctag is None else ctag.on_overload

    def _drain_route(
        self,
        zone: Optional[str],
        invocation: Invocation,
        script: Optional[TappScript] = None,
    ) -> ScheduleDecision:
        """Route a queued (or brownout-degraded) invocation from its
        entry zone; subclasses bind this to their entrypoint shape."""
        raise NotImplementedError

    def _notify_queue(
        self, event: str, placement: Placement, now: Optional[float]
    ) -> None:
        callback = self.on_queue_event
        if callback is not None:
            callback(event, placement, now)

    def _enqueue_overflow(
        self,
        placement: Placement,
        zone: Optional[str],
        now: Optional[float],
    ) -> Placement:
        """Park an unplaceable invocation in its zone's admission queue
        (the armed overflow path — never reached without a QueueSpec).
        Under an active brownout the tag's ``on-overload:`` escape hatch
        runs first: ``reject`` sheds immediately, ``relax-affinity`` /
        ``any-zone`` try the pre-compiled degraded plan; only then does
        the invocation queue (shedding the lowest-priority entrant when
        full)."""
        queue = self._queue_for(zone)
        if self._brownout is not None:
            self._brownout.observe(queue.depth)
            if self._brownout.active:
                handled = self._brownout_overflow(placement, zone, queue, now)
                if handled is not None:
                    return handled
        priority = self._queue_priority(placement.invocation.tag)
        status, entry = queue.offer(placement, priority, now)
        if status == "queued":
            placement.queued = True
            return placement
        # "shed": the entry is the losing side — the newcomer itself,
        # or the lower-priority incumbent evicted to make room for it.
        shed = entry.placement
        shed.queue_outcome = "shed"
        if shed is not placement:
            placement.queued = True
        self._notify_queue("shed", shed, now)
        return placement

    def _brownout_overflow(
        self,
        placement: Placement,
        zone: Optional[str],
        queue: AdmissionQueue,
        now: Optional[float],
    ) -> Optional[Placement]:
        """Apply the tag's on-overload escape hatch under an active
        brownout; returns the handled placement, or None to fall
        through to the queue."""
        mode = self._queue_on_overload(placement.invocation.tag)
        if mode is None:
            return None
        if mode is OnOverload.REJECT:
            placement.queue_outcome = "shed"
            queue.shed += 1
            self._notify_queue("shed", placement, now)
            return placement
        degraded = self._degraded
        if degraded is None:
            return None
        decision = self._drain_route(
            zone, placement.invocation, script=degraded[0]
        )
        if not decision.scheduled:
            return None
        worker_ref, ledger, warm_hit = self._admit(
            placement.invocation, decision
        )
        placement._rebind(decision, worker_ref is not None, ledger,
                          worker_ref)
        placement.warm_hit = warm_hit
        self._brownout_reroutes += 1
        return placement

    def _drain_queues(self, now: Optional[float] = None) -> None:
        """Try to place queued invocations through the normal route path
        (called from ``Placement.complete()`` whenever a ticket retires).
        Expired entries are counted as ``deadline_exceeded`` and never
        placed; draining stops at the first head the cluster still
        cannot take. Re-entrant calls (a drain admitting work while
        another drain runs) are coalesced into the ongoing pass."""
        if not self._drain_lock.acquire(blocking=False):
            return
        try:
            for zone in sorted(
                self._overload_queues,
                key=lambda z: (z is not None, z or ""),
            ):
                queue = self._overload_queues[zone]
                for entry in queue.expire(now):
                    expired = entry.placement
                    expired.queue_outcome = "deadline_exceeded"
                    self._notify_queue("expired", expired, now)
                while True:
                    head = queue.head()
                    if head is None:
                        break
                    invocation = head.placement.invocation
                    decision = self._drain_route(zone, invocation)
                    if not decision.scheduled:
                        break
                    queue.remove(head, drained=True)
                    worker_ref, ledger, warm_hit = self._admit(
                        invocation, decision
                    )
                    drained = head.placement
                    drained._rebind(decision, worker_ref is not None,
                                    ledger, worker_ref)
                    drained.warm_hit = warm_hit
                    drained.queue_outcome = "drained"
                    if now is not None and head.enqueued_at is not None:
                        drained.queue_wait = now - head.enqueued_at
                    self._notify_queue("drained", drained, now)
                if self._brownout is not None:
                    self._brownout.observe(queue.depth)
        finally:
            self._drain_lock.release()

    def _overload_note(self, zone: Optional[str]) -> Optional[str]:
        """One-line queue/brownout state for explain reports (None when
        the queue layer is off)."""
        if self._overload is None or self._overload.queue is None:
            return None
        spec = self._overload.queue
        queue = self._overload_queues.get(zone)
        snap = queue.snapshot() if queue is not None else {}
        note = (
            f"overload queue[{zone if zone is not None else 'platform'}]: "
            f"depth {snap.get('depth', 0)}/{spec.depth} "
            f"({spec.discipline}), shed {snap.get('shed', 0)}, "
            f"deadline_exceeded {snap.get('deadline_exceeded', 0)}, "
            f"drained {snap.get('drained', 0)}"
        )
        if self._brownout is not None and self._brownout.active:
            note += "; brownout active"
        return note

    def _queue_totals(self) -> Tuple[int, int, int, int]:
        """(queued_total, shed, deadline_exceeded, current depth) summed
        over every zone's admission queue."""
        queued = shed = expired = depth = 0
        for queue in list(self._overload_queues.values()):
            snap = queue.snapshot()
            queued += snap["queued_total"]
            shed += snap["shed"]
            expired += snap["deadline_exceeded"]
            depth += snap["depth"]
        return queued, shed, expired, depth

    def _platform_stats(
        self,
        *,
        routed: int,
        tapp_routed: int,
        vanilla_routed: int,
        failed: int,
        script_reloads: int,
    ) -> PlatformStats:
        """Assemble the ledger/cluster half of a stats snapshot; the
        caller supplies only its entrypoints' routing totals (the single
        place both façades' snapshots are built)."""
        cluster = self._watcher.cluster
        suspects = dead = 0
        for w in cluster.workers.values():
            if w.health is HealthState.SUSPECT:
                suspects += 1
            elif w.health is HealthState.DEAD:
                dead += 1
        admitted = completed = evicted = 0
        for shard in list(self._ledgers.values()):
            a, c, e = shard.snapshot()
            admitted += a
            completed += c
            evicted += e
        queued, shed, expired, depth = self._queue_totals()
        cold_starts = warm_hits = expirations = idle_instances = 0
        if self._lifecycle is not None:
            pools = self._lifecycle.snapshot()
            cold_starts = pools["cold_starts"]
            warm_hits = pools["warm_hits"]
            expirations = pools["expirations"]
            idle_instances = pools["idle_instances"]
        return PlatformStats(
            routed=routed,
            tapp_routed=tapp_routed,
            vanilla_routed=vanilla_routed,
            failed=failed,
            script_reloads=script_reloads,
            admitted=admitted,
            completed=completed,
            inflight=sum(w.inflight for w in cluster.workers.values()),
            workers=len(cluster.workers),
            controllers=len(cluster.controllers),
            policy_version=(
                self._active.version if self._active is not None else None
            ),
            topology_epoch=cluster.topology_epoch,
            load_events=cluster.load_seq,
            evicted=evicted,
            retries=self._retries,
            suspect_workers=suspects,
            dead_workers=dead,
            queued=queued,
            shed=shed,
            deadline_exceeded=expired,
            queue_depth=depth,
            duplicate_completions=self._duplicate_completions,
            brownout_reroutes=self._brownout_reroutes,
            cold_starts=cold_starts,
            warm_hits=warm_hits,
            expirations=expirations,
            idle_instances=idle_instances,
        )

    @staticmethod
    def _coerce_invocation(
        function: Union[str, Invocation],
        tag: Optional[str],
        model_id: Optional[str],
        request_id: int = 0,
    ) -> Invocation:
        if isinstance(function, Invocation):
            if tag is not None or model_id is not None or request_id != 0:
                raise TypeError(
                    "pass either a pre-built Invocation or the field "
                    "keywords, not both (the keywords would be silently "
                    "ignored)"
                )
            return function
        return Invocation(
            function=function, tag=tag, model_id=model_id,
            request_id=request_id,
        )


class TappPlatform(PlatformCore):
    """One serverless platform instance: watcher + gateway + controllers.

    The degenerate single-entrypoint federation: one flat
    :class:`Gateway` routes over the whole cluster (``entry_zone=None``
    semantics — no zone-local pass, no forwarding). For multi-zone
    deployments with per-zone entrypoints use
    :class:`~repro.core.platform.federation.TappFederation`, which shares
    every behaviour of this façade through :class:`PlatformCore`.
    """

    def __init__(
        self,
        spec: Optional[Union[ClusterSpec, ClusterState]] = None,
        *,
        distribution: DistributionPolicy = DistributionPolicy.DEFAULT,
        seed: Optional[int] = None,
        compiled: bool = True,
        policy: Optional[PolicyInput] = None,
        strict_policies: bool = False,
        max_policy_history: int = 8,
        retry: Optional[RetryPolicy] = None,
        lease: Optional[LeaseConfig] = None,
        overload: Optional[OverloadSpec] = None,
        lifecycle: Optional[LifecycleSpec] = None,
    ) -> None:
        if isinstance(spec, ClusterState):
            cluster = spec
        elif spec is not None:
            cluster = spec.build()
        else:
            cluster = None
        super().__init__(
            cluster,
            compiled=compiled,
            strict_policies=strict_policies,
            max_policy_history=max_policy_history,
            retry=retry,
            lease=lease,
            overload=overload,
            lifecycle=lifecycle,
        )
        if isinstance(spec, ClusterSpec):
            self._adopt_controller_policies(spec.controllers)
        self._gateway = Gateway(
            self._watcher,
            distribution=distribution,
            seed=seed,
            compiled=compiled,
        )
        if policy is not None:
            self.apply_policy(policy, strict=strict_policies)

    @classmethod
    def from_watcher(
        cls,
        watcher: Watcher,
        *,
        distribution: DistributionPolicy = DistributionPolicy.DEFAULT,
        seed: Optional[int] = None,
        compiled: bool = True,
    ) -> "TappPlatform":
        """Wrap an existing watcher (the legacy-shim migration path)."""
        platform = cls.__new__(cls)
        # One copy of the core init invariants: delegate, don't clone.
        PlatformCore.__init__(platform, None, watcher=watcher,
                              compiled=compiled)
        platform._gateway = Gateway(
            watcher, distribution=distribution, seed=seed, compiled=compiled
        )
        return platform

    def _gateways(self) -> Tuple[Gateway, ...]:
        return (self._gateway,)

    @property
    def gateway(self) -> Gateway:
        return self._gateway

    # -- unified invocation flow ---------------------------------------------------

    def invoke(
        self,
        function: Union[str, Invocation],
        *,
        tag: Optional[str] = None,
        model_id: Optional[str] = None,
        request_id: int = 0,
        trace: bool = False,
        retry: Optional[RetryPolicy] = None,
        now: Optional[float] = None,
    ) -> Placement:
        """Route **and** admit one invocation; returns its :class:`Placement`.

        This is the paper's full request path in one call: the gateway
        resolves the policy tag to a (controller, worker) pair, and the
        admission is recorded immediately so the very next decision sees
        the slot occupancy and running-function multiset this one created.
        Unscheduled invocations return an un-admitted placement (check
        ``scheduled`` / ``failed_by_policy``).

        With a :class:`RetryPolicy` in force (the ``retry`` argument, the
        routed controller's spec, or the platform default — in that
        order), an invocation that finds *no valid worker* is re-routed
        up to ``max_attempts`` times with deterministic backoff charged
        to ``Placement.retry_wait``. A tAPP ``followup: fail`` policy
        failure is terminal and never retried (paper §3.3).

        With an :class:`OverloadSpec` queue configured, an invocation
        that still finds no capacity after retries is *parked* in the
        admission queue instead of failing (``Placement.queued``); a
        later ``complete()`` drains it through the same route path.
        ``now`` is the caller's clock, stamped on the queue entry so
        deadlines can expire (None: entries never expire).
        """
        invocation = self._coerce_invocation(function, tag, model_id,
                                             request_id)
        if self._lifecycle is not None and now is not None:
            # Lazy janitor: expire stale warm instances before routing,
            # so warm-first ranks against the warmth that exists at now.
            self._lifecycle.expire(now)
        placement = self.place(invocation, self._gateway.route(invocation,
                                                               trace=trace))
        if placement.scheduled:
            return placement
        placement = self._retry_unscheduled(invocation, placement, retry,
                                            trace=trace)
        # Queue armed → park instead of failing. Note a saturated tAPP
        # evaluation reports failed_by_policy (followup-fail exhaustion
        # IS the no-capacity outcome under a policy), so that flag does
        # not gate the queue; deadlines bound genuinely unplaceable work.
        if (not placement.scheduled
                and self._overload is not None
                and self._overload.queue is not None):
            placement = self._enqueue_overflow(placement, None, now)
        return placement

    def _retry_unscheduled(
        self,
        invocation: Invocation,
        placement: Placement,
        override: Optional[RetryPolicy],
        *,
        trace: bool = False,
    ) -> Placement:
        """Re-route an unscheduled invoke under the resolved retry policy
        (off the fast path — only entered when the first route failed)."""
        if placement.failed_by_policy:
            return placement
        policy = self._retry_policy_for(placement.controller, override)
        if policy is None:
            return placement
        attempts, waited = placement.attempts, placement.retry_wait
        while (not placement.scheduled
               and not placement.failed_by_policy
               and policy.allows(attempts, waited)):
            waited += policy.backoff(attempts)
            attempts += 1
            self._retries += 1
            placement = self.place(
                invocation, self._gateway.route(invocation, trace=trace)
            )
        placement.attempts = attempts
        placement.retry_wait = waited
        return placement

    def retry(
        self,
        placement: Placement,
        *,
        retry: Optional[RetryPolicy] = None,
    ) -> Optional[Placement]:
        """Re-route a failed placement around the workers it already tried.

        Returns the replacement :class:`Placement` (carrying cumulative
        ``attempts`` / ``retry_wait`` / ``failed_workers`` bookkeeping),
        or ``None`` when no retry is issued: no policy in force, the
        policy's attempt/deadline budget is spent, or the original
        failure was a tAPP ``followup: fail`` — a *policy* verdict, which
        is terminal (only *worker* failures retry; paper §3.3).

        The caller owns the old ticket: a crashed worker's ticket was
        already reconciled as an eviction, a timed-out one should be
        completed (``slow=True``) by whoever declared the timeout.
        """
        policy = self._retry_policy_for(placement.controller, retry)
        if policy is None or placement.failed_by_policy:
            return None
        if not policy.allows(placement.attempts, placement.retry_wait):
            return None
        failed = placement.failed_workers
        if placement.worker is not None:
            failed = failed + (placement.worker,)
        self._retries += 1
        invocation = placement.invocation
        replacement = self._masked_route(
            failed,
            lambda: self.place(invocation, self._gateway.route(invocation)),
        )
        replacement.attempts = placement.attempts + 1
        replacement.retry_wait = (
            placement.retry_wait + policy.backoff(placement.attempts)
        )
        replacement.failed_workers = failed
        return replacement

    def invoke_batch(
        self,
        invocations: Iterable[Union[str, Invocation]],
        *,
        trace: bool = False,
        on_placement: Optional[Callable[[Placement], None]] = None,
        retry: Optional[RetryPolicy] = None,
        now: Optional[float] = None,
    ) -> List[Placement]:
        """Route + admit a batch against one script/snapshot resolution.

        Each invocation is admitted before the next is routed (and
        ``on_placement`` fires in between), so results are bit-identical
        to a sequence of :meth:`invoke` calls — including policies whose
        affinity constraints read the placements made earlier in the same
        batch, and including the unscheduled-retry loop when a
        :class:`RetryPolicy` is in force (its re-routes interleave into
        the batch exactly where sequential invokes would place them),
        and including the admission-queue overflow path when an
        :class:`OverloadSpec` queue is armed.
        """
        invs = [
            inv if isinstance(inv, Invocation) else Invocation(function=inv)
            for inv in invocations
        ]
        if self._lifecycle is not None and now is not None:
            # One janitor tick for the whole batch: the batch resolves
            # against a single snapshot, so warmth expires once, up
            # front, exactly like a sequence of invokes at equal now.
            self._lifecycle.expire(now)
        placements: List[Placement] = []
        queue_armed = (
            self._overload is not None and self._overload.queue is not None
        )

        def _admit(invocation: Invocation, decision: ScheduleDecision) -> None:
            placement = self.place(invocation, decision)
            if not placement.scheduled:
                placement = self._retry_unscheduled(invocation, placement,
                                                    retry, trace=trace)
                if queue_armed and not placement.scheduled:
                    placement = self._enqueue_overflow(placement, None, now)
            placements.append(placement)
            if on_placement is not None:
                on_placement(placement)

        self._gateway.route_batch(invs, trace=trace, on_decision=_admit)
        return placements

    # -- observability ---------------------------------------------------------------

    def explain(
        self,
        function: Union[str, Invocation],
        *,
        tag: Optional[str] = None,
        model_id: Optional[str] = None,
    ) -> ExplainReport:
        """Why would this invocation schedule where it does (or fail)?

        Evaluates the invocation with tracing on and lifts the trace into
        a typed per-block / per-worker rejection report. Side-effect-free:
        nothing is admitted, gateway stats are untouched, and the engine's
        RNG stream / controller cursors are restored afterwards, so
        explaining between two real invokes never changes the second one.
        Rejected candidates the active policy can *never* accept (per the
        static analyzer) are marked statically inevitable.
        """
        invocation = self._coerce_invocation(function, tag, model_id)
        decision = self._gateway.probe(invocation)
        report = build_explain_report(invocation, decision)
        report = self._annotate_explain(report, invocation.tag, None)
        note = self._overload_note(None)
        if note is not None:
            report = dataclasses.replace(
                report, failure_notes=report.failure_notes + (note,)
            )
        return report

    def _drain_route(
        self,
        zone: Optional[str],
        invocation: Invocation,
        script: Optional[TappScript] = None,
    ) -> ScheduleDecision:
        return self._gateway.route(invocation, script=script)

    def prewarm(self) -> int:
        """Eagerly build the scheduler's candidate indexes for the active
        policy against the live topology (see :meth:`Gateway.prewarm`).

        Useful right after :meth:`apply_policy` or a batch of topology
        changes, so the lazy index build does not land on the first live
        invocation. Returns the number of block indexes warmed.
        """
        return self._gateway.prewarm()

    def stats(self) -> PlatformStats:
        gw = self._gateway.stats
        return self._platform_stats(
            routed=gw.routed,
            tapp_routed=gw.tapp_routed,
            vanilla_routed=gw.vanilla_routed,
            failed=gw.failed,
            script_reloads=gw.script_reloads,
        )
