"""Seeded fault injection for failure-domain testing (PR 6).

A :class:`ChaosSpec` declares *how much* chaos (worker crashes, degraded
workers, flappy heartbeats, controller losses, inter-zone partitions)
over a time horizon; :class:`FaultInjector` expands it — with one
``random.Random(seed)`` stream, so the schedule is a pure function of
the spec — into a sorted list of :class:`FaultEvent` pairs
(crash/recover, sever/heal, …) and knows how to apply each one to a
platform façade. The injector drives two consumers:

* the discrete-event simulator threads the events into its heap as
  ``"fault"`` events (``Simulation(chaos=...)``), so faults interleave
  deterministically with request traffic;
* the chaos property tests (``tests/test_chaos.py``) replay schedules
  against a live platform and assert the ledger/robustness invariants
  after every step.

Chaos is strictly additive: with no spec (or an all-zero one) the
schedule is empty, no platform call is made, and placements, traces,
and RNG streams are bit-identical to a chaos-free run — property-tested
alongside the invariants.
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Optional, Sequence, Tuple

#: Event kinds, in the order pairs are emitted (each fault kind emits a
#: start event and, where applicable, its recovery twin).
KINDS = (
    "crash", "recover",          # worker DEAD → restored
    "degrade", "restore_perf",   # worker perf_factor inflated → nominal
    "flap_down", "flap_up",      # worker SUSPECT → restored (flappy lease)
    "controller_down", "controller_up",
    "sever", "heal",             # inter-zone partition (federations only)
    # Traffic-side fault (PR 9): arrival-rate multiplier against one zone
    # for a duration. The platform itself is untouched — the simulator
    # consumes the window to amplify offered load, exercising the
    # admission-queue / shedding / brownout overload path.
    "overload_burst", "burst_end",
)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: apply ``kind`` to ``target`` at time ``at``.

    ``target`` is a worker name, a controller name, or — for
    ``sever``/``heal`` — a ``(zone_a, zone_b)`` pair. Paired events
    (crash/recover, …) share a target; ``until`` on the *start* event
    records when its twin fires (provenance only; the twin is a separate
    event in the schedule). ``value`` carries kind-specific payload
    (the degraded ``perf_factor``).
    """

    at: float
    kind: str
    target: object
    until: Optional[float] = None
    value: Optional[float] = None

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    """How much seeded chaos to inject over ``horizon`` seconds.

    Counts are *event pair* counts (each crash schedules its recovery
    too, unless the downtime would outlive the horizon — a fault may
    outlive the run, which is exactly the non-recovered-crash case the
    invariants must survive). All randomness comes from ``seed``; two
    specs with equal fields expand to identical schedules.
    """

    seed: int = 0
    horizon: float = 60.0
    worker_crashes: int = 0
    crash_downtime: float = 8.0
    degraded_events: int = 0
    degraded_duration: float = 6.0
    degraded_factor: float = 4.0
    flappy_workers: int = 0
    flap_period: float = 2.0
    controller_losses: int = 0
    controller_downtime: float = 5.0
    partitions: int = 0
    partition_duration: float = 10.0
    overload_bursts: int = 0
    burst_duration: float = 5.0
    burst_factor: float = 3.0

    def __post_init__(self) -> None:
        if self.horizon <= 0:
            raise ValueError("horizon must be > 0")
        for field in ("worker_crashes", "degraded_events", "flappy_workers",
                      "controller_losses", "partitions", "overload_bursts"):
            if getattr(self, field) < 0:
                raise ValueError(f"{field} must be >= 0")
        for field in ("crash_downtime", "degraded_duration", "flap_period",
                      "controller_downtime", "partition_duration",
                      "burst_duration"):
            if getattr(self, field) <= 0:
                raise ValueError(f"{field} must be > 0")
        if self.degraded_factor < 1.0:
            raise ValueError("degraded_factor must be >= 1.0")
        if self.burst_factor < 1.0:
            raise ValueError("burst_factor must be >= 1.0")

    @property
    def total_faults(self) -> int:
        return (self.worker_crashes + self.degraded_events
                + self.flappy_workers + self.controller_losses
                + self.partitions + self.overload_bursts)


class FaultInjector:
    """Expands a :class:`ChaosSpec` into a deterministic fault schedule
    and applies events to a platform façade.

    ``workers`` / ``controllers`` / ``zones`` name the targets faults
    may pick from (pass the deployment's; zone pairs are only drawn when
    two or more zones exist). The schedule is computed once, eagerly, in
    :meth:`schedule` order; :meth:`apply` maps each event onto the
    platform's failure-detection API (``fail_worker`` / ``restore`` /
    ``suspect_worker`` / ``heartbeat`` / ``update_controller`` /
    ``sever`` / ``heal``), tolerating targets that disappeared since
    scheduling (a deregistered worker) by skipping the event — every
    skip is recorded in :attr:`skipped` with its reason, so a chaos run
    whose schedule silently stopped biting is visible after the fact.
    """

    def __init__(
        self,
        spec: ChaosSpec,
        workers: Sequence[str],
        controllers: Sequence[str] = (),
        zones: Sequence[str] = (),
    ) -> None:
        self.spec = spec
        self._workers = tuple(workers)
        self._controllers = tuple(controllers)
        self._zones = tuple(zones)
        self._schedule: Optional[Tuple[FaultEvent, ...]] = None
        #: Events that did not take effect at apply time, with reasons.
        self.skipped: List[Tuple[FaultEvent, str]] = []

    # -- schedule construction ---------------------------------------------------

    def schedule(self) -> Tuple[FaultEvent, ...]:
        """The full fault schedule, sorted by time (memoized; pure in the
        spec + target lists)."""
        if self._schedule is None:
            self._schedule = tuple(sorted(
                self._expand(), key=lambda e: (e.at, KINDS.index(e.kind),
                                               str(e.target))
            ))
        return self._schedule

    def _expand(self) -> List[FaultEvent]:
        spec = self.spec
        rng = random.Random(spec.seed)
        events: List[FaultEvent] = []

        def _paired(count, targets, start_kind, end_kind, duration,
                    value=None):
            for _ in range(count):
                if not targets:
                    return
                target = targets[rng.randrange(len(targets))]
                at = rng.uniform(0.0, spec.horizon)
                until = at + duration
                if until <= spec.horizon:
                    events.append(FaultEvent(at, start_kind, target,
                                             until=until, value=value))
                    events.append(FaultEvent(until, end_kind, target,
                                             value=value))
                else:
                    # The fault outlives the run — no recovery twin.
                    events.append(FaultEvent(at, start_kind, target,
                                             value=value))

        _paired(spec.worker_crashes, self._workers, "crash", "recover",
                spec.crash_downtime)
        _paired(spec.degraded_events, self._workers, "degrade",
                "restore_perf", spec.degraded_duration,
                value=spec.degraded_factor)
        _paired(spec.flappy_workers, self._workers, "flap_down", "flap_up",
                spec.flap_period)
        _paired(spec.controller_losses, self._controllers, "controller_down",
                "controller_up", spec.controller_downtime)
        if len(self._zones) >= 2:
            pairs = [
                (a, b)
                for i, a in enumerate(self._zones)
                for b in self._zones[i + 1:]
            ]
            _paired(spec.partitions, pairs, "sever", "heal",
                    spec.partition_duration)
        # Drawn last so a default (zero-burst) spec consumes exactly the
        # PR-6 stream — schedules stay bit-identical per seed.
        _paired(spec.overload_bursts, self._zones, "overload_burst",
                "burst_end", spec.burst_duration, value=spec.burst_factor)
        return events

    # -- application --------------------------------------------------------------

    def apply(self, event: FaultEvent, platform, *, now: float = 0.0) -> bool:
        """Apply one event to ``platform``; returns whether it took effect
        (False: the target no longer exists, or the façade lacks the
        capability — e.g. ``sever`` on a single-zone platform). A False
        return is never silent: the (event, reason) pair lands in
        :attr:`skipped`."""
        kind, target = event.kind, event.target
        try:
            if kind == "crash":
                platform.fail_worker(target)
            elif kind == "recover":
                platform.restore(target)
                # Restart the lease clock too, or the next check_leases
                # sweep would immediately re-kill the revived worker.
                platform.heartbeat_lease(target, now)
            elif kind == "degrade":
                platform.heartbeat(target, perf_factor=float(event.value))
            elif kind == "restore_perf":
                platform.heartbeat(target, perf_factor=1.0)
            elif kind == "flap_down":
                platform.suspect_worker(target)
            elif kind == "flap_up":
                platform.restore(target)
                platform.heartbeat_lease(target, now)
            elif kind == "controller_down":
                return self._set_controller(platform, event, False)
            elif kind == "controller_up":
                return self._set_controller(platform, event, True)
            elif kind in ("sever", "heal"):
                if not hasattr(platform, kind):
                    return self._skip(
                        event, "platform has no inter-zone links"
                    )
                getattr(platform, kind)(*target)
            elif kind in ("overload_burst", "burst_end"):
                # Traffic-side fault: nothing to do to the platform — the
                # simulator consumes the window to amplify arrivals. Still
                # validate the target so a burst against a zone the
                # deployment no longer has is reported, not ignored.
                zones = getattr(platform, "zones", None)
                if zones is not None and target not in zones:
                    return self._skip(event, f"unknown zone {target!r}")
            else:  # pragma: no cover - KINDS-validated at construction
                raise ValueError(f"unknown fault kind {kind!r}")
        except KeyError:
            return self._skip(event, "target deregistered since scheduling")
        return True

    def _skip(self, event: FaultEvent, reason: str) -> bool:
        self.skipped.append((event, reason))
        return False

    def _set_controller(self, platform, event: FaultEvent,
                        healthy: bool) -> bool:
        name = event.target
        controller = platform.watcher.cluster.controllers.get(name)
        if controller is None:
            return self._skip(event, f"unknown controller {name!r}")
        platform.watcher.update_controller(name, healthy=healthy,
                                           reachable=healthy)
        return True
