"""Declarative cluster construction for the platform façade.

A :class:`ClusterSpec` is the serialisable description of a deployment —
workers with their zones/sets/capacities and the per-zone controllers —
that :class:`~repro.core.platform.TappPlatform` turns into live state.
It replaces the ad-hoc ``make_cluster`` + field-mutation pattern: specs
are frozen values, so a deployment can be permuted (the paper's
redeploy-every-N-repetitions methodology), diffed, or embedded in a
scenario table, and the *live* mutable state only ever exists behind the
watcher.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Iterable, Mapping, Tuple, Union

from repro.core.scheduler.state import (
    ClusterState,
    ControllerState,
    WorkerState,
)

_DEFAULT_MEMORY = 16 * 1024**3


@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """Declarative description of one worker (model replica / invoker)."""

    name: str
    zone: str = "default"
    sets: Tuple[str, ...] = ()
    capacity_slots: int = 16
    resident_models: Tuple[str, ...] = ()
    memory_bytes: int = _DEFAULT_MEMORY
    perf_factor: float = 1.0

    def build(self) -> WorkerState:
        return WorkerState(
            name=self.name,
            zone=self.zone,
            sets=frozenset(self.sets),
            capacity_slots=self.capacity_slots,
            resident_models=frozenset(self.resident_models),
            memory_bytes=self.memory_bytes,
            perf_factor=self.perf_factor,
        )

    @classmethod
    def coerce(
        cls, value: Union["WorkerSpec", WorkerState, Mapping]
    ) -> "WorkerSpec":
        if isinstance(value, cls):
            return value
        if isinstance(value, WorkerState):
            return cls(
                name=value.name,
                zone=value.zone,
                sets=tuple(sorted(value.sets)),
                capacity_slots=value.capacity_slots,
                resident_models=tuple(sorted(value.resident_models)),
                memory_bytes=value.memory_bytes,
                perf_factor=value.perf_factor,
            )
        fields = dict(value)
        for key in ("sets", "resident_models"):
            if key in fields:
                fields[key] = tuple(fields[key])
        return cls(**fields)


@dataclasses.dataclass(frozen=True)
class ControllerSpec:
    """Declarative description of one per-zone controller."""

    name: str
    zone: str = "default"

    def build(self) -> ControllerState:
        return ControllerState(name=self.name, zone=self.zone)

    @classmethod
    def coerce(
        cls, value: Union["ControllerSpec", ControllerState, Mapping]
    ) -> "ControllerSpec":
        if isinstance(value, cls):
            return value
        if isinstance(value, ControllerState):
            return cls(name=value.name, zone=value.zone)
        return cls(**dict(value))


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """A whole deployment: controllers + workers, in registration order.

    Registration order matters to the vanilla baseline (its co-prime home
    depends on it), which is why :meth:`shuffled` exists: one seed = one
    deployment permutation, reproducing the paper's methodology of
    redeploying the platform between repetitions.
    """

    workers: Tuple[WorkerSpec, ...] = ()
    controllers: Tuple[ControllerSpec, ...] = ()

    @classmethod
    def of(
        cls,
        workers: Iterable[Union[WorkerSpec, WorkerState, Mapping]] = (),
        controllers: Iterable[Union[ControllerSpec, ControllerState, Mapping]] = (),
    ) -> "ClusterSpec":
        """Coerce plain dicts / live states into a spec (config-file path)."""
        return cls(
            workers=tuple(WorkerSpec.coerce(w) for w in workers),
            controllers=tuple(ControllerSpec.coerce(c) for c in controllers),
        )

    def shuffled(self, seed: int) -> "ClusterSpec":
        """The same deployment with worker registration order permuted."""
        workers = list(self.workers)
        random.Random(seed).shuffle(workers)
        return dataclasses.replace(self, workers=tuple(workers))

    def build(self) -> ClusterState:
        """Materialise live cluster state (duplicate names raise here)."""
        cluster = ClusterState()
        for controller in self.controllers:
            cluster.add_controller(controller.build())
        for worker in self.workers:
            cluster.add_worker(worker.build())
        return cluster
