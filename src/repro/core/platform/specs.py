"""Declarative cluster construction for the platform façade.

A :class:`ClusterSpec` is the serialisable description of a deployment —
workers with their zones/sets/capacities and the per-zone controllers —
that :class:`~repro.core.platform.TappPlatform` turns into live state.
It replaces the ad-hoc ``make_cluster`` + field-mutation pattern: specs
are frozen values, so a deployment can be permuted (the paper's
redeploy-every-N-repetitions methodology), diffed, or embedded in a
scenario table, and the *live* mutable state only ever exists behind the
watcher.

A :class:`FederationSpec` is the multi-zone sibling (PR 5): an ordered
mapping of zone name → :class:`ClusterSpec` slice plus an inter-zone
network model, which
:class:`~repro.core.platform.federation.TappFederation` turns into one
shared cluster with a per-zone gateway per slice. The network model is
duck-typed — anything with ``get_rtt(a, b)`` works, notably the
simulator's ``NetworkModel`` — so the platform layer never imports the
simulator.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Iterable, Mapping, Optional, Tuple, Union

from repro.core.scheduler.state import (
    ClusterState,
    ControllerState,
    WorkerState,
)

_DEFAULT_MEMORY = 16 * 1024**3


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Deterministic retry/backoff policy for worker-failure re-routing.

    ``max_attempts`` bounds total attempts (first try included); backoff
    before retry *k* (1-based) is ``backoff_base * backoff_multiplier**(k-1)``
    — deterministic, no jitter, so seeded runs reproduce bit-for-bit.
    ``deadline`` caps the cumulative backoff a request may accumulate
    (a per-function latency budget); a retry whose backoff would exceed
    it is not issued. Retries apply to *worker* failures (crash, timeout,
    no valid worker); a tAPP ``followup: fail`` policy failure is
    terminal and never retried (paper §3.3 semantics).
    """

    max_attempts: int = 3
    backoff_base: float = 0.05
    backoff_multiplier: float = 2.0
    deadline: Optional[float] = None

    def __post_init__(self) -> None:
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.backoff_base < 0:
            raise ValueError("backoff_base must be >= 0")
        if self.backoff_multiplier <= 0:
            raise ValueError("backoff_multiplier must be > 0")
        if self.deadline is not None and self.deadline < 0:
            raise ValueError("deadline must be >= 0")

    def backoff(self, attempts_made: int) -> float:
        """Wait (seconds) before the retry following ``attempts_made``
        attempts (>= 1)."""
        return self.backoff_base * self.backoff_multiplier ** (attempts_made - 1)

    def allows(self, attempts_made: int, waited: float = 0.0) -> bool:
        """May another attempt be issued after ``attempts_made`` tries and
        ``waited`` seconds of cumulative backoff?"""
        if attempts_made >= self.max_attempts:
            return False
        if self.deadline is not None:
            return waited + self.backoff(attempts_made) <= self.deadline
        return True


@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """Declarative description of one worker (model replica / invoker).

    ``keep_alive`` overrides the platform's
    :class:`~repro.core.platform.lifecycle.LifecycleSpec` keep-alive
    window for instances pooled on this worker (None: inherit; inert
    when the lifecycle layer is unarmed).
    """

    name: str
    zone: str = "default"
    sets: Tuple[str, ...] = ()
    capacity_slots: int = 16
    resident_models: Tuple[str, ...] = ()
    memory_bytes: int = _DEFAULT_MEMORY
    perf_factor: float = 1.0
    keep_alive: Optional[float] = None

    def __post_init__(self) -> None:
        if self.keep_alive is not None and self.keep_alive <= 0:
            raise ValueError(
                f"keep_alive must be positive, got {self.keep_alive}"
            )

    def build(self) -> WorkerState:
        return WorkerState(
            name=self.name,
            zone=self.zone,
            sets=frozenset(self.sets),
            capacity_slots=self.capacity_slots,
            resident_models=frozenset(self.resident_models),
            memory_bytes=self.memory_bytes,
            perf_factor=self.perf_factor,
            keep_alive=self.keep_alive,
        )

    @classmethod
    def coerce(
        cls, value: Union["WorkerSpec", WorkerState, Mapping]
    ) -> "WorkerSpec":
        if isinstance(value, cls):
            return value
        if isinstance(value, WorkerState):
            return cls(
                name=value.name,
                zone=value.zone,
                sets=tuple(sorted(value.sets)),
                capacity_slots=value.capacity_slots,
                resident_models=tuple(sorted(value.resident_models)),
                memory_bytes=value.memory_bytes,
                perf_factor=value.perf_factor,
                keep_alive=value.keep_alive,
            )
        fields = dict(value)
        for key in ("sets", "resident_models"):
            if key in fields:
                fields[key] = tuple(fields[key])
        return cls(**fields)


@dataclasses.dataclass(frozen=True)
class ControllerSpec:
    """Declarative description of one per-zone controller.

    ``retry`` is the :class:`RetryPolicy` for invocations this controller
    schedules (None: the platform-level default, if any). It is platform
    configuration, not live state — :class:`ControllerState` does not
    carry it; the platform façade resolves it per placement.
    ``keep_alive`` likewise overrides the platform lifecycle's
    keep-alive window for instances completed under this controller
    (resolution: worker > controller > spec default; inert unarmed).
    """

    name: str
    zone: str = "default"
    retry: Optional[RetryPolicy] = None
    keep_alive: Optional[float] = None

    def __post_init__(self) -> None:
        if self.keep_alive is not None and self.keep_alive <= 0:
            raise ValueError(
                f"keep_alive must be positive, got {self.keep_alive}"
            )

    def build(self) -> ControllerState:
        return ControllerState(name=self.name, zone=self.zone)

    @classmethod
    def coerce(
        cls, value: Union["ControllerSpec", ControllerState, Mapping]
    ) -> "ControllerSpec":
        if isinstance(value, cls):
            return value
        if isinstance(value, ControllerState):
            return cls(name=value.name, zone=value.zone)
        fields = dict(value)
        if isinstance(fields.get("retry"), Mapping):
            fields["retry"] = RetryPolicy(**fields["retry"])
        return cls(**fields)


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """A whole deployment: controllers + workers, in registration order.

    Registration order matters to the vanilla baseline (its co-prime home
    depends on it), which is why :meth:`shuffled` exists: one seed = one
    deployment permutation, reproducing the paper's methodology of
    redeploying the platform between repetitions.
    """

    workers: Tuple[WorkerSpec, ...] = ()
    controllers: Tuple[ControllerSpec, ...] = ()

    @classmethod
    def of(
        cls,
        workers: Iterable[Union[WorkerSpec, WorkerState, Mapping]] = (),
        controllers: Iterable[Union[ControllerSpec, ControllerState, Mapping]] = (),
    ) -> "ClusterSpec":
        """Coerce plain dicts / live states into a spec (config-file path)."""
        return cls(
            workers=tuple(WorkerSpec.coerce(w) for w in workers),
            controllers=tuple(ControllerSpec.coerce(c) for c in controllers),
        )

    def shuffled(self, seed: int) -> "ClusterSpec":
        """The same deployment with worker registration order permuted."""
        workers = list(self.workers)
        random.Random(seed).shuffle(workers)
        return dataclasses.replace(self, workers=tuple(workers))

    def build(self) -> ClusterState:
        """Materialise live cluster state (duplicate names raise here)."""
        cluster = ClusterState()
        for controller in self.controllers:
            cluster.add_controller(controller.build())
        for worker in self.workers:
            cluster.add_worker(worker.build())
        return cluster


def _coerce_zone_slice(zone: str, spec) -> ClusterSpec:
    """Coerce one zone's slice, pinning every member to the zone.

    Members declared with the default zone are adopted into the
    federation zone; an explicit *different* zone is a contradiction and
    raises — a slice cannot smuggle workers into another zone.
    """
    if not isinstance(spec, ClusterSpec):
        spec = ClusterSpec.of(**dict(spec))

    def _pin(member):
        if member.zone in ("default", zone):
            return dataclasses.replace(member, zone=zone)
        raise ValueError(
            f"zone slice {zone!r} declares {member.name!r} with "
            f"contradictory zone {member.zone!r}"
        )

    return ClusterSpec(
        workers=tuple(_pin(w) for w in spec.workers),
        controllers=tuple(_pin(c) for c in spec.controllers),
    )


@dataclasses.dataclass(frozen=True)
class FederationSpec:
    """A multi-zone deployment: ordered zone → :class:`ClusterSpec` slices.

    ``network`` is any object exposing ``get_rtt(zone_a, zone_b) ->
    seconds`` (e.g. the simulator's ``NetworkModel``); it prices the
    cross-zone forwarding hops and orders forward targets latency-first.
    Without one, hops are free and forwarding follows declaration order.
    ``default_entry`` names the zone ``invoke`` enters when the caller
    does not say (defaults to the first declared zone).
    """

    zones: Tuple[Tuple[str, ClusterSpec], ...] = ()
    network: Optional[object] = None
    default_entry: Optional[str] = None

    def __post_init__(self) -> None:
        pairs = tuple((name, _coerce_zone_slice(name, spec))
                      for name, spec in self.zones)
        names = [name for name, _ in pairs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate federation zone in {names}")
        object.__setattr__(self, "zones", pairs)
        if self.default_entry is not None and self.default_entry not in names:
            raise ValueError(
                f"default_entry {self.default_entry!r} is not a federation "
                f"zone (have {names})"
            )
        if self.network is not None and not hasattr(self.network, "get_rtt"):
            raise TypeError(
                "network must expose get_rtt(zone_a, zone_b) (e.g. "
                "repro.core.sim.NetworkModel)"
            )

    @classmethod
    def of(
        cls,
        zones: Mapping[str, Union[ClusterSpec, Mapping]],
        *,
        network: Optional[object] = None,
        default_entry: Optional[str] = None,
    ) -> "FederationSpec":
        """Build from a zone-name mapping (insertion order = zone order)."""
        return cls(
            zones=tuple(zones.items()),
            network=network,
            default_entry=default_entry,
        )

    @property
    def zone_names(self) -> Tuple[str, ...]:
        return tuple(name for name, _ in self.zones)

    @property
    def entry_zone(self) -> str:
        """The zone ``invoke`` enters when the caller does not specify."""
        if not self.zones:
            raise ValueError("federation spec declares no zones")
        return self.default_entry or self.zones[0][0]

    def get(self, zone: str) -> ClusterSpec:
        for name, spec in self.zones:
            if name == zone:
                return spec
        raise KeyError(zone)

    def merged(self) -> ClusterSpec:
        """The whole federation as one flat deployment, in zone order."""
        return ClusterSpec(
            workers=tuple(w for _, s in self.zones for w in s.workers),
            controllers=tuple(c for _, s in self.zones for c in s.controllers),
        )

    def build(self) -> ClusterState:
        """Materialise the shared live cluster state of all zones."""
        return self.merged().build()

    def shuffled(self, seed: int) -> "FederationSpec":
        """Permute worker registration order *within* each zone slice.

        Zone membership is structural here, so the paper's
        redeploy-permutation methodology applies per slice; one seed
        permutes every slice deterministically.
        """
        rng = random.Random(seed)
        shuffled = []
        for name, spec in self.zones:
            workers = list(spec.workers)
            rng.shuffle(workers)
            shuffled.append(
                (name, dataclasses.replace(spec, workers=tuple(workers)))
            )
        return dataclasses.replace(self, zones=tuple(shuffled))

    def rtt(self, zone_a: str, zone_b: str) -> float:
        """Inter-zone RTT in seconds (0.0 without a network model)."""
        if self.network is None:
            return 0.0
        return float(self.network.get_rtt(zone_a, zone_b))

    def zone_order_from(self, entry: str) -> Tuple[str, ...]:
        """Every *other* zone, nearest-first from ``entry``.

        Ties (and the no-network case) fall back to declaration order —
        the latency-aware forwarding order of this entrypoint.
        """
        others = [
            (self.rtt(entry, name), index, name)
            for index, name in enumerate(self.zone_names)
            if name != entry
        ]
        others.sort()
        return tuple(name for _, _, name in others)
