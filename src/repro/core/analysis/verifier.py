"""Reachability, satisfiability, and starvation analysis of compiled plans.

The analyzer evaluates a :class:`~repro.core.tapp.compile.CompiledScript`
against a topology snapshot and proves, per (tag × entry zone), using only
facts that cannot change within a topology epoch:

* **reachability** — whether the tag's plan (its own blocks plus the
  ``followup: default`` chain) reaches at least one statically-valid
  worker, reporting blocks that are dead under every resolvable
  controller;
* **satisfiability** — contradictory constraint conjunctions detected per
  worker item (affinity ∧ anti-affinity over the same functions, admission
  limits of zero) and items whose ``BlockIndex`` static survivor set is
  empty;
* **starvation bounds** — per tag, the maximum number of concurrent
  admissions the statically-valid candidate set can absorb before every
  candidate saturates. The bound combines the per-item invalidate ceilings
  (``overload`` → capacity, ``max_concurrent_invocations`` → the limit,
  ``capacity_used`` → the smallest admission count that trips the runtime
  percentage signal) with the per-controller entitlement caps the
  distribution policy grants, so a bound of 0 is a *proof* that no
  sequence of admissions ever places the tag.

Federated deployments are analyzed per entry zone with the engine's
tolerance none/same pinning applied; a per-entry-zone verdict folds in the
zones the federation would forward to (:func:`forward_targets`), so
"unplaceable from zone Z" accounts for cross-zone forwarding and is never
a false alarm for a script that legitimately relies on it.

Everything here is *sound in one direction*: affinity residues are
dynamic (they depend on what is running where), so a non-contradictory
affinity clause never lowers a bound — bounds are upper bounds (flagged
``exact=False``) and a zero bound therefore remains a proof.

The analyzer reuses the scheduler's epoch-cached view entries and block
indexes (:func:`cached_view_entry` / :meth:`ItemIndex.static_survivors`),
so running it doubles as a prewarm of the exact structures the compiled
fast path consumes, and its survivor sets are — by construction — the
ones scheduling decisions will see.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, Set, Tuple

from repro.core.scheduler.gateway import forward_targets
from repro.core.scheduler.state import ClusterState, ControllerState
from repro.core.scheduler.strategy import Strategy
from repro.core.scheduler.topology import DistributionPolicy, cached_view_entry
from repro.core.tapp.ast import (
    CapacityUsed,
    FollowupKind,
    MaxConcurrentInvocations,
    Overload,
    TopologyTolerance,
)
from repro.core.tapp.compile import CompiledBlock, CompiledScript, CompiledTag
from repro.core.tapp.validate import Finding

__all__ = [
    "AnalysisReport",
    "BlockVerdict",
    "FederationView",
    "TagVerdict",
    "UNBOUNDED",
    "analyze_plan",
]

# Admission ceiling of a worker item whose static constraints impose no
# bound (e.g. capacity_used thresholds above 100%, which the runtime
# signal can never reach).
UNBOUNDED = math.inf


# ---------------------------------------------------------------------------
# Public result types
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FederationView:
    """Forwarding context for per-entry-zone analysis.

    ``zone_order`` maps each entry zone to its latency-ordered forwarding
    candidates — the same table the federation router consults — so the
    analyzer can fold forward-target zones into each entry zone's verdict.
    """

    zone_order: Mapping[str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class BlockVerdict:
    """Static verdict of one workers-block (within one entry-zone scan)."""

    tag: str
    index: int
    live: bool
    # Why the block is dead (None when live).
    reason: Optional[str]
    # Workers this block can select that also have a positive admission
    # ceiling in the owning tag's verdict.
    selectable: Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class TagVerdict:
    """Static verdict of one tag evaluated from one entry zone."""

    tag: str
    entry_zone: Optional[str]
    # ≥1 statically-valid candidate somewhere in the chain (incl. forwards).
    reachable: bool
    # Some admission sequence can place the tag (starvation_bound > 0).
    placeable: bool
    # Max concurrent admissions the static candidate set can absorb.
    starvation_bound: int
    # False when an affinity/anti-affinity residue makes the bound an
    # upper bound rather than an exact saturation count.
    exact: bool
    # (worker, absorbable admissions) for every worker with a positive
    # ceiling, merged over the chain and forward targets.
    admissible: Tuple[Tuple[str, int], ...]
    # Per-block verdicts of the *local* (entry-zone) scan, own tag's
    # blocks plus the followup chain's.
    blocks: Tuple[BlockVerdict, ...]

    @property
    def selectable(self) -> Tuple[str, ...]:
        return tuple(name for name, _absorb in self.admissible)


@dataclasses.dataclass(frozen=True)
class AnalysisReport:
    """Verdicts + findings of one analyzer run over one topology epoch."""

    verdicts: Tuple[TagVerdict, ...]
    findings: Tuple[Finding, ...]
    entry_zones: Tuple[Optional[str], ...]
    topology_epoch: int
    starvation_floor: int

    @property
    def proofs(self) -> Tuple[Finding, ...]:
        """Findings the analyzer *proved* (strict-mode deploy blockers)."""
        return tuple(f for f in self.findings if f.proof)

    @property
    def ok(self) -> bool:
        return not any(f.level == "error" for f in self.findings) and not self.proofs

    def tag(
        self, name: str, entry_zone: Optional[str] = None
    ) -> Optional[TagVerdict]:
        for v in self.verdicts:
            if v.tag == name and v.entry_zone == entry_zone:
                return v
        # Flat callers often pass the zone they are in even though the
        # analysis ran context-free; fall back to the tag's sole verdict.
        matches = [v for v in self.verdicts if v.tag == name]
        if len(matches) == 1:
            return matches[0]
        return None

    def selectable(
        self, name: str, entry_zone: Optional[str] = None
    ) -> Optional[frozenset]:
        """Workers some admission sequence can place ``name`` on, or None
        when the tag/zone was not analyzed (callers must not treat an
        un-analyzed tag as unplaceable)."""
        verdict = self.tag(name, entry_zone)
        if verdict is None:
            return None
        return frozenset(verdict.selectable)

    def summary(self) -> str:
        placeable = sum(1 for v in self.verdicts if v.placeable)
        return (
            f"analysis @epoch {self.topology_epoch}: "
            f"{placeable}/{len(self.verdicts)} tag×zone verdicts placeable, "
            f"{len(self.proofs)} unplaceability proofs, "
            f"{len(self.findings)} findings"
        )

    def verdict(self) -> str:
        """Human-readable report of every verdict and finding."""
        zones = [z if z is not None else "-" for z in self.entry_zones]
        lines = [
            f"policy analysis @epoch {self.topology_epoch} "
            f"(entry zones: {', '.join(zones)})"
        ]
        for v in self.verdicts:
            entry = "" if v.entry_zone is None else f" [entry={v.entry_zone}]"
            if v.placeable:
                kind = "bound" if v.exact else "bound ≤"
                detail = (
                    f"placeable, admission {kind} {v.starvation_bound} "
                    f"across {len(v.admissible)} worker(s)"
                )
            elif v.reachable:
                detail = (
                    "UNPLACEABLE — statically-valid candidates exist but "
                    "every admission ceiling is provably zero"
                )
            else:
                detail = "UNPLACEABLE — no statically-valid candidate"
            lines.append(f"  tag {v.tag!r}{entry}: {detail}")
            for b in v.blocks:
                owner = "" if b.tag == v.tag else f" (via tag {b.tag!r})"
                if b.live:
                    sel = ", ".join(b.selectable) if b.selectable else "-"
                    lines.append(
                        f"    block[{b.index}]{owner}: live, selectable: {sel}"
                    )
                else:
                    lines.append(
                        f"    block[{b.index}]{owner}: dead — {b.reason}"
                    )
        if self.findings:
            lines.append("findings:")
            lines.extend(f"  {f}" for f in self.findings)
        else:
            lines.append("no findings")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Admission ceilings (the satisfiability core)
# ---------------------------------------------------------------------------


def _capacity_used_ceiling(percent: float, slots: int) -> float:
    """Smallest admission count that trips the capacity_used signal.

    Mirrors the watcher's bookkeeping exactly: after ``k`` admissions on
    an otherwise idle worker, ``capacity_used_pct`` reads ``100*k/slots``
    while ``0 < k < slots`` and ``100.0`` otherwise, and the constraint
    invalidates at ``pct >= percent``.
    """
    if percent <= 0 or slots <= 0:
        return 0.0
    if percent > 100.0:
        return UNBOUNDED  # the signal caps at 100: threshold unreachable
    base = math.ceil(slots * percent / 100.0)
    for k in (base - 1, base, base + 1):
        if k < 1:
            continue
        if k >= slots:
            return float(slots)  # pct reads 100.0 ≥ percent
        if 100.0 * k / slots >= percent:
            return float(k)
    return float(slots)


def _invalidate_ceiling(condition, worker) -> float:
    """Admissions an idle worker absorbs before the condition invalidates."""
    if isinstance(condition, MaxConcurrentInvocations):
        return float(max(0, condition.limit))
    if isinstance(condition, CapacityUsed):
        return _capacity_used_ceiling(condition.percent, worker.capacity_slots)
    if isinstance(condition, Overload):
        return float(max(0, worker.capacity_slots))
    return UNBOUNDED  # unknown conditions: no static bound (stay sound)


def _spec_contradictions(spec) -> Tuple[str, ...]:
    """Why a constraint conjunction can never admit anything (if so)."""
    notes: List[str] = []
    aff = spec.affinity.functions if spec.affinity is not None else ()
    anti = spec.anti_affinity.functions if spec.anti_affinity is not None else ()
    overlap = sorted(set(aff) & set(anti))
    if overlap:
        shown = ", ".join(repr(f) for f in overlap)
        notes.append(
            f"affinity and anti-affinity both name {shown}: the item is "
            f"invalid whenever they run and starves them when they don't"
        )
    cond = spec.invalidate
    if isinstance(cond, MaxConcurrentInvocations) and cond.limit <= 0:
        notes.append(
            f"max_concurrent_invocations {cond.limit} admits nothing"
        )
    if isinstance(cond, CapacityUsed) and cond.percent <= 0:
        notes.append(f"capacity_used {cond.percent:g}% admits nothing")
    return tuple(notes)


# ---------------------------------------------------------------------------
# Per-(tag × entry zone) scans
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _ItemScan:
    tag: str
    block: int
    item: int
    contradictions: Tuple[str, ...]
    dynamic_affinity: bool
    survivors: frozenset  # statically-valid worker names
    positive: frozenset   # survivors with a positive admission ceiling


@dataclasses.dataclass
class _BlockScan:
    tag: str
    index: int
    live: bool
    reason: Optional[str]
    items: List[_ItemScan]
    survivors: frozenset


@dataclasses.dataclass
class _BlockEnt:
    """One chain block's admission resources, in evaluation order.

    The runtime consumes these *sequentially*: a later block only sees a
    worker after every earlier block went invalid for it, its inflight
    count carrying over (load signals are per worker, not per block) and
    its per-(controller, worker) entitlement ledger already drawn down.
    """

    ctls: Tuple[str, ...]
    # worker name -> [max dynamic ceiling over covering items,
    #                 {controller -> entitlement cap for this worker}]
    cover: Dict[str, list]


@dataclasses.dataclass
class _TagScan:
    entry_zone: Optional[str]
    # Chain blocks in evaluation order (the fold `_merge_bound` walks).
    entitlements: List[_BlockEnt]
    blocks: List[_BlockScan]
    exact: bool


def _chain(
    plan: CompiledScript, ctag: CompiledTag, cluster: ClusterState,
    entry_zone: Optional[str],
) -> List[Tuple[CompiledTag, Optional[str]]]:
    """The (tag, zone_override) evaluation chain the engine walks.

    The initial zone override *is* the entry zone; a ``followup: default``
    re-enters the default tag once, with the ``topology_tolerance: same``
    sticky-zone pinning applied (first sticky label present in the
    cluster wins, availability notwithstanding — engine semantics).
    """
    links = [(ctag, entry_zone)]
    if (
        ctag.followup is FollowupKind.DEFAULT
        and plan.default is not None
        and plan.default.tag != ctag.tag
    ):
        sticky = entry_zone
        for label in ctag.sticky_same_labels:
            designated = cluster.controllers.get(label)
            if designated is not None:
                sticky = designated.zone
                break
        links.append((plan.default, sticky))
    return links


def _block_contexts(
    cblock: CompiledBlock,
    cluster: ClusterState,
    zone_override: Optional[str],
    entry_zone: Optional[str],
) -> Tuple[List[Tuple[ControllerState, Optional[str]]], Optional[str]]:
    """Every (controller, zone restriction) the block may evaluate under.

    Mirrors ``TappEngine._c_block`` / ``_c_resolve_controller``, unioned
    over round-robin cursor states: the gateway cursor advances per
    decision, so over a request sequence every available alternative is
    eventually tried — the union is exactly the reachable context set.
    Returns ``([], reason)`` when the block is dead under every cursor.
    """
    clause = cblock.controller
    if clause is None:
        ctls = [c for c in cluster.controllers.values() if c.available]
        if entry_zone is not None:
            ctls = [c for c in ctls if c.zone == entry_zone]
        if not ctls:
            where = (
                f" in entry zone {entry_zone!r}"
                if entry_zone is not None
                else ""
            )
            return [], f"no available controller{where}"
        return [(c, zone_override) for c in ctls], None

    tol = clause.topology_tolerance
    designated = cluster.controllers.get(clause.label)
    if designated is not None and designated.available:
        if entry_zone is not None and tol is not TopologyTolerance.ALL:
            # Federated evaluation pins tolerance none/same candidates to
            # the designated controller's home zone.
            return [(designated, designated.zone)], None
        return [(designated, zone_override)], None

    if tol is TopologyTolerance.NONE:
        return [], (
            f"designated controller {clause.label!r} is unavailable and "
            f"tolerance=none forbids alternatives"
        )
    alternatives = [c for c in cluster.controllers.values() if c.available]
    if not alternatives:
        return [], (
            f"designated controller {clause.label!r} is unavailable and no "
            f"alternative controller is available"
        )
    if tol is TopologyTolerance.SAME:
        if designated is None:
            return [], (
                f"designated controller {clause.label!r} is unknown and "
                f"tolerance=same cannot resolve its zone"
            )
        return [(c, designated.zone) for c in alternatives], None
    return [(c, zone_override) for c in alternatives], None


def _scan_tag(
    plan: CompiledScript,
    ctag: CompiledTag,
    cluster: ClusterState,
    distribution: DistributionPolicy,
    entry_zone: Optional[str],
) -> _TagScan:
    """One entry zone's static scan of a tag's full evaluation chain."""
    entitlements: List[_BlockEnt] = []
    blocks: List[_BlockScan] = []
    exact = True
    for tag_c, zone_override in _chain(plan, ctag, cluster, entry_zone):
        if (
            len(tag_c.enumerated) > 1
            and tag_c.strategy is not Strategy.BEST_FIRST
        ):
            # The block-selection strategy may reorder blocks between
            # invocations; the fold assumes source order, so the bound
            # is an upper bound rather than an exact saturation count.
            exact = False
        for cblock in tag_c.blocks:
            contexts, dead = _block_contexts(
                cblock, cluster, zone_override, entry_zone
            )
            items = cblock.sets if cblock.uses_sets else cblock.wrks
            item_scans: List[_ItemScan] = []
            block_survivors: Set[str] = set()
            cover: Dict[str, list] = {}
            for j, item in enumerate(items):
                contradictions = _spec_contradictions(item.spec)
                dynamic_affinity = not contradictions and (
                    item.spec.affinity is not None
                    or item.spec.anti_affinity is not None
                )
                if dynamic_affinity:
                    # Affinity residues are load-dependent: ceilings stay
                    # upper bounds, never proofs of positive capacity.
                    exact = False
                survivors: Set[str] = set()
                positive: Set[str] = set()
                for ctl, restriction in contexts:
                    entry = cached_view_entry(
                        cluster,
                        ctl.zone,
                        distribution,
                        controller_name=ctl.name,
                        zone_restriction=restriction,
                    )
                    bindex = entry.block_index(cblock)
                    if cblock.uses_sets:
                        cands = bindex.sets[j].static_survivors()
                    else:
                        idx = bindex.wrk
                        # One shared index per wrk block: position == item.
                        if (idx.static_mask >> j) & 1:
                            cands = [(j, idx.workers[j], idx._sat_caps[j])]
                        else:
                            cands = []
                    for _pos, worker, sat_cap in cands:
                        survivors.add(worker.name)
                        ceiling = (
                            0.0
                            if contradictions
                            else _invalidate_ceiling(
                                item.spec.invalidate, worker
                            )
                        )
                        slot = cover.setdefault(worker.name, [0.0, {}])
                        if ceiling > slot[0]:
                            slot[0] = ceiling
                        if ceiling > 0.0 and sat_cap > 0:
                            ents = slot[1]
                            if sat_cap > ents.get(ctl.name, 0):
                                ents[ctl.name] = sat_cap
                            positive.add(worker.name)
                block_survivors |= survivors
                item_scans.append(
                    _ItemScan(
                        tag=tag_c.tag,
                        block=cblock.index,
                        item=j,
                        contradictions=contradictions,
                        dynamic_affinity=dynamic_affinity,
                        survivors=frozenset(survivors),
                        positive=frozenset(positive),
                    )
                )
            live = dead is None and bool(block_survivors)
            if dead is None and not live:
                dead = (
                    "no statically-valid candidate under any resolvable "
                    "controller"
                )
            blocks.append(
                _BlockScan(
                    tag=tag_c.tag,
                    index=cblock.index,
                    live=live,
                    reason=dead,
                    items=item_scans,
                    survivors=frozenset(block_survivors),
                )
            )
            if cover:
                entitlements.append(
                    _BlockEnt(
                        ctls=tuple(ctl.name for ctl, _r in contexts),
                        cover=cover,
                    )
                )
    return _TagScan(
        entry_zone=entry_zone,
        entitlements=entitlements,
        blocks=blocks,
        exact=exact,
    )


def _merge_bound(
    scans: Sequence[_TagScan],
) -> Tuple[int, Tuple[Tuple[str, int], ...], bool, bool]:
    """Fold scans into (bound, admissible workers, exact, reachable).

    ``scans`` arrive in evaluation order (the entry zone's local chain,
    then each forward target), and each scan's blocks are in chain
    order; the fold concatenates them and replays the runtime's
    sequential draw-down per worker: a block absorbs admissions while
    its dynamic ceiling exceeds the worker's carried-over inflight count
    AND one of its controllers has per-(controller, worker) entitlement
    left — the ledger is shared across blocks, so an earlier block's
    admissions spend the entitlements later blocks would use.

    When a multi-controller block precedes a block with a different-but-
    overlapping controller set, *which* controller each admission spends
    depends on the round-robin cursor; the fold then spends soonest-dying
    controllers first (an upper bound) and drops the ``exact`` flag. A
    zero bound is order-robust either way: if no block can absorb the
    first admission, no spending order can, so unplaceability proofs
    hold regardless.

    Saturation is order-independent *across workers* (ceilings and
    entitlements are per worker — affinity, the one cross-worker
    coupling, already clears ``exact``), so the tag bound is the plain
    per-worker sum.
    """
    exact = all(scan.exact for scan in scans)
    blocks: List[_BlockEnt] = [
        ent for scan in scans for ent in scan.entitlements
    ]
    for i, ent in enumerate(blocks):
        if len(set(ent.ctls)) <= 1:
            continue
        here = set(ent.ctls)
        for later in blocks[i + 1:]:
            there = set(later.ctls)
            if here & there and here != there:
                exact = False
    # Last fold position each controller is usable at, for the
    # spend-soonest-dying-first allocation.
    last_use: Dict[str, int] = {}
    for i, ent in enumerate(blocks):
        for ctl in ent.ctls:
            last_use[ctl] = i
    workers = sorted({w for ent in blocks for w in ent.cover})
    admissible: List[Tuple[str, int]] = []
    total = 0
    for name in workers:
        absorbed = 0
        spent: Dict[str, int] = {}
        for ent in blocks:
            slot = ent.cover.get(name)
            if slot is None:
                continue
            ceiling, caps = slot
            room = ceiling - absorbed
            if room <= 0:
                continue
            for ctl in sorted(caps, key=lambda c: last_use[c]):
                spare = caps[ctl] - spent.get(ctl, 0)
                if spare <= 0:
                    continue
                take = spare if room == UNBOUNDED else int(min(spare, room))
                if take <= 0:
                    continue
                spent[ctl] = spent.get(ctl, 0) + take
                absorbed += take
                room -= take
                if room <= 0:
                    break
        if absorbed > 0:
            admissible.append((name, absorbed))
            total += absorbed
    return total, tuple(admissible), exact, bool(workers)


# ---------------------------------------------------------------------------
# The analyzer entry point
# ---------------------------------------------------------------------------


def analyze_plan(
    plan: CompiledScript,
    cluster: ClusterState,
    distribution: DistributionPolicy,
    *,
    entry_zones: Sequence[Optional[str]] = (None,),
    starvation_floor: int = 1,
    federation: Optional[FederationView] = None,
    tags: Optional[Sequence[str]] = None,
) -> AnalysisReport:
    """Statically verify a compiled plan against a topology snapshot.

    ``entry_zones`` is ``(None,)`` for a flat platform (context-free
    evaluation) or the federation's zone names; with a ``federation``
    view, each entry zone's verdict folds in its forward-target zones so
    proofs hold under the full routing pipeline. ``starvation_floor``
    flags tags whose (positive) admission bound is below it.
    """
    zone_list: Tuple[Optional[str], ...] = tuple(entry_zones) or (None,)
    if tags is None:
        names = list(plan.tags)
    else:
        names = [t for t in tags if t in plan.tags]
    known_zones = {z for z in zone_list if z is not None}
    scans: Dict[Tuple[str, Optional[str]], _TagScan] = {}

    def scan_of(tag_name: str, zone: Optional[str]) -> _TagScan:
        key = (tag_name, zone)
        hit = scans.get(key)
        if hit is None:
            hit = scans[key] = _scan_tag(
                plan, plan.tags[tag_name], cluster, distribution, zone
            )
        return hit

    verdicts: List[TagVerdict] = []
    findings: List[Finding] = []
    seen_findings: Set[Tuple[str, str, str]] = set()

    def emit(
        level: str, where: str, message: str, category: str, proof: bool = False
    ) -> None:
        key = (where, message, category)
        if key in seen_findings:
            return
        seen_findings.add(key)
        findings.append(
            Finding(level, where, message, category=category, proof=proof)
        )

    for tag_name in names:
        local_scans: List[_TagScan] = []
        for zone in zone_list:
            scan = scan_of(tag_name, zone)
            local_scans.append(scan)
            group = [scan]
            if federation is not None and zone is not None:
                order = tuple(federation.zone_order.get(zone, ()))
                for target in forward_targets(
                    plan.source, tag_name, cluster, zone, order
                ):
                    if target in known_zones and target != zone:
                        group.append(scan_of(tag_name, target))
            total, admissible, exact, reachable = _merge_bound(group)
            selectable = {name for name, _absorb in admissible}
            verdicts.append(
                TagVerdict(
                    tag=tag_name,
                    entry_zone=zone,
                    reachable=reachable,
                    placeable=total > 0,
                    starvation_bound=total,
                    exact=exact,
                    admissible=admissible,
                    blocks=tuple(
                        BlockVerdict(
                            tag=b.tag,
                            index=b.index,
                            live=b.live,
                            reason=b.reason,
                            selectable=tuple(
                                sorted(b.survivors & selectable)
                            ),
                        )
                        for b in scan.blocks
                    ),
                )
            )
            where = f"tag:{tag_name}"
            entry = "" if zone is None else f" from entry zone {zone!r}"
            if total == 0:
                if reachable:
                    why = (
                        "statically-valid candidates exist but every "
                        "admission ceiling is provably zero"
                    )
                else:
                    why = "no block reaches a statically-valid worker"
                emit(
                    "warning",
                    where,
                    f"statically unplaceable{entry}: {why}; every request "
                    f"will be rejected by policy",
                    "reachability",
                    proof=True,
                )
            elif total < starvation_floor:
                kind = "" if exact else " (upper bound)"
                emit(
                    "warning",
                    where,
                    f"admission bound {total}{kind}{entry} is below the "
                    f"declared starvation floor {starvation_floor}",
                    "starvation",
                )

        # Block/item findings describe the *plan*, so they fire only when
        # the defect holds from every analyzed entry zone, and only for
        # the tag's own blocks (the followup chain's blocks are reported
        # under their owning tag).
        own_indexes = {
            b.index for b in local_scans[0].blocks if b.tag == tag_name
        }
        for bi in sorted(own_indexes):
            per_zone = [
                next(b for b in s.blocks if b.tag == tag_name and b.index == bi)
                for s in local_scans
            ]
            bwhere = f"tag:{tag_name}.block[{bi}]"
            if all(not b.live for b in per_zone):
                emit(
                    "warning",
                    bwhere,
                    f"statically dead: {per_zone[0].reason}",
                    "reachability",
                )
                block_dead = True
            else:
                block_dead = False
            for j in range(len(per_zone[0].items)):
                zone_items = [b.items[j] for b in per_zone]
                item = zone_items[0]
                iwhere = f"{bwhere}.workers[{j}]"
                if item.contradictions:
                    emit(
                        "warning",
                        iwhere,
                        "constraint conjunction is unsatisfiable: "
                        + "; ".join(item.contradictions),
                        "satisfiability",
                    )
                    continue
                if block_dead:
                    continue  # the block-level finding already covers it
                if all(not i.survivors for i in zone_items):
                    emit(
                        "warning",
                        iwhere,
                        "empty static survivor set: no worker can ever "
                        "match this item",
                        "satisfiability",
                    )
                elif all(not i.positive for i in zone_items):
                    emit(
                        "warning",
                        iwhere,
                        "every statically-valid candidate of this item has "
                        "a zero admission ceiling",
                        "satisfiability",
                    )

    return AnalysisReport(
        verdicts=tuple(verdicts),
        findings=tuple(findings),
        entry_zones=zone_list,
        topology_epoch=cluster.topology_epoch,
        starvation_floor=starvation_floor,
    )
