"""Static policy verification over compiled tAPP plans.

Answers the reachability/satisfiability questions of arXiv:2407.14159
statically, at ``apply_policy`` time, using only the epoch-static halves
of the constraint split (:func:`repro.core.scheduler.constraints.split_spec`)
evaluated against a :class:`~repro.core.scheduler.state.ClusterState`
topology snapshot.
"""
from repro.core.analysis.verifier import (
    AnalysisReport,
    BlockVerdict,
    FederationView,
    TagVerdict,
    UNBOUNDED,
    analyze_plan,
)

__all__ = [
    "AnalysisReport",
    "BlockVerdict",
    "FederationView",
    "TagVerdict",
    "UNBOUNDED",
    "analyze_plan",
]
