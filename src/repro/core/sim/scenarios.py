"""Paper-faithful evaluation scenarios (§5.1–§5.3).

Builders for:
* the two-zone benchmark cluster of §5.3 (France Central / East US, two
  controllers, three workers, MongoDB + terrain backend in East US);
* the qualitative MQTT case of §5.1 (edge zone with a local-only broker);
* the ad-hoc and real-world function profiles (§5.2) with timings scaled
  to reproduce the paper's relationships (absolute values are calibration
  constants — documented per profile);
* the tAPP scripts used in the experiments (Fig. 8 analogues).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.platform import (
    ChaosSpec,
    ClusterSpec,
    ControllerSpec,
    FederationSpec,
    OverloadSpec,
    RetryPolicy,
    TappFederation,
    TappPlatform,
    WorkerSpec,
)
from repro.core.scheduler.topology import DistributionPolicy
from repro.core.sim.core import (
    FunctionProfile,
    NetworkModel,
    SimConfig,
    Simulation,
    WorkloadSpec,
)

# Zones of the quantitative cluster (§5.3): the data (MongoDB, terrain
# backend) lives next to the `east_us` nodes; `france` is ~80ms away.
ZONE_EAST = "east_us"
ZONE_FRANCE = "france"

# Zones of the qualitative case (§5.1).
ZONE_EDGE = "edge"
ZONE_CLOUD = "cloud"


# ---------------------------------------------------------------------------
# Clusters
# ---------------------------------------------------------------------------


def benchmark_cluster(*, deployment_seed: int = 0) -> ClusterSpec:
    """§5.3: 1 controller + 1 worker in France, 1 controller + 2 workers in
    East US. Worker slots model Standard_DS1_v2 (1 vCPU) invoker pools.

    ``deployment_seed`` permutes worker registration order — the paper's
    methodology redeploys the whole platform every 2 repetitions "to avoid
    benchmarking specific configurations, e.g., bad, random configurations
    where vanilla OpenWhisk elects as primary a high-latency worker". Each
    seed is one such deployment: vanilla's co-prime primary depends on the
    order, tAPP's topology-aware choice does not.
    """
    return ClusterSpec(
        controllers=(
            ControllerSpec("FranceCtl", zone=ZONE_FRANCE),
            ControllerSpec("EastCtl", zone=ZONE_EAST),
        ),
        workers=(
            WorkerSpec("fr-w0", zone=ZONE_FRANCE, sets=("france", "any"),
                       capacity_slots=2),
            WorkerSpec("us-w0", zone=ZONE_EAST, sets=("east", "any"),
                       capacity_slots=2),
            WorkerSpec("us-w1", zone=ZONE_EAST, sets=("east", "any"),
                       capacity_slots=2),
        ),
    ).shuffled(deployment_seed)


def benchmark_network() -> NetworkModel:
    """Measured latencies of §5.3: ~2ms from East US to the data host,
    ~80ms from France Central. Bandwidths sized for the 124MB payload."""
    return NetworkModel(
        rtt={
            (ZONE_EAST, ZONE_EAST): 0.002,
            (ZONE_FRANCE, ZONE_EAST): 0.080,
            (ZONE_FRANCE, ZONE_FRANCE): 0.002,
        },
        bandwidth={
            (ZONE_EAST, ZONE_EAST): 300e6,     # same-region ~2.4 Gbps
            (ZONE_FRANCE, ZONE_EAST): 35e6,    # cross-Atlantic ~280 Mbps
            (ZONE_FRANCE, ZONE_FRANCE): 300e6,
        },
    )


def mqtt_cluster(*, cloud_first: bool = True) -> ClusterSpec:
    """§5.1: edge zone (controller + worker + broker/db) and cloud zone
    (controller + worker). The broker is reachable only from the edge.

    ``cloud_first`` controls worker registration order. Vanilla OpenWhisk's
    co-prime schedule makes "the first worker chosen for the function depend
    on the deployment" (§5.1) — the paper observed the *unlucky* deployment
    where the cloud worker is primary and every invocation fails. The
    qualitative benchmark runs both orders to show vanilla is
    deployment-dependent while tAPP succeeds under either.
    """
    edge = WorkerSpec("W_1", zone=ZONE_EDGE, sets=("edge", "any"),
                      capacity_slots=4)
    cloud = WorkerSpec("W_2", zone=ZONE_CLOUD, sets=("cloud", "any"),
                       capacity_slots=4)
    return ClusterSpec(
        controllers=(
            ControllerSpec("LocalCtl", zone=ZONE_EDGE),
            ControllerSpec("CloudCtl", zone=ZONE_CLOUD),
        ),
        workers=(cloud, edge) if cloud_first else (edge, cloud),
    )


def mqtt_federation_spec() -> FederationSpec:
    """§5.1 as a two-entry federation: each zone is an entrypoint.

    Same topology as :func:`mqtt_cluster`, but sliced per zone so
    :class:`TappFederation` stands up an edge gateway (where the sensors
    publish) and a cloud gateway (where the analytics dashboards live).
    The inter-zone network model prices the forwarding hops.
    """
    return FederationSpec.of(
        {
            ZONE_EDGE: ClusterSpec(
                controllers=(ControllerSpec("LocalCtl"),),
                workers=(
                    WorkerSpec("W_1", sets=("edge", "any"), capacity_slots=4),
                ),
            ),
            ZONE_CLOUD: ClusterSpec(
                controllers=(ControllerSpec("CloudCtl"),),
                workers=(
                    WorkerSpec("W_2", sets=("cloud", "any"), capacity_slots=4),
                ),
            ),
        },
        network=mqtt_network(),
        default_entry=ZONE_EDGE,
    )


def mqtt_network() -> NetworkModel:
    return NetworkModel(
        rtt={
            (ZONE_EDGE, ZONE_EDGE): 0.001,
            (ZONE_EDGE, ZONE_CLOUD): 0.040,
            (ZONE_CLOUD, ZONE_CLOUD): 0.002,
        },
        bandwidth={
            (ZONE_EDGE, ZONE_EDGE): 1e9,
            (ZONE_EDGE, ZONE_CLOUD): 100e6,
            (ZONE_CLOUD, ZONE_CLOUD): 1e9,
        },
        # The broker is only reachable from the edge network (§5.1).
        resource_zones={"mqtt_broker": [ZONE_EDGE]},
    )


# ---------------------------------------------------------------------------
# Function profiles (§5.2)
# ---------------------------------------------------------------------------

#: Ad-hoc tests. exec_time values are calibration constants chosen to match
#: the paper's qualitative relationships (Fig. 9): hellojs ~ tens of ms,
#: sleep = 3s exactly, matrixMult ~ meaningful CPU work, cold-start loads
#: 42.8MB of dependencies.
def adhoc_profiles(tagged: bool) -> Dict[str, FunctionProfile]:
    def tag(name: Optional[str]) -> Optional[str]:
        return name if tagged else None

    return {
        "hellojs": FunctionProfile(
            name="hellojs", exec_time=0.020, cold_start_time=0.30,
        ),
        "sleep": FunctionProfile(
            name="sleep", exec_time=3.0, exec_jitter=0.0, cold_start_time=0.30,
        ),
        "matrixMult": FunctionProfile(
            name="matrixMult", exec_time=0.160, cold_start_time=0.30,
        ),
        "cold-start": FunctionProfile(
            name="cold-start", exec_time=0.030,
            cold_start_time=2.8,            # 42.8MB dependency load
            warm_ttl=60.0,                  # throttled past cache timeout
        ),
        "mongoDB": FunctionProfile(
            name="mongoDB", exec_time=0.010, cold_start_time=0.35,
            data_zone=ZONE_EAST, data_bytes=106, data_roundtrips=3,
            tag=tag("db_query"),
        ),
        "data-locality": FunctionProfile(
            name="data-locality", exec_time=0.060, cold_start_time=0.35,
            data_zone=ZONE_EAST, data_bytes=int(124.38e6), data_roundtrips=3,
            tag=tag("db_query"),
        ),
        # Real-world (Wonderless) tests.
        "slackpost": FunctionProfile(
            name="slackpost", exec_time=0.180, cold_start_time=0.40,
        ),
        "pycatj": FunctionProfile(
            name="pycatj", exec_time=0.045, cold_start_time=0.45,
        ),
    }


#: JMeter configurations (§5.3 "Configuration").
WORKLOADS: Dict[str, WorkloadSpec] = {
    "hellojs": WorkloadSpec("hellojs", users=4, requests_per_user=200, ramp_up=10.0),
    "sleep": WorkloadSpec("sleep", users=4, requests_per_user=25, ramp_up=10.0),
    "matrixMult": WorkloadSpec("matrixMult", users=4, requests_per_user=200, ramp_up=10.0),
    "cold-start": WorkloadSpec("cold-start", users=1, requests_per_user=3, pause=660.0),
    "mongoDB": WorkloadSpec("mongoDB", users=4, requests_per_user=200, ramp_up=10.0),
    "data-locality": WorkloadSpec("data-locality", users=4, requests_per_user=50, ramp_up=10.0),
    "slackpost": WorkloadSpec("slackpost", users=1, requests_per_user=100, pause=1.0),
    "pycatj": WorkloadSpec("pycatj", users=4, requests_per_user=200, ramp_up=10.0),
}


#: tAPP script used for the tagged data-locality runs (§5.4.2): prefer the
#: workers co-located with the data (East US), spill to France on load.
DATA_LOCALITY_SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- db_query:
  - workers:
    - set: east
    strategy: random
    invalidate: capacity_used 90%
  - workers:
    - set: france
    strategy: random
    invalidate: overload
  followup: default
"""

#: tAPP script of the MQTT case (Fig. 8).
MQTT_SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- MQTT:
  - controller: LocalCtl
    workers:
    - set: edge
    topology_tolerance: none
  followup: fail
- DB:
  - workers:
    - wrk: W_1
      invalidate: capacity_used 50%
    - wrk: W_2
    strategy: best_first
- Cloud:
  - controller: CloudCtl
    workers:
    - set: cloud
    topology_tolerance: none
  followup: fail
"""


def mqtt_profiles() -> Dict[str, FunctionProfile]:
    """The three pipeline functions of the §5.1 case study."""
    return {
        "data-collection": FunctionProfile(
            name="data-collection", exec_time=1.1,  # collects 1s of sensor data
            requires="mqtt_broker", data_zone=ZONE_EDGE, data_bytes=60_000 * 40,
            tag="MQTT",
        ),
        "feature-extraction": FunctionProfile(
            name="feature-extraction", exec_time=0.08,
            data_zone=ZONE_EDGE, data_bytes=60_000 * 40, tag="DB",
        ),
        "feature-analysis": FunctionProfile(
            name="feature-analysis", exec_time=0.15,
            data_zone=ZONE_EDGE, data_bytes=12 * 8, tag="Cloud",
        ),
    }


# ---------------------------------------------------------------------------
# Runners
# ---------------------------------------------------------------------------


def run_benchmark(
    test: str,
    *,
    scheduler: str,                      # "vanilla" | a DistributionPolicy value
    tagged: bool = False,
    script: Optional[str] = None,
    seed: int = 0,
) -> Tuple[Simulation, "SimResult"]:
    """Run one §5.2 test on a fresh §5.3 deployment. Returns (sim, result)."""
    spec = benchmark_cluster(deployment_seed=seed)
    profiles = adhoc_profiles(tagged)
    network = benchmark_network()
    config = SimConfig(seed=seed, gateway_zone=ZONE_EAST)

    if scheduler == "vanilla":
        # A policy-free platform routes through the vanilla fallback.
        platform = TappPlatform(spec, seed=seed)
        sim = Simulation(platform, network, profiles, config, is_tapp=False)
    else:
        policy = DistributionPolicy.parse(scheduler)
        platform = TappPlatform(spec, distribution=policy, seed=seed)
        if script is not None:
            platform.apply_policy(script)
        elif tagged:
            platform.apply_policy(DATA_LOCALITY_SCRIPT)
        # No script + untagged → gateway falls back to vanilla logic but the
        # run still pays the tAPP platform overhead (§5.4.1 methodology),
        # with topology-prioritised worker order. We emulate the co-located
        # preference by loading a minimal blank-set default script.
        else:
            platform.apply_policy(
                "- default:\n"
                "  - workers:\n"
                "    - set:\n"
                "    strategy: platform\n"
                "    invalidate: overload\n"
            )
        sim = Simulation(platform, network, profiles, config, is_tapp=True)

    result = sim.run([WORKLOADS[test]])
    return sim, result


# ---------------------------------------------------------------------------
# Co-location / interference scenario family (constraint layer v2)
# ---------------------------------------------------------------------------
#
# The affinity/anti-affinity extension (arXiv:2407.14572) targets workloads
# the original paper cannot express: *what else runs on the worker* matters.
# Two racks of identical workers; a latency-sensitive API function suffers
# noisy-neighbour interference from a batch cruncher (cache/membus
# pressure), and a join function wants to co-locate with the cache-warmer
# that holds its working set.

ZONE_RACK_A = "rack_a"
ZONE_RACK_B = "rack_b"


def colocation_cluster() -> ClusterSpec:
    """Two racks × two workers, one controller per rack."""
    return ClusterSpec(
        controllers=(
            ControllerSpec("RackACtl", zone=ZONE_RACK_A),
            ControllerSpec("RackBCtl", zone=ZONE_RACK_B),
        ),
        workers=tuple(
            WorkerSpec(
                f"w{i}",
                zone=(ZONE_RACK_A if i < 2 else ZONE_RACK_B),
                sets=((ZONE_RACK_A if i < 2 else ZONE_RACK_B), "any"),
                capacity_slots=4,
            )
            for i in range(4)
        ),
    )


def colocation_network() -> NetworkModel:
    """Rack-to-rack hops are cheap; interference, not topology, dominates."""
    return NetworkModel(
        rtt={
            (ZONE_RACK_A, ZONE_RACK_A): 0.0005,
            (ZONE_RACK_A, ZONE_RACK_B): 0.002,
            (ZONE_RACK_B, ZONE_RACK_B): 0.0005,
        },
        bandwidth={},
        default_bandwidth=1e9,
    )


def colocation_profiles() -> Dict[str, FunctionProfile]:
    return {
        # Latency-sensitive: each co-running foreign invocation multiplies
        # its 20ms service time (cache-thrash victim).
        "latency_api": FunctionProfile(
            name="latency_api", exec_time=0.020, cold_start_time=0.25,
            interference_sensitivity=4.0, tag="latency",
        ),
        # Noisy neighbour: long CPU burns, insensitive itself.
        "batch_crunch": FunctionProfile(
            name="batch_crunch", exec_time=0.8, cold_start_time=0.25,
            tag="batch",
        ),
        # Affinity pair: the warmer pins a working set; the join wants to
        # land where a warmer instance is running.
        "cache_warmer": FunctionProfile(
            name="cache_warmer", exec_time=1.5, cold_start_time=0.25,
            tag="warm",
        ),
        "feature_join": FunctionProfile(
            name="feature_join", exec_time=0.030, cold_start_time=0.25,
            tag="join",
        ),
    }


#: Baseline: constraint-free default policy — the scheduler is blind to
#: co-location, so latency_api lands next to batch_crunch.
COLOCATION_BLANK_SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
"""

#: Constraint-layer policy: anti-affinity keeps the interference victims
#: away from the cruncher (spilling to loaded-but-quiet workers first),
#: and affinity steers the join onto a warmer-hosting worker.
COLOCATION_SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- latency:
  - workers:
    - set:
    strategy: platform
    invalidate: capacity_used 90%
    anti-affinity: [batch_crunch]
  followup: default
- batch:
  - workers:
    - set:
    strategy: best_first
    invalidate: overload
    anti-affinity: [latency_api]
  followup: default
- warm:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- join:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
    affinity: [cache_warmer]
  followup: default
"""


def colocation_workload(
    *, requests_per_user: int = 50
) -> List[WorkloadSpec]:
    return [
        WorkloadSpec("latency_api", users=4,
                     requests_per_user=requests_per_user, ramp_up=1.0),
        WorkloadSpec("batch_crunch", users=4,
                     requests_per_user=max(1, requests_per_user // 4),
                     ramp_up=1.0),
        WorkloadSpec("cache_warmer", users=1,
                     requests_per_user=max(1, requests_per_user // 5),
                     pause=0.2),
        WorkloadSpec("feature_join", users=2,
                     requests_per_user=requests_per_user, ramp_up=1.0),
    ]


def colocation_federation_spec() -> FederationSpec:
    """The two racks as federation zones — each rack is an entrypoint."""
    cluster = colocation_cluster()
    return FederationSpec.of(
        {
            zone: ClusterSpec(
                workers=tuple(w for w in cluster.workers if w.zone == zone),
                controllers=tuple(
                    c for c in cluster.controllers if c.zone == zone
                ),
            )
            for zone in (ZONE_RACK_A, ZONE_RACK_B)
        },
        network=colocation_network(),
        default_entry=ZONE_RACK_A,
    )


def run_colocation_case(
    *,
    constrained: bool,
    seed: int = 0,
    requests_per_user: int = 50,
    federated: bool = False,
) -> Tuple[Simulation, "SimResult"]:
    """Run the interference workload with/without the affinity constraints.

    ``federated`` drives the same deployment through a two-entry
    :class:`TappFederation` instead of the flat platform: each workload
    class enters at its own rack's gateway (latency_api + cache_warmer
    at rack A, batch_crunch + feature_join at rack B) and spills across
    racks only when its own rack declines. Returns (sim, result); split
    per-class stats via ``result.for_function(...)``.
    """
    policy = COLOCATION_SCRIPT if constrained else COLOCATION_BLANK_SCRIPT
    if federated:
        platform = TappFederation(
            colocation_federation_spec(),
            distribution=DistributionPolicy.SHARED,
            seed=seed,
            policy=policy,
        )
    else:
        platform = TappPlatform(
            colocation_cluster(),
            distribution=DistributionPolicy.SHARED,
            seed=seed,
            policy=policy,
        )
    sim = Simulation(
        platform,
        colocation_network(),
        colocation_profiles(),
        SimConfig(seed=seed, gateway_zone=ZONE_RACK_A),
        is_tapp=True,
    )
    workload = colocation_workload(requests_per_user=requests_per_user)
    if federated:
        entries = {
            "latency_api": ZONE_RACK_A,
            "cache_warmer": ZONE_RACK_A,
            "batch_crunch": ZONE_RACK_B,
            "feature_join": ZONE_RACK_B,
        }
        workload = [
            dataclasses.replace(spec, entry_zone=entries[spec.function])
            for spec in workload
        ]
    result = sim.run(workload)
    return sim, result


#: Overload-aware variant of the data-locality policy (PR 9): db_query
#: traffic is higher-priority than best-effort default traffic (the queue
#: sheds default first when full) and may relax its affinity for the
#: east-side workers under a sustained brownout.
OVERLOAD_SCRIPT = """
- default:
  - workers:
    - set:
    strategy: platform
    invalidate: overload
- db_query:
  - workers:
    - set: east
    strategy: random
    invalidate: capacity_used 90%
    priority: 2
  - workers:
    - set: france
    strategy: random
    invalidate: overload
    priority: 2
  followup: default
  on-overload: relax-affinity
"""


def chaos_benchmark_chaos(
    *, seed: int = 0, crashes: int = 2, partitions: int = 0
) -> ChaosSpec:
    """A §5.3-sized chaos schedule: a couple of worker crashes (with
    recovery) inside the first minute, optional inter-zone partitions."""
    return ChaosSpec(
        seed=seed,
        horizon=60.0,
        worker_crashes=crashes,
        crash_downtime=10.0,
        partitions=partitions,
        partition_duration=15.0,
    )


def run_chaos_case(
    *,
    test: str = "hellojs",
    seed: int = 0,
    chaos: Optional[ChaosSpec] = None,
    retry: Optional[RetryPolicy] = RetryPolicy(max_attempts=3),
    federated: bool = False,
    overload: Optional[OverloadSpec] = None,
    script: Optional[str] = None,
) -> Tuple[Simulation, "SimResult"]:
    """Run one §5.2 test under seeded fault injection (PR 6).

    The same deployment + workload as :func:`run_benchmark`'s tAPP
    shared-distribution arm, but with a :class:`RetryPolicy` on the
    platform and a :class:`ChaosSpec` threaded into the simulator's
    event stream: workers crash (evicting their in-flight tickets) and
    recover mid-run, and affected requests re-route under the policy.
    ``chaos=None`` runs the schedule-free control — bit-identical to a
    pre-chaos simulation. ``federated=True`` drives the two-rack
    federation instead (partitions then sever real forwarding links).
    ``overload`` arms the PR 9 admission-queue / breaker / brownout
    layer (off by default — placements stay bit-identical without it);
    ``script`` overrides the default policy (e.g. ``OVERLOAD_SCRIPT``).
    """
    profiles = adhoc_profiles(False)
    config = SimConfig(seed=seed, gateway_zone=ZONE_EAST)
    if federated:
        platform = TappFederation(
            colocation_federation_spec(),
            distribution=DistributionPolicy.SHARED,
            seed=seed,
            policy=script if script is not None else COLOCATION_BLANK_SCRIPT,
            retry=retry,
            overload=overload,
        )
        network = colocation_network()
        config = SimConfig(seed=seed, gateway_zone=ZONE_RACK_A)
    else:
        platform = TappPlatform(
            benchmark_cluster(deployment_seed=seed),
            distribution=DistributionPolicy.SHARED,
            seed=seed,
            policy=script if script is not None else DATA_LOCALITY_SCRIPT,
            retry=retry,
            overload=overload,
        )
        network = benchmark_network()
    sim = Simulation(
        platform, network, profiles, config, is_tapp=True, chaos=chaos
    )
    result = sim.run([WORKLOADS[test]])
    return sim, result


def run_mqtt_case(
    *, use_tapp: bool, minutes: int = 30, seed: int = 0, cloud_first: bool = True
) -> Dict[str, "SimResult"]:
    """§5.1 qualitative case: one pipeline invocation per minute."""
    spec = mqtt_cluster(cloud_first=cloud_first)
    profiles = mqtt_profiles()
    network = mqtt_network()
    config = SimConfig(seed=seed, gateway_zone=ZONE_CLOUD)

    if use_tapp:
        platform = TappPlatform(
            spec, distribution=DistributionPolicy.SHARED, seed=seed,
            policy=MQTT_SCRIPT,
        )
        is_tapp = True
    else:
        platform = TappPlatform(spec, seed=seed)
        is_tapp = False

    # One platform across the three pipeline stages: scheduler cursors and
    # cluster state carry over, exactly like one live deployment would.
    results: Dict[str, "SimResult"] = {}
    for fn in ("data-collection", "feature-extraction", "feature-analysis"):
        sim = Simulation(platform, network, profiles, config, is_tapp=is_tapp)
        workload = [
            WorkloadSpec(function=fn, users=1, requests_per_user=minutes, pause=60.0)
        ]
        results[fn] = sim.run(workload)
    return results


def run_mqtt_federated_case(
    *, minutes: int = 30, seed: int = 0
) -> Tuple[TappFederation, Dict[str, "SimResult"]]:
    """§5.1 end-to-end through a federation with TWO entrypoints.

    The paper's pipeline, but with requests entering where they
    originate: ``data-collection`` is triggered from the *cloud*
    dashboard (entry = cloud) yet must run next to the edge-only broker —
    its ``topology_tolerance: none`` home — so every invocation is
    forwarded cloud→edge and never placed outside the edge;
    ``feature-extraction`` enters at the edge (data gravity);
    ``feature-analysis`` enters at the edge but its ``Cloud`` tag
    designates the cloud controller, a designated cross-zone hop. The
    returned federation's :meth:`~TappFederation.stats` expose the
    forwarding ledger; per-request hops land on the sim records
    (``forwarded`` / ``forward_rtt``).
    """
    federation = TappFederation(
        mqtt_federation_spec(),
        distribution=DistributionPolicy.SHARED,
        seed=seed,
        policy=MQTT_SCRIPT,
    )
    profiles = mqtt_profiles()
    network = mqtt_network()
    config = SimConfig(seed=seed, gateway_zone=ZONE_CLOUD)

    entries = {
        "data-collection": ZONE_CLOUD,      # dashboard-triggered
        "feature-extraction": ZONE_EDGE,    # data gravity
        "feature-analysis": ZONE_EDGE,      # edge-triggered, cloud-designated
    }
    results: Dict[str, "SimResult"] = {}
    for fn, entry in entries.items():
        sim = Simulation(federation, network, profiles, config, is_tapp=True)
        results[fn] = sim.run([
            WorkloadSpec(
                function=fn, users=1, requests_per_user=minutes,
                pause=60.0, entry_zone=entry,
            )
        ])
    return federation, results
