"""Discrete-event simulation of serverless scheduling (paper §5 evaluation)."""
from repro.core.sim.core import (
    FunctionProfile,
    NetworkModel,
    RequestRecord,
    SimConfig,
    SimResult,
    Simulation,
    WorkloadSpec,
    gateway_scheduler,
    vanilla_scheduler,
)

__all__ = [
    "FunctionProfile",
    "NetworkModel",
    "RequestRecord",
    "SimConfig",
    "SimResult",
    "Simulation",
    "WorkloadSpec",
    "gateway_scheduler",
    "vanilla_scheduler",
]
