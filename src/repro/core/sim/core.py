"""Discrete-event simulator for serverless function scheduling.

Reproduces the paper's evaluation environment (§5.3) as a closed-loop
(JMeter-style) queueing simulation over a zoned cluster:

* **users** issue requests sequentially (send → wait for response →
  optional pause → next), with a ramp-up stagger;
* the **gateway** (tAPP or vanilla) resolves each invocation to a worker
  using the *live* cluster snapshot — the same scheduler code that drives
  the JAX serving runtime;
* **workers** have concurrent slots, per-function warm containers (code
  locality) — modelled by the platform's warm-pool lifecycle when one is
  armed, by a sim-local TTL cache otherwise — a performance factor
  (heterogeneity / stragglers), and zone placement;
* a **network model** charges zone-to-zone RTTs and bandwidth for
  functions that touch remote data (data locality) and the gateway→zone
  forwarding hop;
* functions may **require** a resource label reachable only from some
  zones (the §5.1 MQTT broker) — running elsewhere raises a function
  error, which is exactly how vanilla OpenWhisk fails that case study.

The simulator is deterministic under a seed, so benchmark tables are
reproducible bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import random
import statistics
import warnings
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.platform import (
    LegacyWarmCache,
    Placement,
    TappFederation,
    TappPlatform,
)
from repro.core.platform.faults import ChaosSpec, FaultEvent, FaultInjector
from repro.core.scheduler.engine import Invocation, ScheduleDecision
from repro.core.scheduler.state import ClusterState
from repro.core.scheduler.vanilla import VanillaScheduler
from repro.core.scheduler.watcher import Watcher


# ---------------------------------------------------------------------------
# Models
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FunctionProfile:
    """Execution profile of one benchmark function."""

    name: str
    exec_time: float                      # service time at perf_factor=1 (s)
    exec_jitter: float = 0.05             # lognormal-ish multiplicative jitter
    cold_start_time: float = 0.35         # container/init time on first use (s)
    warm_overhead: float = 0.004          # warm-path platform overhead (s)
    # Deprecated (PR 10): the sim-local warm cache TTL. An armed warm-pool
    # lifecycle (TappPlatform(..., lifecycle=LifecycleSpec(keep_alive=...)))
    # is authoritative for warm/cold and ignores this field; setting it to
    # a non-default value emits a DeprecationWarning but keeps the seed-era
    # unarmed behaviour bit-for-bit (OpenWhisk: 10 min).
    warm_ttl: float = 600.0
    data_zone: Optional[str] = None       # zone hosting the function's data
    data_bytes: int = 0                   # payload moved from data zone
    data_roundtrips: int = 1              # queries per invocation
    requires: Optional[str] = None        # resource reachable only in some zones
    tag: Optional[str] = None             # tAPP policy tag attached to requests
    # Co-location interference (noisy-neighbour model): execution time is
    # scaled by (1 + sensitivity * co_runners), where co_runners counts
    # admitted invocations of *other* functions on the worker at start time
    # (cache/membus pressure from dissimilar workloads; instances of the
    # same function share working sets and are not charged).
    interference_sensitivity: float = 0.0

    def __post_init__(self) -> None:
        if self.warm_ttl != 600.0:
            warnings.warn(
                "FunctionProfile.warm_ttl is deprecated; arm the platform's "
                "warm-pool lifecycle (TappPlatform(..., lifecycle="
                "LifecycleSpec(keep_alive=...))) to model container expiry "
                "— armed platforms ignore warm_ttl entirely",
                DeprecationWarning,
                stacklevel=3,
            )


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    """Zone-to-zone RTT (seconds) and bandwidth (bytes/s). Symmetric keys."""

    rtt: Mapping[Tuple[str, str], float]
    bandwidth: Mapping[Tuple[str, str], float]
    default_rtt: float = 0.080
    default_bandwidth: float = 50e6
    # Resource reachability: resource label -> zones that can reach it.
    resource_zones: Mapping[str, Sequence[str]] = dataclasses.field(
        default_factory=dict
    )

    def get_rtt(self, a: str, b: str) -> float:
        if a == b:
            return self.rtt.get((a, b), 0.0005)
        return self.rtt.get((a, b), self.rtt.get((b, a), self.default_rtt))

    def get_bandwidth(self, a: str, b: str) -> float:
        if a == b:
            return self.bandwidth.get((a, b), 10e9)
        return self.bandwidth.get(
            (a, b), self.bandwidth.get((b, a), self.default_bandwidth)
        )

    def reachable(self, resource: str, zone: str) -> bool:
        zones = self.resource_zones.get(resource)
        return zones is None or zone in zones


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """A JMeter-style closed-loop workload for one function."""

    function: str
    users: int = 4
    requests_per_user: int = 200
    ramp_up: float = 10.0                 # thread-start stagger window (s)
    pause: float = 0.0                    # think time between requests (s)
    # Federation zone these users' requests enter at (None: the platform's
    # single gateway / the federation's default entry). Multi-entry
    # workloads mix specs with different entry zones.
    entry_zone: Optional[str] = None


@dataclasses.dataclass
class RequestRecord:
    request_id: int
    function: str
    user: int
    submitted: float
    completed: float = 0.0
    worker: Optional[str] = None
    controller: Optional[str] = None
    scheduled: bool = False
    error: Optional[str] = None
    cold: bool = False
    # Federation bookkeeping: which zone the request entered at, whether
    # it was forwarded out of it, and the total cross-zone RTT its hops
    # (failed attempts included) were charged.
    entry_zone: Optional[str] = None
    forwarded: bool = False
    forward_rtt: float = 0.0
    # Failure handling (PR 6): re-routes this request survived (worker
    # crashes / no-valid-worker retries under a RetryPolicy), and the
    # cumulative deterministic backoff charged into its latency.
    retries: int = 0
    retry_wait: float = 0.0
    # Overload handling (PR 9): time spent parked in the admission queue
    # before a completion drained the request onto a worker. Requests
    # shed or expired by the queue terminate with error "shed" /
    # "deadline_exceeded" instead.
    queue_wait: float = 0.0

    @property
    def latency(self) -> float:
        return self.completed - self.submitted

    @property
    def ok(self) -> bool:
        return self.scheduled and self.error is None


@dataclasses.dataclass
class SimResult:
    records: List[RequestRecord]

    def ok_latencies(self) -> List[float]:
        return [r.latency for r in self.records if r.ok]

    @property
    def n_failed(self) -> int:
        return sum(1 for r in self.records if not r.ok)

    @property
    def failure_rate(self) -> float:
        return self.n_failed / max(1, len(self.records))

    def summary(self) -> Dict[str, float]:
        lats = self.ok_latencies()
        if not lats:
            return {
                "count": len(self.records),
                "ok": 0,
                "failure_rate": self.failure_rate,
                "mean": float("nan"),
                "std": float("nan"),
                "p50": float("nan"),
                "p99": float("nan"),
                "max": float("nan"),
            }
        lats_sorted = sorted(lats)

        def pct(p: float) -> float:
            idx = min(len(lats_sorted) - 1, int(p * len(lats_sorted)))
            return lats_sorted[idx]

        return {
            "count": len(self.records),
            "ok": len(lats),
            "failure_rate": self.failure_rate,
            "mean": statistics.fmean(lats),
            "std": statistics.pstdev(lats) if len(lats) > 1 else 0.0,
            "p50": pct(0.50),
            "p99": pct(0.99),
            "max": lats_sorted[-1],
        }

    @property
    def n_forwarded(self) -> int:
        """Requests whose placement left their entry zone (federation)."""
        return sum(1 for r in self.records if r.forwarded)

    @property
    def n_retried(self) -> int:
        """Requests that survived at least one retry re-route."""
        return sum(1 for r in self.records if r.retries)

    @property
    def n_shed(self) -> int:
        """Requests the admission queue shed or expired (PR 9)."""
        return sum(
            1 for r in self.records
            if r.error in ("shed", "deadline_exceeded")
        )

    @property
    def n_queued(self) -> int:
        """Requests that waited in the admission queue before placing."""
        return sum(1 for r in self.records if r.queue_wait > 0.0)

    def queue_waits(self) -> List[float]:
        return [r.queue_wait for r in self.records if r.queue_wait > 0.0]

    def per_worker_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for r in self.records:
            if r.worker:
                counts[r.worker] = counts.get(r.worker, 0) + 1
        return counts

    def for_function(self, function: str) -> "SimResult":
        """The sub-result of one function's requests (per-class summaries)."""
        return SimResult(
            records=[r for r in self.records if r.function == function]
        )


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------

# Scheduler adapter: anything mapping (Invocation, ClusterState) -> decision.
SchedulerFn = Callable[[Invocation, ClusterState], ScheduleDecision]


@dataclasses.dataclass
class SimConfig:
    # Control-plane costs (seconds). tAPP interprets a script per request
    # (paper §4.3 keeps this footprint small via caching); vanilla's
    # round-robin is marginally cheaper. Tagged requests additionally pay
    # tag extraction + policy resolution + label→node mapping retrieval —
    # the paper calls many-lightweight-request workloads "the worst case
    # for the overhead" (§5.4.2), so this constant is deliberately visible.
    scheduler_overhead_tapp: float = 0.0020
    scheduler_overhead_vanilla: float = 0.0008
    tag_resolution_overhead: float = 0.045
    gateway_zone: str = "cloud"           # where the entry point lives
    queue_limit: int = 10_000             # per-worker buffered invocations
    seed: int = 0


class Simulation:
    """Closed-loop discrete-event simulation of one deployment + workload.

    The primary constructor takes a :class:`TappPlatform` — the simulator
    drives the exact invoke→admit→complete flow the serving runtime uses.
    A :class:`TappFederation` works the same way and additionally honours
    each :class:`WorkloadSpec`'s ``entry_zone``: requests enter at their
    zone's gateway, forwarded placements land wherever the tolerance
    allows, and failed forward attempts are charged their cross-zone RTT
    on top of the usual gateway→controller→worker hops. The seed-era
    ``Simulation(watcher, scheduler_fn, ...)`` signature is kept as a
    deprecated shim: the watcher is wrapped in a platform, the scheduler
    function only overrides routing, and admissions still flow through
    the platform.
    """

    def __init__(
        self,
        platform: "TappPlatform | TappFederation | Watcher",
        *args,
        network: Optional[NetworkModel] = None,
        profiles: Optional[Mapping[str, FunctionProfile]] = None,
        config: Optional[SimConfig] = None,
        is_tapp: bool = True,
        scheduler: Optional[SchedulerFn] = None,
        chaos: Optional[ChaosSpec] = None,
    ) -> None:
        if isinstance(platform, Watcher):
            warnings.warn(
                "Simulation(watcher, scheduler, ...) is deprecated; "
                "construct a repro.core.platform.TappPlatform and pass it "
                "as the first argument",
                DeprecationWarning,
                stacklevel=2,
            )
            if args and callable(args[0]):
                scheduler, args = args[0], args[1:]
            platform = TappPlatform.from_watcher(platform)
        elif args and callable(args[0]):
            raise TypeError(
                "scheduler functions combine with a Watcher first argument "
                "(deprecated) or the scheduler= keyword — a TappPlatform "
                "routes by itself"
            )
        if len(args) > 3:
            raise TypeError(
                f"Simulation takes at most (network, profiles, config) "
                f"positionally after the platform; got {len(args)} extra "
                f"arguments"
            )
        if args:
            network = args[0]
        if len(args) > 1:
            profiles = args[1]
        if len(args) > 2:
            config = args[2]
        if network is None or profiles is None:
            raise TypeError("Simulation requires network and profiles")
        self.platform = platform
        self.scheduler = scheduler  # legacy routing override (None: platform)
        self.network = network
        self.profiles = dict(profiles)
        self.config = config or SimConfig()
        self.is_tapp = is_tapp
        self.rng = random.Random(self.config.seed)
        self._warm = LegacyWarmCache()                 # (worker, fn) -> last end
        self._queues: Dict[str, List] = {}             # worker -> FIFO of pending
        self._link_load: Dict[Tuple[str, str], int] = {}  # active transfers/link
        self._events: List = []
        self._seq = itertools.count()
        self.records: List[RequestRecord] = []
        # Seeded fault injection (PR 6): the injector is built lazily in
        # run() (it draws targets from the live cluster membership). With
        # chaos=None nothing is scheduled and the event stream — and
        # therefore every placement, trace, and RNG draw — is bit-identical
        # to pre-chaos simulators.
        self.chaos = chaos
        self._injector: Optional[FaultInjector] = None
        # Overload layer (PR 9): requests parked in the platform's
        # admission queue, keyed by placement identity, until a queue
        # event (drained / shed / expired) resolves them; and the
        # precomputed overload_burst windows (start, end, zone, factor)
        # the submit path uses to amplify arrivals — no RNG involved.
        self._waiting: Dict[int, Tuple[Dict, RequestRecord]] = {}
        self._burst_windows: List[Tuple[float, float, object, float]] = []
        self._burst_rid = itertools.count(10_000_000)

    @property
    def watcher(self) -> Watcher:
        """The platform's watcher (compat accessor)."""
        return self.platform.watcher

    @property
    def cluster(self) -> ClusterState:
        return self.platform.cluster

    @property
    def _lifecycle_armed(self) -> bool:
        """Warm-pool lifecycle armed on the platform (PR 10)?

        Armed platforms own warm/cold: the placement's ``warm_hit``
        verdict drives the latency model and the sim-local TTL cache is
        never consulted or written.
        """
        return getattr(self.platform, "lifecycle_spec", None) is not None

    # -- event helpers -----------------------------------------------------------

    def _push(self, time: float, kind: str, payload) -> None:
        heapq.heappush(self._events, (time, next(self._seq), kind, payload))

    # -- main loop ---------------------------------------------------------------

    def run(self, workload: Sequence[WorkloadSpec]) -> SimResult:
        if not self._federated:
            zoned = sorted(
                {s.function for s in workload if s.entry_zone is not None}
            )
            if zoned:
                # A flat platform has one gateway: silently routing these
                # through it while charging entry-zone RTTs would skew
                # every latency — refuse instead.
                raise ValueError(
                    f"workloads {zoned} set entry_zone but the platform is "
                    f"not a TappFederation; drop entry_zone or pass a "
                    f"federation"
                )
        if self.chaos is not None and self._injector is None:
            cluster = self.platform.cluster
            self._injector = FaultInjector(
                self.chaos,
                list(cluster.workers),
                list(cluster.controllers),
                (tuple(self.platform.zones) if self._federated
                 else tuple(cluster.zones())),
            )
            for event in self._injector.schedule():
                self._push(event.at, "fault", event)
                if event.kind == "overload_burst":
                    self._burst_windows.append((
                        event.at,
                        event.until if event.until is not None
                        else float("inf"),
                        event.target,
                        float(event.value or 1.0),
                    ))
        if hasattr(self.platform, "on_queue_event"):
            # Admission-queue callbacks (a no-op unless the platform was
            # built with an OverloadSpec queue): drained requests resume
            # their timeline, shed/expired ones terminate with an error.
            self.platform.on_queue_event = self._on_queue_event
        rid = itertools.count()
        for spec in workload:
            profile = self.profiles[spec.function]
            for user in range(spec.users):
                start = (
                    (user / max(1, spec.users)) * spec.ramp_up
                    if spec.users > 1
                    else 0.0
                )
                self._push(
                    start,
                    "submit",
                    {
                        "spec": spec,
                        "profile": profile,
                        "user": user,
                        "remaining": spec.requests_per_user,
                        "rid": next(rid),
                    },
                )

        while self._events:
            time, _, kind, payload = heapq.heappop(self._events)
            if kind == "submit":
                # Coalesce heap-adjacent submits at the same timestamp into
                # one batch so the scheduler shares a single snapshot/plan
                # resolution (results are identical to one-by-one: decisions
                # and admissions interleave in the same order).
                batch = [payload]
                while (
                    self._events
                    and self._events[0][2] == "submit"
                    and self._events[0][0] == time
                ):
                    batch.append(heapq.heappop(self._events)[3])
                self._on_submit_batch(time, batch)
            elif kind == "start":
                self._on_start(time, payload)
            elif kind == "finish":
                self._on_finish(time, payload)
            elif kind == "fault":
                self._on_fault(time, payload)
            else:  # pragma: no cover - defensive
                raise RuntimeError(f"unknown event {kind}")
        return SimResult(records=self.records)

    # -- event handlers -------------------------------------------------------------

    def _begin_submit(
        self, time: float, payload: Dict
    ) -> Tuple[Invocation, RequestRecord]:
        profile: FunctionProfile = payload["profile"]
        spec: WorkloadSpec = payload["spec"]
        record = RequestRecord(
            request_id=payload["rid"],
            function=profile.name,
            user=payload["user"],
            submitted=time,
            # The *actual* entry zone is stamped from the placement in
            # _finish_submit (a None entry resolves to the federation's
            # default entry there).
            entry_zone=spec.entry_zone if self._federated else None,
        )
        self.records.append(record)
        invocation = Invocation(
            function=profile.name, tag=profile.tag, request_id=record.request_id
        )
        return invocation, record

    def _on_submit(self, time: float, payload: Dict) -> None:
        invocation, record = self._begin_submit(time, payload)
        placement = self._route_one(invocation, record.entry_zone, time)
        self._finish_submit(time, payload, record, placement)

    @property
    def _federated(self) -> bool:
        return isinstance(self.platform, TappFederation)

    def _route_one(
        self,
        invocation: Invocation,
        entry_zone: Optional[str] = None,
        now: Optional[float] = None,
    ) -> Placement:
        if self.scheduler is None:
            if self._federated:
                return self.platform.invoke(invocation, entry_zone=entry_zone,
                                            now=now)
            return self.platform.invoke(invocation, now=now)
        # Legacy adapter: external routing, platform-side admission.
        decision = self.scheduler(invocation, self.platform.cluster)
        return self.platform.place(invocation, decision)

    def _burst_copies(self, time: float, payloads: List[Dict]) -> List[Dict]:
        """Extra one-shot submit copies for payloads inside an active
        overload_burst window: factor − 1 amplification against the
        burst's target zone (a flat platform has one entry, so any
        window amplifies it). Deterministic — rids come off a dedicated
        counter and no RNG is drawn."""
        extra: List[Dict] = []
        for start, end, zone, factor in self._burst_windows:
            if not (start <= time < end):
                continue
            copies = max(0, int(round(factor)) - 1)
            if not copies:
                continue
            for payload in payloads:
                if self._federated:
                    entry = (payload["spec"].entry_zone
                             or self.platform.spec.entry_zone)
                    if entry != zone:
                        continue
                for _ in range(copies):
                    burst = dict(payload)
                    burst["remaining"] = 1  # one-shot: no user chain
                    burst["rid"] = next(self._burst_rid)
                    extra.append(burst)
        return extra

    def _on_submit_batch(self, time: float, payloads: List[Dict]) -> None:
        if self._burst_windows:
            payloads = payloads + self._burst_copies(time, payloads)
        if len(payloads) == 1:
            self._on_submit(time, payloads[0])
            return
        prepared = [self._begin_submit(time, p) for p in payloads]
        invocations = [inv for inv, _ in prepared]
        pending = iter(zip(payloads, prepared))

        if self.scheduler is None:
            def _on_placement(placement: Placement) -> None:
                payload, (_, record) = next(pending)
                self._finish_submit(time, payload, record, placement)

            # One batched routing pass: script version check, plan, and
            # epoch-cached views shared; each placement is admitted (and
            # its sim bookkeeping done) before the next decision is made,
            # so results are identical to one-by-one submits.
            if self._federated:
                self.platform.invoke_batch(
                    invocations,
                    entry_zones=[p["spec"].entry_zone for p in payloads],
                    on_placement=_on_placement,
                    now=time,
                )
            else:
                self.platform.invoke_batch(
                    invocations, on_placement=_on_placement, now=time
                )
            return

        schedule_batch = getattr(self.scheduler, "schedule_batch", None)
        if schedule_batch is None:
            for payload, (invocation, record) in zip(payloads, prepared):
                placement = self._route_one(invocation)
                self._finish_submit(time, payload, record, placement)
            return

        def _place(invocation: Invocation, decision: ScheduleDecision) -> None:
            payload, (_, record) = next(pending)
            self._finish_submit(
                time, payload, record, self.platform.place(invocation, decision)
            )

        schedule_batch(invocations, on_decision=_place)

    def _finish_submit(
        self,
        time: float,
        payload: Dict,
        record: RequestRecord,
        placement: Placement,
    ) -> None:
        profile: FunctionProfile = payload["profile"]
        decision = placement.decision
        overhead = (
            self.config.scheduler_overhead_tapp
            if self.is_tapp
            else self.config.scheduler_overhead_vanilla
        )
        if self.is_tapp and profile.tag is not None:
            overhead += self.config.tag_resolution_overhead
        now = time + overhead

        attempts = getattr(placement, "attempts", 1)
        if attempts > 1:
            # Retry bookkeeping: count the re-routes and charge the not-
            # yet-charged share of the policy's deterministic backoff into
            # this request's latency (re-entries via _retry_or_fail carry
            # cumulative retry_wait, so the delta is what this pass adds).
            record.retries = attempts - 1
            if placement.retry_wait > record.retry_wait:
                now += placement.retry_wait - record.retry_wait
                record.retry_wait = placement.retry_wait

        placement_entry = getattr(placement, "entry_zone", None)
        if placement_entry is not None:
            # The federation resolved the actual entry (a workload with
            # entry_zone=None entered at the default entry zone) — the
            # record and the RTT charge below must use it, not the flat
            # config.gateway_zone fallback.
            record.entry_zone = placement_entry
        hops = getattr(placement, "hops", ())
        if hops:
            # Cross-zone forwarding: failed attempts cost their hop RTT
            # before the request moves on; the taken hops' latency is
            # charged below through the entry→controller→worker path.
            # Accumulated (+=): a retried request's earlier attempts
            # already charged theirs.
            now += sum(h.rtt for h in hops if not h.scheduled)
            record.forward_rtt += sum(h.rtt for h in hops)
            record.forwarded |= any(h.scheduled for h in hops)

        if not decision.scheduled or decision.worker is None:
            outcome = getattr(placement, "queue_outcome", None)
            if getattr(placement, "queued", False) and outcome is None:
                # Parked in the admission queue (PR 9): the request's
                # timeline pauses here; a completion-driven drain (or a
                # shed/expiry) resumes it via _on_queue_event.
                self._waiting[id(placement)] = (payload, record)
                return
            if outcome is not None:
                # Shed at admission (queue full / brownout reject).
                record.completed = now
                record.error = outcome
                self._finish_user_chain(now, payload, record)
                return
            self._retry_or_fail(
                now,
                {"payload": payload, "record": record, "placement": placement},
                "no-valid-worker",
            )
            return

        record.scheduled = True
        record.worker = decision.worker
        record.controller = decision.controller
        cluster = self.platform.cluster
        worker = cluster.workers[decision.worker]

        # Request path: gateway → controller (zone hop) → worker (zone hop).
        # Vanilla's topology-blind worker choice pays cross-zone
        # controller→worker hops that tAPP's local-first ordering avoids —
        # this is the §5.4.1 effect (default policy beating vanilla).
        # Federated requests enter at their workload's zone gateway, so a
        # forwarded placement pays its cross-zone hop right here.
        ctl = (
            cluster.controllers.get(decision.controller)
            if decision.controller
            else None
        )
        ctl_zone = ctl.zone if ctl is not None else worker.zone
        entry = record.entry_zone or self.config.gateway_zone
        now += self.network.get_rtt(entry, ctl_zone)
        now += self.network.get_rtt(ctl_zone, worker.zone)

        state = {"payload": payload, "record": record, "placement": placement}
        queue = self._queues.setdefault(decision.worker, [])
        # `inflight` counts all admitted (buffered) work — the paper's
        # "concurrent invocations"; executing work = inflight - queued.
        executing = worker.inflight - len(queue)
        if executing <= worker.capacity_slots:
            self._push(now, "start", state)
        else:
            queue.append((now, state))

    def _on_start(self, time: float, state: Dict) -> None:
        record: RequestRecord = state["record"]
        profile: FunctionProfile = self.profiles[record.function]
        worker = self.platform.cluster.workers.get(record.worker)
        if worker is None or not state["placement"].ticket_alive:
            # Deregistered while queued, or crashed before the work could
            # start (the ticket was reconciled as a ledger eviction either
            # way). complete() is a bookkeeping no-op on a dead ticket;
            # the request retries under the policy, or fails.
            state["placement"].complete()
            self._retry_or_fail(
                time, state,
                "worker-evicted" if worker is None else "worker-crashed",
            )
            return

        duration = 0.0
        # Code locality: cold vs warm container. An armed warm-pool
        # lifecycle (PR 10) is authoritative: admission already
        # spawned-or-reused an instance and stamped the verdict on the
        # placement, and expiry runs platform-side off keep_alive —
        # warm_ttl is ignored. Unarmed platforms keep the seed-era
        # sim-local TTL cache bit-for-bit.
        if self._lifecycle_armed:
            if state["placement"].warm_hit:
                duration += profile.warm_overhead
            else:
                duration += profile.cold_start_time
                record.cold = True
        elif self._warm.is_warm(
            worker.name, profile.name, time, profile.warm_ttl
        ):
            duration += profile.warm_overhead
        else:
            duration += profile.cold_start_time
            record.cold = True

        # Required local-only resource (the MQTT broker case).
        if profile.requires and not self.network.reachable(
            profile.requires, worker.zone
        ):
            # Connection attempt times out → function error.
            duration += self.network.get_rtt(worker.zone, profile.data_zone or worker.zone)
            duration += 1.0  # connect timeout
            record.error = f"cannot-reach:{profile.requires}"
            self._push(time + duration, "finish", state)
            return

        # Execution time with heterogeneity + jitter + co-location
        # interference (anti-affinity policies exist to dodge the latter).
        jitter = 1.0 + self.rng.uniform(-profile.exec_jitter, profile.exec_jitter)
        slowdown = 1.0
        if profile.interference_sensitivity > 0.0:
            co_runners = sum(
                count
                for fn, count in worker.running_functions.items()
                if fn != profile.name
            )
            slowdown = 1.0 + profile.interference_sensitivity * co_runners
        duration += (
            profile.exec_time * jitter * slowdown / max(1e-6, worker.perf_factor)
        )

        # Data locality: RTTs + payload transfer from the data zone. Link
        # bandwidth is shared by concurrent transfers on the same zone pair
        # (fair-share approximation at transfer start).
        if profile.data_zone is not None:
            link = _link_key(worker.zone, profile.data_zone)
            rtt = self.network.get_rtt(worker.zone, profile.data_zone)
            bw = self.network.get_bandwidth(worker.zone, profile.data_zone)
            duration += profile.data_roundtrips * rtt
            if profile.data_bytes:
                sharers = self._link_load.get(link, 0) + 1
                self._link_load[link] = sharers
                state["link"] = link
                duration += profile.data_bytes * sharers / bw

        if not self._lifecycle_armed:
            self._warm.touch(worker.name, profile.name, time + duration)
        self._push(time + duration, "finish", state)

    def _on_queue_event(
        self, event: str, placement: Placement, now: Optional[float]
    ) -> None:
        """Resolve a request parked in the platform's admission queue.

        ``drained``: the placement was re-bound onto a worker by a
        completion-driven drain — resume its timeline (queue wait is
        wall time between park and drain, stamped by the platform).
        ``shed`` / ``expired``: terminal failure; the user chain moves
        on. Events for placements the sim is not tracking (e.g. direct
        platform use from a test) are ignored."""
        tracked = self._waiting.pop(id(placement), None)
        if tracked is None:
            return
        payload, record = tracked
        at = now if now is not None else record.submitted
        if event == "drained":
            record.queue_wait = placement.queue_wait
            self._finish_submit(at, payload, record, placement)
            return
        record.completed = at
        record.error = placement.queue_outcome or event
        self._finish_user_chain(at, payload, record)

    def _on_finish(self, time: float, state: Dict) -> None:
        record: RequestRecord = state["record"]
        placement: Placement = state["placement"]
        retired = placement.complete(now=time)
        link = state.pop("link", None)
        if link is not None:
            self._link_load[link] = max(0, self._link_load.get(link, 1) - 1)

        if (
            not retired
            and placement.admitted
            and record.worker in self.platform.cluster.workers
        ):
            # The ticket was reconciled as an eviction while the work
            # executed and the worker is still a cluster member — a crash
            # (DEAD transition): the result died with that incarnation.
            # A *deregistered* worker is the drain case instead — running
            # work completes — so it falls through to the normal path.
            self._retry_or_fail(time, state, "worker-crashed")
            return

        record.completed = time
        # Pull the next queued invocation for this worker, if any.
        queue = self._queues.get(record.worker or "", [])
        if queue:
            _, next_state = queue.pop(0)
            self._push(time, "start", next_state)

        self._finish_user_chain(time, state["payload"], record)

    def _retry_or_fail(self, time: float, state: Dict, error: str) -> None:
        """Re-route a failed request under the platform's retry policy,
        or record its terminal failure.

        ``platform.retry`` resolves the policy (explicit > controller >
        platform default) and returns ``None`` when no retry is issued —
        including the no-policy case, which keeps chaos-free runs
        bit-identical: nothing here touches RNG streams or routing state
        unless a retry actually happens. The re-route happens at failure
        time against the live cluster; the policy's backoff is charged
        into the request's latency by ``_finish_submit``'s delta charge.
        """
        record: RequestRecord = state["record"]
        retry = getattr(self.platform, "retry", None)
        replacement = retry(state["placement"]) if retry is not None else None
        if replacement is None:
            record.completed = time
            record.error = error
            self._finish_user_chain(time, state["payload"], record)
            return
        self._finish_submit(time, state["payload"], record, replacement)

    def _on_fault(self, time: float, event: FaultEvent) -> None:
        """Apply one injected fault to the platform and reconcile the
        sim-side bookkeeping the platform cannot see."""
        if not self._injector.apply(event, self.platform, now=time):
            return
        if event.kind == "crash":
            # The worker's warm containers die with it (a restarted
            # worker starts cold), and its queued-but-not-started work is
            # retried or failed — the platform already evicted the
            # tickets. Executing work is handled at its finish event (the
            # dead-ticket complete() there routes into retry-or-fail).
            target = event.target
            self._warm.forget_worker(target)
            for _, state in self._queues.pop(target, ()):
                state["placement"].complete()
                self._retry_or_fail(time, state, "worker-crashed")

    def _finish_user_chain(self, time: float, payload: Dict, record: RequestRecord) -> None:
        payload = dict(payload)
        payload["remaining"] -= 1
        if payload["remaining"] > 0:
            spec: WorkloadSpec = payload["spec"]
            payload["rid"] = record.request_id + 1_000_000  # unique per chain hop
            self._push(time + spec.pause, "submit", payload)


def _link_key(a: str, b: str) -> Tuple[str, str]:
    return (a, b) if a <= b else (b, a)


# ---------------------------------------------------------------------------
# Scheduler adapters
# ---------------------------------------------------------------------------


def gateway_scheduler(gateway) -> SchedulerFn:
    """Deprecated: adapt a :class:`Gateway` to the legacy scheduler signature.

    New code should construct a :class:`~repro.core.platform.TappPlatform`
    and pass it to :class:`Simulation` directly — the platform routes AND
    admits in one step, so no adapter is needed.
    """
    warnings.warn(
        "gateway_scheduler is deprecated; pass a TappPlatform to Simulation",
        DeprecationWarning,
        stacklevel=2,
    )

    def schedule(invocation: Invocation, _cluster: ClusterState) -> ScheduleDecision:
        return gateway.route(invocation)

    def schedule_batch(invocations, *, on_decision=None):
        return gateway.route_batch(invocations, on_decision=on_decision)

    schedule.schedule_batch = schedule_batch  # type: ignore[attr-defined]
    return schedule


def vanilla_scheduler(vanilla: Optional[VanillaScheduler] = None) -> SchedulerFn:
    """Deprecated: a policy-free :class:`TappPlatform` routes vanilla."""
    warnings.warn(
        "vanilla_scheduler is deprecated; a TappPlatform with no policy "
        "applied routes through the same vanilla fallback",
        DeprecationWarning,
        stacklevel=2,
    )
    v = vanilla or VanillaScheduler()

    def schedule(invocation: Invocation, cluster: ClusterState) -> ScheduleDecision:
        return v.schedule(invocation, cluster)

    return schedule
