"""Semantic validation of parsed tAPP scripts.

Validation is split from parsing so the watcher can re-validate scripts
against the *live* topology (unknown controller labels, unknown worker
labels, empty sets) and surface warnings without rejecting the script —
the paper's semantics treats unknown/unreachable workers as invalidated,
not as parse errors.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.core.tapp.ast import (
    DEFAULT_TAG,
    FollowupKind,
    Strategy,
    TagPolicy,
    TappScript,
    WorkerRef,
    WorkerSet,
)


def _affinity_conflicts(item, block) -> Sequence[str]:
    """Functions required present AND absent by the *effective* constraints.

    Effective clauses follow the same item ▸ block resolution rule the
    engine applies, so a conflict here means the worker item can never be
    valid while either function runs — almost certainly a script bug.
    """
    affinity = item.affinity if item.affinity is not None else block.affinity
    anti = (
        item.anti_affinity
        if item.anti_affinity is not None
        else block.anti_affinity
    )
    if affinity is None or anti is None:
        return ()
    return sorted(set(affinity.functions) & set(anti.functions))


@dataclasses.dataclass(frozen=True)
class Finding:
    level: str  # "error" | "warning"
    where: str
    message: str
    # What kind of rule produced the finding: "structure" (grammar-level
    # invariants), "topology" (references that match nothing in the live
    # deployment), "constraint" (unsatisfiable constraint combinations),
    # or one of the static-analysis categories "reachability" /
    # "satisfiability" / "starvation" produced by
    # :mod:`repro.core.analysis`. The platform's strict policy mode
    # promotes non-structure warnings to rejections; plain validation
    # treats all warnings as advisory.
    category: str = "structure"
    # True when the finding is a *proof* (the analyzer established the
    # property holds under every admissible execution, not just a lint
    # heuristic). Strict policy mode treats proofs as deploy blockers.
    proof: bool = False

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        mark = "/proof" if self.proof else ""
        return f"[{self.level}{mark}] {self.where}: {self.message}"


@dataclasses.dataclass(frozen=True)
class ValidationReport:
    findings: tuple

    @property
    def errors(self) -> Sequence[Finding]:
        return [f for f in self.findings if f.level == "error"]

    @property
    def warnings(self) -> Sequence[Finding]:
        return [f for f in self.findings if f.level == "warning"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def raise_on_error(self) -> None:
        if self.errors:
            raise TappValidationError(self)


class TappValidationError(ValueError):
    def __init__(self, report: ValidationReport) -> None:
        self.report = report
        msgs = "; ".join(str(f) for f in report.errors)
        super().__init__(f"tAPP validation failed: {msgs}")


def validate_script(
    script: TappScript,
    *,
    known_controllers: Optional[Sequence[str]] = None,
    known_worker_labels: Optional[Sequence[str]] = None,
    known_set_labels: Optional[Sequence[str]] = None,
) -> ValidationReport:
    """Validate a script, optionally against a live topology snapshot.

    Structural rules (always errors):
      * ``followup: default`` on the default tag itself (the paper pins the
        default tag's followup to ``fail``);
      * ``strategy: warm-first`` at tag level — block selection has no
        single warmth to rank by (the engine degrades it to best_first,
        so the script never does what it says);
      * a non-default tag with ``followup: default`` (explicit or implied)
        while the script has no default tag → warning (the scheduler will
        treat the missing default as ``fail``).
    Topology rules (warnings, since membership is dynamic):
      * controller labels not present in the deployment;
      * wrk/set labels that match nothing right now.
    Dead-code lints (structure warnings — valid scripts, likely mistakes):
      * the same wrk label or set label listed twice in one block (the
        duplicate item can never be selected before its twin invalidates,
        so it is almost always a copy-paste slip);
      * worker sets declared in the deployment but referenced by no block
        (dead deployment metadata, or a typo in the script) — suppressed
        when any block uses the blank set, which reaches every set member;
      * block-level ``warm-first`` on a set list whose every set declares
        its own (non-warm-first) inner strategy: the block strategy only
        orders the *sets* and member ordering never sees warm-first.
    """
    findings: List[Finding] = []

    for tag in script.tags:
        where = f"tag:{tag.tag}"
        if tag.tag == DEFAULT_TAG and tag.followup is FollowupKind.DEFAULT:
            findings.append(
                Finding(
                    "error",
                    where,
                    "the default tag cannot use 'followup: default' "
                    "(it is always 'fail')",
                )
            )
        if tag.strategy is Strategy.WARM_FIRST:
            findings.append(
                Finding(
                    "error",
                    where,
                    "strategy 'warm-first' ranks workers by warm-instance "
                    "availability; at tag level it would order blocks, "
                    "which have no single warmth — declare it on a block "
                    "or worker set instead",
                )
            )
        if (
            tag.tag != DEFAULT_TAG
            and tag.effective_followup is FollowupKind.DEFAULT
            and script.default is None
        ):
            findings.append(
                Finding(
                    "warning",
                    where,
                    "followup resolves to 'default' but the script defines no "
                    "default tag; scheduling will fail when the tag is exhausted",
                )
            )
        findings.extend(_validate_tag_topology(
            tag,
            known_controllers=known_controllers,
            known_worker_labels=known_worker_labels,
            known_set_labels=known_set_labels,
        ))

    findings.extend(_lint_unreferenced_sets(script, known_set_labels))
    return ValidationReport(findings=tuple(findings))


def _lint_unreferenced_sets(
    script: TappScript, known_set_labels: Optional[Sequence[str]]
) -> List[Finding]:
    """Declared worker sets no block references (dead deployment metadata)."""
    if known_set_labels is None:
        return []
    referenced = set()
    for tag in script.tags:
        for block in tag.blocks:
            for item in block.workers:
                if isinstance(item, WorkerSet):
                    if item.label is None:
                        # The blank set selects every worker, so every
                        # declared set is (implicitly) in play.
                        return []
                    referenced.add(item.label)
    unused = sorted(set(known_set_labels) - referenced)
    if not unused:
        return []
    return [
        Finding(
            "warning",
            "script",
            f"worker sets {unused} are declared in the deployment but "
            f"referenced by no block",
        )
    ]


def _lint_duplicate_items(block, where: str) -> List[Finding]:
    """The same wrk/set label listed more than once within one block."""
    findings: List[Finding] = []
    wrk_labels: List[str] = []
    set_labels: List[Optional[str]] = []
    for item in block.workers:
        if isinstance(item, WorkerRef):
            wrk_labels.append(item.label)
        elif isinstance(item, WorkerSet):
            set_labels.append(item.label)
    for label in sorted({w for w in wrk_labels if wrk_labels.count(w) > 1}):
        findings.append(
            Finding(
                "warning",
                where,
                f"worker {label!r} is listed {wrk_labels.count(label)} times "
                f"in this block; the duplicates are dead items",
            )
        )
    dup_sets = {s for s in set_labels if set_labels.count(s) > 1}
    for label in sorted(dup_sets, key=lambda s: (s is None, s)):
        shown = "the blank set" if label is None else f"set {label!r}"
        findings.append(
            Finding(
                "warning",
                where,
                f"{shown} is listed {set_labels.count(label)} times in this "
                f"block; the duplicate members are dead items",
            )
        )
    return findings


def _validate_tag_topology(
    tag: TagPolicy,
    *,
    known_controllers: Optional[Sequence[str]],
    known_worker_labels: Optional[Sequence[str]],
    known_set_labels: Optional[Sequence[str]],
) -> List[Finding]:
    findings: List[Finding] = []
    for bi, block in enumerate(tag.blocks):
        where = f"tag:{tag.tag}.block[{bi}]"
        findings.extend(_lint_duplicate_items(block, where))
        if (
            block.strategy is Strategy.WARM_FIRST
            and block.uses_sets
            and all(
                isinstance(item, WorkerSet)
                and item.strategy is not None
                and item.strategy is not Strategy.WARM_FIRST
                for item in block.workers
            )
        ):
            findings.append(
                Finding(
                    "warning",
                    where,
                    "block-level 'warm-first' on a set list only orders the "
                    "sets; every set here declares its own inner strategy, "
                    "so member ordering never sees warm-first — declare "
                    "'strategy: warm-first' on the sets to try warm members "
                    "first",
                )
            )
        if (
            block.controller is not None
            and known_controllers is not None
            and block.controller.label not in known_controllers
        ):
            findings.append(
                Finding(
                    "warning",
                    where,
                    f"controller {block.controller.label!r} is not present in "
                    f"the current deployment",
                    category="topology",
                )
            )
        for wi, item in enumerate(block.workers):
            iwhere = f"{where}.workers[{wi}]"
            conflicts = _affinity_conflicts(item, block)
            if conflicts:
                findings.append(
                    Finding(
                        "warning",
                        iwhere,
                        f"functions {conflicts} appear in both the effective "
                        f"affinity and anti-affinity lists; the item is "
                        f"unsatisfiable whenever they run",
                        category="constraint",
                    )
                )
            if isinstance(item, WorkerRef):
                if (
                    known_worker_labels is not None
                    and item.label not in known_worker_labels
                ):
                    findings.append(
                        Finding(
                            "warning",
                            iwhere,
                            f"worker label {item.label!r} matches no live worker",
                            category="topology",
                        )
                    )
            elif isinstance(item, WorkerSet):
                if (
                    item.label is not None
                    and known_set_labels is not None
                    and item.label not in known_set_labels
                ):
                    findings.append(
                        Finding(
                            "warning",
                            iwhere,
                            f"worker set {item.label!r} currently has no members",
                            category="topology",
                        )
                    )
    return findings
