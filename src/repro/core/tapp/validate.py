"""Semantic validation of parsed tAPP scripts.

Validation is split from parsing so the watcher can re-validate scripts
against the *live* topology (unknown controller labels, unknown worker
labels, empty sets) and surface warnings without rejecting the script —
the paper's semantics treats unknown/unreachable workers as invalidated,
not as parse errors.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.core.tapp.ast import (
    DEFAULT_TAG,
    FollowupKind,
    TagPolicy,
    TappScript,
    WorkerRef,
    WorkerSet,
)


def _affinity_conflicts(item, block) -> Sequence[str]:
    """Functions required present AND absent by the *effective* constraints.

    Effective clauses follow the same item ▸ block resolution rule the
    engine applies, so a conflict here means the worker item can never be
    valid while either function runs — almost certainly a script bug.
    """
    affinity = item.affinity if item.affinity is not None else block.affinity
    anti = (
        item.anti_affinity
        if item.anti_affinity is not None
        else block.anti_affinity
    )
    if affinity is None or anti is None:
        return ()
    return sorted(set(affinity.functions) & set(anti.functions))


@dataclasses.dataclass(frozen=True)
class Finding:
    level: str  # "error" | "warning"
    where: str
    message: str
    # What kind of rule produced the finding: "structure" (grammar-level
    # invariants), "topology" (references that match nothing in the live
    # deployment), or "constraint" (unsatisfiable constraint combinations).
    # The platform's strict policy mode promotes non-structure warnings to
    # rejections; plain validation treats all warnings as advisory.
    category: str = "structure"

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"[{self.level}] {self.where}: {self.message}"


@dataclasses.dataclass(frozen=True)
class ValidationReport:
    findings: tuple

    @property
    def errors(self) -> Sequence[Finding]:
        return [f for f in self.findings if f.level == "error"]

    @property
    def warnings(self) -> Sequence[Finding]:
        return [f for f in self.findings if f.level == "warning"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def raise_on_error(self) -> None:
        if self.errors:
            raise TappValidationError(self)


class TappValidationError(ValueError):
    def __init__(self, report: ValidationReport) -> None:
        self.report = report
        msgs = "; ".join(str(f) for f in report.errors)
        super().__init__(f"tAPP validation failed: {msgs}")


def validate_script(
    script: TappScript,
    *,
    known_controllers: Optional[Sequence[str]] = None,
    known_worker_labels: Optional[Sequence[str]] = None,
    known_set_labels: Optional[Sequence[str]] = None,
) -> ValidationReport:
    """Validate a script, optionally against a live topology snapshot.

    Structural rules (always errors):
      * ``followup: default`` on the default tag itself (the paper pins the
        default tag's followup to ``fail``);
      * a non-default tag with ``followup: default`` (explicit or implied)
        while the script has no default tag → warning (the scheduler will
        treat the missing default as ``fail``).
    Topology rules (warnings, since membership is dynamic):
      * controller labels not present in the deployment;
      * wrk/set labels that match nothing right now.
    """
    findings: List[Finding] = []

    for tag in script.tags:
        where = f"tag:{tag.tag}"
        if tag.tag == DEFAULT_TAG and tag.followup is FollowupKind.DEFAULT:
            findings.append(
                Finding(
                    "error",
                    where,
                    "the default tag cannot use 'followup: default' "
                    "(it is always 'fail')",
                )
            )
        if (
            tag.tag != DEFAULT_TAG
            and tag.effective_followup is FollowupKind.DEFAULT
            and script.default is None
        ):
            findings.append(
                Finding(
                    "warning",
                    where,
                    "followup resolves to 'default' but the script defines no "
                    "default tag; scheduling will fail when the tag is exhausted",
                )
            )
        findings.extend(_validate_tag_topology(
            tag,
            known_controllers=known_controllers,
            known_worker_labels=known_worker_labels,
            known_set_labels=known_set_labels,
        ))

    return ValidationReport(findings=tuple(findings))


def _validate_tag_topology(
    tag: TagPolicy,
    *,
    known_controllers: Optional[Sequence[str]],
    known_worker_labels: Optional[Sequence[str]],
    known_set_labels: Optional[Sequence[str]],
) -> List[Finding]:
    findings: List[Finding] = []
    for bi, block in enumerate(tag.blocks):
        where = f"tag:{tag.tag}.block[{bi}]"
        if (
            block.controller is not None
            and known_controllers is not None
            and block.controller.label not in known_controllers
        ):
            findings.append(
                Finding(
                    "warning",
                    where,
                    f"controller {block.controller.label!r} is not present in "
                    f"the current deployment",
                    category="topology",
                )
            )
        for wi, item in enumerate(block.workers):
            iwhere = f"{where}.workers[{wi}]"
            conflicts = _affinity_conflicts(item, block)
            if conflicts:
                findings.append(
                    Finding(
                        "warning",
                        iwhere,
                        f"functions {conflicts} appear in both the effective "
                        f"affinity and anti-affinity lists; the item is "
                        f"unsatisfiable whenever they run",
                        category="constraint",
                    )
                )
            if isinstance(item, WorkerRef):
                if (
                    known_worker_labels is not None
                    and item.label not in known_worker_labels
                ):
                    findings.append(
                        Finding(
                            "warning",
                            iwhere,
                            f"worker label {item.label!r} matches no live worker",
                            category="topology",
                        )
                    )
            elif isinstance(item, WorkerSet):
                if (
                    item.label is not None
                    and known_set_labels is not None
                    and item.label not in known_set_labels
                ):
                    findings.append(
                        Finding(
                            "warning",
                            iwhere,
                            f"worker set {item.label!r} currently has no members",
                            category="topology",
                        )
                    )
    return findings
